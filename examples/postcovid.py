"""Vignette 2 — identify Post-COVID-19 patients, on the session API.

    PYTHONPATH=src python examples/postcovid.py

``MiningSession.fit`` mines the cohort (any engine — the planner picks);
``SequenceFrame.arrays()`` hands the canonical flat corpus to the WHO-rule
identifier (core.postcovid): a PCC symptom starts after infection, persists
>= 2 months (duration spread of covid->symptom sequences), is new-onset,
and is not explained by a competing cause.
"""
import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.core import postcovid
from repro.data import dbmart, synthea


def main():
    pats, dates, phx, truth = synthea.generate_cohort(
        n_patients=240, avg_events=44, seed=7)
    db = dbmart.from_rows(pats, dates, phx)
    seq, dur, pat, msk = MiningSession(MiningConfig()).fit(db).arrays()

    cfg = postcovid.PostCovidConfig(
        covid_id=db.vocab.phenx_index[synthea.COVID])
    pcc, candidates = postcovid.identify(
        seq, dur, pat, msk, db.phenx, db.nevents, cfg,
        db.n_patients, db.vocab.n_phenx)
    pcc = np.asarray(pcc)
    pred = postcovid.decode_symptoms(pcc, db.vocab)

    n_pred = int(pcc.any(1).sum())
    print(f"cohort: {db.n_patients} patients | predicted PCC: {n_pred} | "
          f"ground truth: {int(truth.long_covid.sum())}")

    tp = fp = fn = 0
    for p in range(db.n_patients):
        t, pr = truth.symptom_sets[p], pred[p]
        tp += len(t & pr)
        fp += len(pr - t)
        fn += len(t - pr)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    print(f"symptom-level: precision={prec:.3f} recall={rec:.3f}")

    print("\nexample patients:")
    shown = 0
    for p in range(db.n_patients):
        if pred[p] and shown < 5:
            print(f"  patient {p}: {sorted(pred[p])} "
                  f"(truth: {sorted(truth.symptom_sets[p])})")
            shown += 1


if __name__ == "__main__":
    main()

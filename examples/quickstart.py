"""Quickstart: mine transitive sequences through the unified session API.

    PYTHONPATH=src python examples/quickstart.py

The R-package happy path on the façade (``repro.api``): alphanumeric dbmart
-> ``MiningSession.fit`` (the planner picks the engine; print
``session.plan(db)`` to see why, or force one with
``MiningConfig(engine=...)``) -> chainable screen / top-k -> human-readable
sequences.  The hand-wired mine->flatten->screen->decode version of this
script lives in git history; the façade is the documented path.
"""
from repro.api import MiningConfig, MiningSession
from repro.data import dbmart, synthea


def main():
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=128, avg_events=32, seed=42)
    db = dbmart.from_rows(pats, dates, phx)
    print(f"dbmart: {db.n_patients} patients, {db.total_events} events, "
          f"{db.vocab.n_phenx} unique phenX")

    session = MiningSession(MiningConfig(threshold=5))
    print(session.plan(db))
    frame = session.fit(db)
    print(f"mined {len(frame):,} transitive sequences")
    print(f"screened at support>=5: kept {frame.screen().n_kept:,}")

    print("\nmost supported transitive sequences:")
    for d in frame.top_k(8).decode():
        print(f"  {d.text:55s} support={d.support}")

    # --- corpus-free screening ---------------------------------------------
    # screen="fused" counts support in the [2^H] bucket table without ever
    # materializing the [P, n, n] pair corpus (peak = one patient block +
    # the table), then materializes survivors only — byte-identical to the
    # materializing path above, asserted across every engine in CI.
    fused = MiningSession(MiningConfig(threshold=5, screen="fused")).fit(db)
    print(f"\ncorpus-free screen kept {fused.screen().n_kept:,} "
          f"(same bytes, no corpus on the screen pass)")

    # --- streaming with checkpoint / resume --------------------------------
    # The same cohort arriving incrementally, with a byte budget tight
    # enough to spill and a disk budget demoting cold histories into the
    # compressed block tier; the session checkpoints mid-stream and a
    # fresh session restores it, continuing byte-identically.
    import tempfile

    stream = MiningSession(MiningConfig(
        threshold=5, screen="hash", tick_patients=16,
        budget_bytes=1 << 20, disk_bytes=1 << 18), vocab=db.vocab)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        stream.submit(p, db.date[p, :n], db.phenx[p, :n])
    stream.tick()                              # ingest one wave...
    with tempfile.TemporaryDirectory() as ckpt:
        stream.checkpoint(ckpt)                # ...snapshot it atomically
        resumed = MiningSession.restore(ckpt, vocab=db.vocab)
    resumed.run()                              # drain the rest after "restart"
    print(f"\nresumed stream: kept {resumed.frame().screen().n_kept:,} "
          f"at support>=5 (continuation is byte-identical)")


if __name__ == "__main__":
    main()

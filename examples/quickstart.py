"""Quickstart: mine transitive sequences through the unified session API.

    PYTHONPATH=src python examples/quickstart.py

The R-package happy path on the façade (``repro.api``): alphanumeric dbmart
-> ``MiningSession.fit`` (the planner picks the engine; print
``session.plan(db)`` to see why, or force one with
``MiningConfig(engine=...)``) -> chainable screen / top-k -> human-readable
sequences.  The hand-wired mine->flatten->screen->decode version of this
script lives in git history; the façade is the documented path.
"""
from repro.api import MiningConfig, MiningSession
from repro.data import dbmart, synthea


def main():
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=128, avg_events=32, seed=42)
    db = dbmart.from_rows(pats, dates, phx)
    print(f"dbmart: {db.n_patients} patients, {db.total_events} events, "
          f"{db.vocab.n_phenx} unique phenX")

    session = MiningSession(MiningConfig(threshold=5))
    print(session.plan(db))
    frame = session.fit(db)
    print(f"mined {len(frame):,} transitive sequences")
    print(f"screened at support>=5: kept {frame.screen().n_kept:,}")

    print("\nmost supported transitive sequences:")
    for d in frame.top_k(8).decode():
        print(f"  {d.text:55s} support={d.support}")

    # --- corpus-free screening ---------------------------------------------
    # screen="fused" counts support in the [2^H] bucket table without ever
    # materializing the [P, n, n] pair corpus (peak = one patient block +
    # the table), then materializes survivors only — byte-identical to the
    # materializing path above, asserted across every engine in CI.
    fused = MiningSession(MiningConfig(threshold=5, screen="fused")).fit(db)
    print(f"\ncorpus-free screen kept {fused.screen().n_kept:,} "
          f"(same bytes, no corpus on the screen pass)")

    # --- streaming with checkpoint / resume --------------------------------
    # The same cohort arriving incrementally, with a byte budget tight
    # enough to spill and a disk budget demoting cold histories into the
    # compressed block tier; the session checkpoints mid-stream and a
    # fresh session restores it, continuing byte-identically.
    import tempfile

    stream = MiningSession(MiningConfig(
        threshold=5, screen="hash", tick_patients=16,
        budget_bytes=1 << 20, disk_bytes=1 << 18), vocab=db.vocab)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        stream.submit(p, db.date[p, :n], db.phenx[p, :n])
    stream.tick()                              # ingest one wave...
    with tempfile.TemporaryDirectory() as ckpt:
        stream.checkpoint(ckpt)                # ...snapshot it atomically
        resumed = MiningSession.restore(ckpt, vocab=db.vocab)
    resumed.run()                              # drain the rest after "restart"
    print(f"\nresumed stream: kept {resumed.frame().screen().n_kept:,} "
          f"at support>=5 (continuation is byte-identical)")

    # --- query serving -----------------------------------------------------
    # The read path: session.serve() publishes a snapshot-isolated replica
    # at every tick boundary and answers plan chains in batched waves —
    # byte-identical to chaining the same ops on the frame, but one kernel
    # dispatch per wave of distinct plans plus an LRU keyed on canonical
    # plans, so repeated/permuted queries are cache hits.
    from repro.serving.tspm import plan

    server = resumed.serve(batch_size=16)
    queries = [plan().screen().min_duration(30),
               plan().min_duration(30).screen(),    # same canonical plan
               plan().screen().top_k(8)]
    with server:                                    # background wave loop
        results = [server.submit(q).result(timeout=60) for q in queries]
    for q, r in zip(queries, results):
        print(f"  serve {str(q):40s} -> {r.n_kept:,} rows "
              f"@ tick {r.view.tick}")
    st = server.stats()
    print(f"served {st['queries']} queries in {st['waves']} wave(s), "
          f"cache hit ratio {st['cache_hit_ratio']:.2f}")


if __name__ == "__main__":
    main()

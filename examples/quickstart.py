"""Quickstart: mine transitive sequences from a synthetic clinical cohort.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the R-package happy path: alphanumeric dbmart -> numeric encoding
-> transitive mining (with durations) -> sparsity screen -> translate the
top sequences back to human-readable form.
"""
import numpy as np

from repro.core import mining, msmr, sparsity
from repro.data import dbmart, synthea


def main():
    # 1. a synthetic Synthea-style cohort (the paper ships one with the pkg)
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=128, avg_events=32, seed=42)
    db = dbmart.from_rows(pats, dates, phx)
    print(f"dbmart: {db.n_patients} patients, {db.total_events} events, "
          f"{db.vocab.n_phenx} unique phenX")

    # 2. transitive sequences + durations (n(n-1)/2 per patient)
    mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
    print(f"mined {int(mined.n_mined):,} transitive sequences "
          f"(closed form: {int(mining.count_sequences(db.nevents)):,})")

    # 3. sparsity screening (paper-faithful sort-based variant)
    seq, dur, pat, msk = mining.flatten(mined)
    scr = sparsity.screen_sorted(seq, dur, pat, msk, threshold=5)
    print(f"screened at support>=5: kept {int(scr.n_kept):,}")

    # 4. top sequences by distinct-patient support, decoded to strings
    _, _, _, u_key, u_sup, n_u = sparsity.support_counts(seq, pat, msk)
    top = msmr.top_sequences(u_key, u_sup, k=8)
    print("\nmost supported transitive sequences:")
    u_key = np.asarray(u_key)
    u_sup = np.asarray(u_sup)
    from repro.core.encoding import SENTINEL

    order = np.argsort(-u_sup)
    shown = 0
    for i in order:
        if shown >= 8 or u_sup[i] <= 0 or u_key[i] == SENTINEL:
            break
        print(f"  {db.vocab.decode_sequence(int(u_key[i])):55s} "
              f"support={int(u_sup[i])}")
        shown += 1


if __name__ == "__main__":
    main()

"""Quickstart: mine transitive sequences through the unified session API.

    PYTHONPATH=src python examples/quickstart.py

The R-package happy path on the façade (``repro.api``): alphanumeric dbmart
-> ``MiningSession.fit`` (the planner picks the engine; print
``session.plan(db)`` to see why, or force one with
``MiningConfig(engine=...)``) -> chainable screen / top-k -> human-readable
sequences.  The hand-wired mine->flatten->screen->decode version of this
script lives in git history; the façade is the documented path.
"""
from repro.api import MiningConfig, MiningSession
from repro.data import dbmart, synthea


def main():
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=128, avg_events=32, seed=42)
    db = dbmart.from_rows(pats, dates, phx)
    print(f"dbmart: {db.n_patients} patients, {db.total_events} events, "
          f"{db.vocab.n_phenx} unique phenX")

    session = MiningSession(MiningConfig(threshold=5))
    print(session.plan(db))
    frame = session.fit(db)
    print(f"mined {len(frame):,} transitive sequences")
    print(f"screened at support>=5: kept {frame.screen().n_kept:,}")

    print("\nmost supported transitive sequences:")
    for d in frame.top_k(8).decode():
        print(f"  {d.text:55s} support={d.support}")


if __name__ == "__main__":
    main()

"""Vignette 1 — tSPM+ inside an MLHO-style ML workflow, on the session API.

    PYTHONPATH=src python examples/mlho_integration.py

Pipeline (the paper's first vignette): ``MiningSession.fit`` -> top-1000
sequences by support -> ``SequenceFrame.to_features`` (patient x sequence
matrix) -> JMI re-ranking (core.msmr) -> logistic regression -> translate
the most predictive sequences back to human-readable strings.
The task: predict long-COVID status from mined sequences.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.core import msmr
from repro.data import dbmart, synthea


def train_logreg(x, y, steps=400, lr=0.5):
    w = jnp.zeros(x.shape[1])
    b = jnp.zeros(())

    @jax.jit
    def step(w, b):
        def loss(w, b):
            z = x @ w + b
            return jnp.mean(jnp.logaddexp(0.0, z) - y * z) + 1e-3 * w @ w

        gw, gb = jax.grad(loss, argnums=(0, 1))(w, b)
        return w - lr * gw, b - lr * gb

    for _ in range(steps):
        w, b = step(w, b)
    return w, b


def main():
    pats, dates, phx, truth = synthea.generate_cohort(
        n_patients=400, avg_events=40, seed=11)
    db = dbmart.from_rows(pats, dates, phx)
    y = truth.long_covid.astype(np.float32)

    # mine + MSMR front half: one façade chain
    frame = MiningSession(MiningConfig()).fit(db)
    fm = frame.to_features(k=1000)
    sel = msmr.select_jmi(np.asarray(fm.x), y, k=32)
    x = jnp.asarray(np.asarray(fm.x)[:, sel])
    print(f"features: {fm.x.shape[1]} screened -> {x.shape[1]} after JMI")

    # train/test split + classifier
    rng = np.random.default_rng(0)
    idx = rng.permutation(db.n_patients)
    tr, te = idx[:320], idx[320:]
    w, b = train_logreg(x[tr], jnp.asarray(y[tr]))
    pred = np.asarray(jax.nn.sigmoid(x[te] @ w + b))
    pos = pred[y[te] == 1]
    neg = pred[y[te] == 0]
    if len(pos) and len(neg):
        auc = (pos[:, None] > neg[None, :]).mean() + \
            0.5 * (pos[:, None] == neg[None, :]).mean()
    else:
        auc = float("nan")
    acc = ((pred > 0.5) == y[te]).mean()
    print(f"held-out: accuracy={acc:.3f} AUC={auc:.3f}")

    # translate the most predictive sequences back (paper: human readable)
    w_np = np.asarray(w)
    feats_np = np.asarray(fm.feature_ids)[sel]
    print("\nmost predictive transitive sequences:")
    for i in np.argsort(-np.abs(w_np))[:6]:
        print(f"  {db.vocab.decode_sequence(int(feats_np[i])):55s} "
              f"w={w_np[i]:+.2f}")


if __name__ == "__main__":
    main()

"""Vignette 1 — tSPM+ inside an MLHO-style ML workflow.

    PYTHONPATH=src python examples/mlho_integration.py

Pipeline (mirrors the paper's first vignette): numeric conversion ->
transitive mining -> sparsity screen -> MSMR (top-200 by support, JMI
re-ranking) -> train a classifier on sequence features -> translate the
most predictive sequences back to human-readable strings.
The task: predict long-COVID status from mined sequences.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mining, msmr, sparsity
from repro.data import dbmart, synthea


def train_logreg(x, y, steps=400, lr=0.5):
    w = jnp.zeros(x.shape[1])
    b = jnp.zeros(())

    @jax.jit
    def step(w, b):
        def loss(w, b):
            z = x @ w + b
            return jnp.mean(jnp.logaddexp(0.0, z) - y * z) + 1e-3 * w @ w

        gw, gb = jax.grad(loss, argnums=(0, 1))(w, b)
        return w - lr * gw, b - lr * gb

    for _ in range(steps):
        w, b = step(w, b)
    return w, b


def main():
    pats, dates, phx, truth = synthea.generate_cohort(
        n_patients=400, avg_events=40, seed=11)
    db = dbmart.from_rows(pats, dates, phx)
    y = truth.long_covid.astype(np.float32)

    # mine + screen
    mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
    seq, dur, pat, msk = mining.flatten(mined)
    _, _, _, u_key, u_sup, _ = sparsity.support_counts(seq, pat, msk)

    # MSMR: support screen (top-1000), then JMI against the label
    feats = msmr.top_sequences(u_key, u_sup, k=1000)
    fm = msmr.feature_matrix(seq, pat, msk, feats, n_patients=db.n_patients)
    sel = msmr.select_jmi(np.asarray(fm.x), y, k=32)
    x = jnp.asarray(np.asarray(fm.x)[:, sel])
    print(f"features: {fm.x.shape[1]} screened -> {x.shape[1]} after JMI")

    # train/test split + classifier
    rng = np.random.default_rng(0)
    idx = rng.permutation(db.n_patients)
    tr, te = idx[:320], idx[320:]
    w, b = train_logreg(x[tr], jnp.asarray(y[tr]))
    pred = np.asarray(jax.nn.sigmoid(x[te] @ w + b))
    auc_num = 0
    pos = pred[y[te] == 1]
    neg = pred[y[te] == 0]
    if len(pos) and len(neg):
        auc = (pos[:, None] > neg[None, :]).mean() + \
            0.5 * (pos[:, None] == neg[None, :]).mean()
    else:
        auc = float("nan")
    acc = ((pred > 0.5) == y[te]).mean()
    print(f"held-out: accuracy={acc:.3f} AUC={auc:.3f}")

    # translate the most predictive sequences back (paper: human readable)
    w_np = np.asarray(w)
    feats_np = np.asarray(feats)[sel]
    print("\nmost predictive transitive sequences:")
    for i in np.argsort(-np.abs(w_np))[:6]:
        print(f"  {db.vocab.decode_sequence(int(feats_np[i])):55s} "
              f"w={w_np[i]:+.2f}")


if __name__ == "__main__":
    main()

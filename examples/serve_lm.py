"""Batched serving demo: prefill + decode with the wave scheduler.

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b --reduced
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "tspm-mlho", "--reduced",
                            "--requests", "8", "--batch", "4"]
    serve.main(argv)

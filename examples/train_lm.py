"""End-to-end driver: train an LM on tSPM+-mined clinical event streams.

    PYTHONPATH=src python examples/train_lm.py                  # quick CPU run
    PYTHONPATH=src python examples/train_lm.py --full           # ~100M params

Wraps launch/train.py: synthetic cohort -> mining pipeline -> token corpus
-> train with checkpointing + preemption handling.  Any assigned arch:
    python examples/train_lm.py --arch gemma2-2b --reduced
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--full" in argv:
        argv.remove("--full")
        argv = ["--arch", "tspm-mlho", "--steps", "300", "--batch", "8",
                "--seq", "256", "--patients", "512"] + argv
    elif not argv:
        argv = ["--arch", "tspm-mlho", "--reduced", "--steps", "120",
                "--batch", "8", "--seq", "128", "--ckpt-dir",
                "/tmp/tspm_lm_ckpt"]
    train.main(argv)

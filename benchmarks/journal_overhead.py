"""Journaling overhead — hash-chained audit log on vs off, same ingest.

The tick journal (repro.journal) rides the per-event hot path: every
submitted delta is re-encoded and chained, every tick appends a wave
digest, and every ``commit_every`` ticks a merkle commitment hashes the
corpus and sketch table.  Auditability must stay cheap, so it carries an
acceptance bar: journaling-on ingest may cost at most **< 5%** over
journaling-off, and must not change a single mined byte.

The workload runs the ``kernel`` backend (the Pallas delta kernel in
CPU-interpret mode, same as the tier-1 streaming bench) at a dense
clinical event mix — the regime the paper's pipeline actually mines in,
where tick compute dominates and the journal's fixed per-entry costs
are the thing under test rather than the jit dispatch floor.

Measurement discipline extends benchmarks/observability: GC off inside
the timed region, and every journaled run is *bracketed* by two bare
runs — the per-round ratio compares against the mean of its brackets,
so linear drift in ambient load cancels exactly; the reported figure is
the median of the bracketed ratios, immune to a minority of
contaminated rounds (unlike per-side best-of-N).

After the timed rounds the journaled run is verified end-to-end (chain +
shadow replay + commitments + final-state comparison) and replayed into
a fresh session whose corpus bytes are asserted identical — the artifact
never reports a throughput number for a journal that would not replay.

Prints ``name,us_per_call,derived`` CSV rows; ``main(json_path=...)``
writes BENCH_journal_overhead.json (gated in ci.yml).
"""
from __future__ import annotations

import gc
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.data import dbmart, synthea
from repro.launch.stream import replay_waves

#: The acceptance ceiling: journaling-on ingest may cost at most this
#: fraction over journaling-off (ci.yml gates the stored artifact on it).
OVERHEAD_CEILING = 0.05


def _replay(db, config, n_waves, seed):
    session = MiningSession(config)
    gc.collect()
    gcold = gc.isenabled()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for _ in replay_waves(db, session, n_waves, seed):
            session.service.run()
        dt = time.perf_counter() - t0
    finally:
        if gcold:
            gc.enable()
    return session, dt


def journal_overhead(n_patients=64, avg_events=72, n_waves=3,
                     tick_patients=8, commit_every=16, repeats=13, seed=13,
                     backend="kernel"):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    base = MiningConfig(engine="stream", tick_patients=tick_patients,
                        backend=backend, n_buckets_log2=16, screen="hash")
    root = tempfile.mkdtemp(prefix="tspm_bench_journal_")

    def on_config(tag):
        # a fresh journal dir per round: re-attaching would resume the
        # previous round's chain and skew late rounds with reopen scans
        d = os.path.join(root, tag)
        shutil.rmtree(d, ignore_errors=True)
        return base.replace(journal_dir=d, journal_commit_every=commit_every)

    # warm the jit caches once so neither side pays first-compile
    _replay(db, base, n_waves, seed)
    _replay(db, on_config("warm"), n_waves, seed)

    try:
        # bracketed rounds: off, on, off, on, ..., off — each journaled
        # run's ratio is taken against the mean of its two bare
        # neighbours, cancelling linear ambient drift
        session_off, dt = _replay(db, base, n_waves, seed)
        offs = [dt]
        ratios = []
        session_on = None
        for r in range(repeats):
            session_on, dt_on = _replay(db, on_config(f"r{r}"), n_waves,
                                        seed)
            session_off, dt = _replay(db, base, n_waves, seed)
            offs.append(dt)
            ratios.append(dt_on / max((offs[-2] + offs[-1]) / 2, 1e-12)
                          - 1.0)
        overhead = float(np.median(ratios))
        off_s = float(np.median(offs))
        on_s = off_s * (1.0 + overhead)

        # exactness: journaling must never change mined bytes
        f_off = session_off.frame()
        f_on = session_on.frame()
        for a, b in zip(f_off.arrays(), f_on.arrays()):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                "journaling changed mined results"

        # auditability: the last journaled round verifies (chain + shadow
        # replay + merkle commitments + final state) and replays into a
        # byte-identical corpus
        res = session_on.verify()
        assert res.ok, f"journal failed verification: {res}"
        replayed = MiningSession.replay(session_on.config.journal_dir)
        a, b = session_on.service.snapshot(), replayed.service.snapshot()
        for name in ("seq", "dur", "patient", "counts"):
            assert np.asarray(getattr(a, name)).tobytes() \
                == np.asarray(getattr(b, name)).tobytes(), \
                f"replayed {name} differs from the live run"

        assert overhead < OVERHEAD_CEILING, \
            f"journaling overhead {overhead * 100:.2f}% exceeds the " \
            f"{OVERHEAD_CEILING * 100:.0f}% ceiling"

        j = session_on.journal()
        journal_bytes = sum(
            os.path.getsize(os.path.join(dp, f))
            for dp, _, fs in os.walk(j.root) for f in fs)
        return {
            "patients": n_patients, "avg_events": avg_events,
            "waves": n_waves, "backend": backend, "repeats": repeats,
            "commit_every": commit_every,
            "off_s": off_s, "on_s": on_s,
            "overhead_frac": overhead,
            "overhead_ceiling": OVERHEAD_CEILING,
            "n_entries": j.n_entries, "n_ticks": j.n_ticks,
            "n_commits": j.n_commits,
            "journal_bytes": journal_bytes,
            "verify": str(res),
            "replay_exact": True,
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


def main(small=True, json_path=None, backend="kernel"):
    kw = dict() if small else dict(n_patients=120, avg_events=96, n_waves=4,
                                   repeats=15)
    r = journal_overhead(backend=backend, **kw)
    print("name,us_per_call,derived")
    print(f"journal/ingest_off,{r['off_s']*1e6:.0f},ticks={r['n_ticks']}")
    print(f"journal/ingest_on,{r['on_s']*1e6:.0f},"
          f"overhead={r['overhead_frac']*100:+.2f}% "
          f"(ceiling {r['overhead_ceiling']*100:.0f}%)")
    print(f"journal/audit,,entries={r['n_entries']};"
          f"commits={r['n_commits']};bytes={r['journal_bytes']};"
          f"replay_exact=1")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"journal/artifact,,{json_path}")
    return r


if __name__ == "__main__":
    main()

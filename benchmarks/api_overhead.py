"""Façade dispatch overhead — MiningSession vs hand-wired core calls.

The session API must be free on the hot path: ``MiningSession.fit`` +
``SequenceFrame.screen`` does planner dispatch, frame canonicalization and
lazy-mask composition on top of exactly the work the hand-wired
mine -> flatten -> screen flow does.  This suite times both on the same
cohort (same backend, both end-to-end to a host-side kept count) and
reports the relative overhead; the acceptance bar for the batch path is
< 5%.  Both paths are timed warm (first call pays jit tracing for both).

Prints ``name,us_per_call,derived`` CSV rows; ``main(json_path=...)``
writes BENCH_api_overhead.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.core import mining, sparsity
from repro.data import dbmart, synthea


def _best_times(fns: dict, repeats: int) -> tuple[dict, dict]:
    """Best-of-N wall time per function, *interleaved* round-robin so host
    scheduler noise and thermal drift hit every path equally.  Returns
    ({name: best_seconds}, {name: last_result})."""
    times = {name: [] for name in fns}
    outs = {}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            outs[name] = fn()
            times[name].append(time.perf_counter() - t0)
    return {n: float(np.min(ts)) for n, ts in times.items()}, outs


def api_overhead(n_patients=400, avg_events=40, threshold=4, repeats=15,
                 backend="jnp", seed=13):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    config = MiningConfig(threshold=threshold, backend=backend)

    # --- mining path: the < 5% dispatch-overhead bar -----------------------
    # Same work on both sides (mine + flatten + host materialization); the
    # façade adds planner dispatch, frame construction and the canonical
    # (seq, patient, dur) lexsort on top.
    def mine_direct():
        mined = mining.mine(db.phenx, db.date, db.nevents, backend=backend)
        seq, dur, pat, msk = mining.flatten(mined)
        # same typed host materialization the frame does, so the diff
        # isolates planner + session + frame-object dispatch
        return (np.asarray(seq, np.int64), np.asarray(dur, np.int32),
                np.asarray(pat, np.int32), np.asarray(msk, bool))

    def mine_facade():
        return MiningSession(config).fit(db)

    # --- end-to-end: mine + exact screen to a host-side kept count ---------
    def screen_direct():
        seq, dur, pat, msk = mine_direct()
        return int(sparsity.screen_sorted(seq, dur, pat, msk, threshold).n_kept)

    def screen_facade():
        return MiningSession(config).fit(db).screen().n_kept

    # --- dispatch-only: the façade machinery with zero mining work ---------
    # Constructing the session, planning, and wrapping pre-mined host arrays
    # in a frame is everything fit() adds over the hand-wired flow; timing
    # it directly is stable where the end-to-end difference (two ~10 ms
    # totals on a shared host) is not.
    pre = mine_direct()

    def dispatch_only():
        from repro.api.frame import SequenceFrame

        sess = MiningSession(config)
        sess.plan(db)
        seq, dur, pat, msk = pre
        return SequenceFrame(seq, dur, pat, msk, threshold=threshold)

    mine_direct()                 # warm the jit caches for both paths
    screen_facade()
    screen_direct()
    dispatch_ts, _ = _best_times({"dispatch": dispatch_only},
                                 max(repeats, 20))
    ts, outs = _best_times(
        {"mine_direct": mine_direct, "mine_facade": mine_facade,
         "screen_direct": screen_direct, "screen_facade": screen_facade},
        repeats)
    mine_direct_s, mine_facade_s = ts["mine_direct"], ts["mine_facade"]
    screen_direct_s, screen_facade_s = ts["screen_direct"], ts["screen_facade"]
    frame = outs["mine_facade"]
    n_direct, n_facade = outs["screen_direct"], outs["screen_facade"]
    assert n_direct == n_facade, \
        f"façade kept {n_facade}, hand-wired kept {n_direct}"

    plan_ts, plan_outs = _best_times(
        {"plan": lambda: MiningSession(config).plan(db)}, max(repeats, 20))
    plan_s, plan = plan_ts["plan"], plan_outs["plan"]

    # telemetry snapshot for the artifact: one extra fit outside the timed
    # paths (the timed sessions above all run telemetry-disabled)
    tel_session = MiningSession(config.replace(
        engine="stream", telemetry=True))
    tel_session.fit(db)
    return {
        "telemetry": tel_session.metrics(),
        "patients": n_patients, "avg_events": avg_events,
        "threshold": threshold, "backend": backend, "repeats": repeats,
        "engine": plan.engine, "corpus_rows": len(frame),
        "dispatch_s": dispatch_ts["dispatch"],
        "dispatch_overhead_frac":
            dispatch_ts["dispatch"] / max(mine_direct_s, 1e-12),
        "mine_direct_s": mine_direct_s, "mine_facade_s": mine_facade_s,
        "mine_overhead_frac": mine_facade_s / max(mine_direct_s, 1e-12) - 1.0,
        "screen_direct_s": screen_direct_s, "screen_facade_s": screen_facade_s,
        "screen_speedup": screen_direct_s / max(screen_facade_s, 1e-12),
        "plan_s": plan_s,
        "n_kept": n_direct,
    }


def main(small=True, json_path=None, backend="jnp"):
    kw = dict() if small else dict(n_patients=1000, avg_events=56)
    r = api_overhead(backend=backend, **kw)
    print("name,us_per_call,derived")
    print(f"api_overhead/mine_direct,{r['mine_direct_s']*1e6:.0f},"
          f"rows={r['corpus_rows']}")
    print(f"api_overhead/mine_facade,{r['mine_facade_s']*1e6:.0f},"
          f"engine={r['engine']};"
          f"end_to_end_delta={r['mine_overhead_frac']*100:+.2f}%")
    print(f"api_overhead/dispatch,{r['dispatch_s']*1e6:.0f},"
          f"overhead={r['dispatch_overhead_frac']*100:.2f}% of the batch "
          f"path (the <5% bar)")
    print(f"api_overhead/screen_direct,{r['screen_direct_s']*1e6:.0f},"
          f"kept={r['n_kept']} (lax.sort screen_sorted)")
    print(f"api_overhead/screen_facade,{r['screen_facade_s']*1e6:.0f},"
          f"speedup={r['screen_speedup']:.2f}x (canonical-order np screen)")
    print(f"api_overhead/plan,{r['plan_s']*1e6:.0f},planner dispatch only")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"api_overhead/artifact,,{json_path}")
    return r


if __name__ == "__main__":
    main()

"""Serving latency under concurrent load: batched waves vs per-query eval.

The read-path claim (ISSUE 9 / ROADMAP item 4): at >= 32 concurrent
clients, the batched QueryServer improves tail latency by >= 2x over
naive sequential evaluation.  Both sides serve the *same* zipf-ish query
stream (repeated plans — the serving workload shape) from the same number
of client threads, and latency is measured submit-to-result per query, so
queue wait counts on both sides:

  * **sequential** — each query builds a fresh ``SequenceFrame`` chain on
    the snapshot and forces it under a server-side lock: one evaluation
    per query, 2-4 jax dispatches each, no result reuse — the "every
    query re-runs mask composition" status quo;
  * **batched** — the same plans through ``session.serve()``: canonical
    plan dedup, LRU result cache, and ONE jitted vmapped kernel dispatch
    per wave of up to ``batch_size`` distinct programs.

Exactness is asserted before speed: every batched keep mask must be
byte-identical to the frame-path mask for its plan.  ``main`` writes
BENCH_serving_latency.json with p50/p99 for both paths and asserts the
p99 speedup ceiling (``min_p99_speedup``) that CI re-validates.
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.data import dbmart, synthea
from repro.serving.tspm import plan


def _percentile(lat_s: list, q: float) -> float:
    lat = np.sort(np.asarray(lat_s))
    return float(lat[int(q * (len(lat) - 1))])


def _make_pool(codes: np.ndarray, rng: np.random.Generator, n_distinct: int):
    """A pool of distinct plans spanning the op vocabulary."""
    pool = []
    while len(pool) < n_distinct:
        kind = len(pool) % 4
        c = int(rng.choice(codes))
        if kind == 0:
            pool.append(plan().screen().starts_with(c))
        elif kind == 1:
            pool.append(plan().screen().ends_with(c))
        elif kind == 2:
            pool.append(plan().screen().min_duration(
                int(rng.integers(1, 120))))
        else:
            pool.append(plan().screen().starts_with(c).top_k(
                int(rng.integers(1, 16))))
    return pool


def _drive(n_clients: int, work):
    """Run ``work(plan) -> latency_s`` from ``n_clients`` threads over a
    strided split of the stream; returns per-query latencies.  Clients
    rendezvous on a barrier before the clock starts, so thread spawn cost
    never pollutes the latency distribution."""
    lats: list[float] = []
    lock = threading.Lock()
    barrier = threading.Barrier(n_clients + 1)

    def client(chunk):
        barrier.wait()
        out = [work(p) for p in chunk]
        with lock:
            lats.extend(out)

    threads = [threading.Thread(target=client, args=(work.stream[i::n_clients],))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    return lats, time.perf_counter() - t0


def serving_latency(n_patients=128, avg_events=24, threshold=3,
                    n_queries=2048, n_clients=32, batch_size=32,
                    n_distinct=24, seed=7, backend="jnp"):
    assert n_clients >= 32, "the acceptance claim is at >= 32 clients"
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    session = MiningSession(MiningConfig(
        threshold=threshold, tick_patients=8, backend=backend))
    server = session.serve(batch_size=batch_size)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        if n:
            session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.run()
    view = server.view()
    base = view.frame

    rng = np.random.default_rng(seed)
    codes = np.unique(db.phenx[db.phenx >= 0])
    pool = _make_pool(codes, rng, n_distinct)
    # zipf-ish repeats: the serving workload shape (hot cohort queries)
    weights = 1.0 / np.arange(1, len(pool) + 1)
    stream = [pool[i] for i in rng.choice(
        len(pool), size=n_queries, p=weights / weights.sum())]

    # oracle masks per distinct plan: the conformance bar and the warmup
    # for everything shared (corpus lexsort, support, counts, jit caches)
    oracle = {p.ops: p.resolve(threshold).apply(base).keep_mask()
              for p in pool}
    warm = plan().screen().min_duration(100_000)       # not in the pool
    assert server.query(warm).n_kept == 0              # warms the kernel

    # --- sequential: one fresh frame chain per query, lock-serialized ------
    eval_lock = threading.Lock()

    def seq_work(p):
        t0 = time.perf_counter()
        with eval_lock:
            p.resolve(threshold).apply(base).keep_mask()
        return time.perf_counter() - t0

    seq_work.stream = stream
    seq_lats, seq_wall = _drive(n_clients, seq_work)

    # --- batched: the QueryServer wave loop --------------------------------
    server.start()

    def bat_work(p):
        t0 = time.perf_counter()
        r = server.submit(p).result(timeout=120)
        dt = time.perf_counter() - t0
        bat_work.masks.append((p, r.keep))   # list.append is thread-safe
        return dt

    bat_work.masks = []
    bat_work.stream = stream
    bat_lats, bat_wall = _drive(n_clients, bat_work)
    server.stop()

    for p, keep in bat_work.masks:
        assert keep.tobytes() == oracle[p.ops].tobytes(), \
            f"batched mask diverged for {p}"

    st = server.stats()
    seq_p50, seq_p99 = _percentile(seq_lats, .50), _percentile(seq_lats, .99)
    bat_p50, bat_p99 = _percentile(bat_lats, .50), _percentile(bat_lats, .99)
    return {
        "patients": n_patients, "corpus_rows": view.n_rows,
        "n_queries": n_queries, "n_clients": n_clients,
        "n_distinct_plans": n_distinct, "batch_size": batch_size,
        "threshold": threshold, "backend": backend, "seed": seed,
        "exact": True,
        "sequential_p50_ms": seq_p50 * 1e3, "sequential_p99_ms": seq_p99 * 1e3,
        "sequential_wall_s": seq_wall,
        "batched_p50_ms": bat_p50 * 1e3, "batched_p99_ms": bat_p99 * 1e3,
        "batched_wall_s": bat_wall,
        "p50_speedup": seq_p50 / max(bat_p50, 1e-9),
        "p99_speedup": seq_p99 / max(bat_p99, 1e-9),
        "min_p99_speedup": 2.0,
        "waves": st["waves"], "cache_hit_ratio": st["cache_hit_ratio"],
        "views_published": st["views_published"],
    }


def main(small=True, json_path=None, backend="jnp"):
    kw = dict() if small else dict(n_patients=256, avg_events=24,
                                   n_queries=4096, n_clients=64)
    r = serving_latency(backend=backend, **kw)
    print("name,us_per_call,derived")
    print(f"serving_latency/sequential_p99,{r['sequential_p99_ms']*1e3:.0f},"
          f"p50={r['sequential_p50_ms']:.2f}ms (lock-serialized frame eval)")
    print(f"serving_latency/batched_p99,{r['batched_p99_ms']*1e3:.0f},"
          f"p50={r['batched_p50_ms']:.2f}ms over {r['waves']} waves; "
          f"hit_ratio={r['cache_hit_ratio']:.2f}")
    print(f"serving_latency/p99_speedup,,"
          f"{r['p99_speedup']:.2f}x at {r['n_clients']} clients "
          f"(>= {r['min_p99_speedup']:.0f}x required); exact=True")
    assert r["p99_speedup"] >= r["min_p99_speedup"], \
        (f"batched p99 speedup {r['p99_speedup']:.2f}x below the "
         f"{r['min_p99_speedup']:.0f}x acceptance bar")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"serving_latency/artifact,,{json_path}")
    return r


if __name__ == "__main__":
    main()

"""Paper Table 2 — performance benchmark: tSPM+ scaling (Synthea-style).

Scaling sweep over cohort size, in-memory vs file-based, with/without
screening; reports sequences/second (the paper's 35k-patient cohort mines
~7.2e9 sequences; CPU-scale here, --full approaches paper scale).
Also the end-user-device observation: this container is a 1-core machine,
matching the paper's "runs on laptops" claim directly.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import chunking, mining, sparsity
from repro.data import synthea
from repro.data.dbmart import from_rows


def cohort(n, avg, seed=1):
    pid, date, xid, _ = synthea.generate_benchmark_rows(n, avg, seed)
    return from_rows(pid.tolist(), date.tolist(),
                     [f"c{v}" for v in xid.tolist()])


def one_scale(n_patients, avg_events, threshold=4, budget=64 << 20,
              spill_dir="/tmp/tspm_perf"):
    db = cohort(n_patients, avg_events)
    n_seq = int(mining.count_sequences(db.nevents))
    out = {"patients": n_patients, "avg_events": avg_events,
           "sequences": n_seq}

    t0 = time.perf_counter()
    res = chunking.mine_chunked(db, budget_bytes=budget)
    out["mem_noscreen_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = chunking.mine_chunked(db, budget_bytes=budget, threshold=threshold)
    out["mem_screen_s"] = time.perf_counter() - t0
    out["kept"] = int(res["keep"].sum())

    t0 = time.perf_counter()
    chunking.mine_to_files(db, spill_dir, budget_bytes=budget)
    out["file_noscreen_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    total = sum(len(p["seq"]) for p in
                chunking.screen_files(spill_dir, threshold))
    out["file_screen_s"] = out["file_noscreen_s"] + time.perf_counter() - t0
    assert total == out["kept"]
    out["seq_per_s"] = n_seq / out["mem_noscreen_s"]
    return out


def main(full=False):
    scales = [(500, 60), (1000, 60), (2000, 60)]
    if full:
        scales += [(5000, 120), (35_000, 60)]
    print(f"# paper Table 2 analogue — {os.cpu_count()}-core host "
          "(end-user-device scale)")
    print("name,us_per_call,derived")
    rows = []
    for n, avg in scales:
        r = one_scale(n, avg)
        rows.append(r)
        for k in ("mem_noscreen_s", "mem_screen_s", "file_noscreen_s",
                  "file_screen_s"):
            print(f"performance/{k}_p{n},{r[k]*1e6:.0f},"
                  f"seqs={r['sequences']};kept={r.get('kept','-')}")
        print(f"performance/throughput_p{n},,seq_per_s={r['seq_per_s']:.0f}")
    return rows


if __name__ == "__main__":
    main()

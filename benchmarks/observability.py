"""Telemetry overhead — instrumented vs bare streaming ingest.

The observability layer (repro.obs: metrics registry, span tracer,
device-timed busy windows) rides the per-tick hot path, so it carries an
acceptance bar: enabling telemetry must cost **< 3%** ingest wall time and
must not change a single mined byte.  This suite replays one cohort
through the stream engine twice per round — telemetry off, telemetry on —
interleaved best-of-N (same discipline as benchmarks/api_overhead), then
asserts both bars and reports what the instrumented run recorded.

Whole-run walls on a shared host jitter by +-10% and more — far above
the ~13 us/tick the instrumentation actually costs — so the measurement
leans on three noise controls: GC is disabled inside the timed region,
rounds are *paired* (each round times off then on back-to-back, so both
legs of a pair share the ambient load), and the reported figure is the
**median of the paired per-round ratios**.  Per-side best-of-N is the
wrong estimator here: the two minima sample independent luck, so one
fortunate off-round reads as several percent of phantom overhead (or
speedup) regardless of repeats; the paired median is immune to any
minority of contaminated rounds.

Prints ``name,us_per_call,derived`` CSV rows; ``main(json_path=...)``
writes BENCH_observability_overhead.json (gated in ci.yml).
"""
from __future__ import annotations

import gc
import json
import time

import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.data import dbmart, synthea
from repro.launch.stream import replay_waves

#: The acceptance ceiling: telemetry-on ingest may cost at most this
#: fraction over telemetry-off (ci.yml gates the stored artifact on it).
OVERHEAD_CEILING = 0.03


def _replay(db, config, n_waves, seed):
    session = MiningSession(config)
    gc.collect()
    gcold = gc.isenabled()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for _ in replay_waves(db, session, n_waves, seed):
            session.service.run()
        dt = time.perf_counter() - t0
    finally:
        if gcold:
            gc.enable()
    return session, dt


def observability_overhead(n_patients=120, avg_events=16, n_waves=4,
                           tick_patients=16, repeats=12, seed=11,
                           backend="jnp"):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    base = MiningConfig(engine="stream", tick_patients=tick_patients,
                        backend=backend, n_buckets_log2=18, screen="hash")

    # warm the jit caches once so neither side pays first-compile; the
    # slab shapes repeat across replays, so rounds after this are warm
    _replay(db, base, n_waves, seed)
    _replay(db, base.replace(telemetry=True), n_waves, seed)

    times = {"off": [], "on": []}
    sessions = {}
    pair = (("off", base), ("on", base.replace(telemetry=True)))
    for r in range(repeats):
        # alternate within-pair order: whichever leg runs first in a pair
        # absorbs any cache-cooling cost, so a fixed order would bias the
        # paired ratio one way
        for tag, cfg in (pair if r % 2 == 0 else pair[::-1]):
            sessions[tag], dt = _replay(db, cfg, n_waves, seed)
            times[tag].append(dt)
    ratios = [on / max(off, 1e-12) - 1.0
              for off, on in zip(times["off"], times["on"])]
    overhead = float(np.median(ratios))
    off_s = float(np.min(times["off"]))
    on_s = float(np.min(times["on"]))

    # exactness: telemetry must never change mined bytes
    f_off = sessions["off"].frame()
    f_on = sessions["on"].frame()
    for a, b in zip(f_off.arrays(), f_on.arrays()):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "telemetry changed mined results"
    assert overhead < OVERHEAD_CEILING, \
        f"telemetry overhead {overhead * 100:.2f}% exceeds the " \
        f"{OVERHEAD_CEILING * 100:.0f}% ceiling"

    snap = sessions["on"].metrics()
    tick_summary = snap.get("stream.tick.dispatch_s", {})
    return {
        "patients": n_patients, "avg_events": avg_events, "waves": n_waves,
        "backend": backend, "repeats": repeats,
        "off_s": off_s, "on_s": on_s,
        "overhead_frac": overhead,
        "overhead_ceiling": OVERHEAD_CEILING,
        "ticks": snap.get("stream.ticks", 0),
        "trace_events":
            len(sessions["on"].trace().to_chrome_trace()["traceEvents"]),
        "tick_dispatch_summary": tick_summary,
        "telemetry": snap,
    }


def main(small=True, json_path=None, backend="jnp"):
    kw = dict() if small else dict(n_patients=400, avg_events=32, n_waves=6,
                                   repeats=15)
    r = observability_overhead(backend=backend, **kw)
    print("name,us_per_call,derived")
    print(f"observability/ingest_off,{r['off_s']*1e6:.0f},"
          f"ticks={r['ticks']}")
    print(f"observability/ingest_on,{r['on_s']*1e6:.0f},"
          f"overhead={r['overhead_frac']*100:+.2f}% "
          f"(ceiling {r['overhead_ceiling']*100:.0f}%)")
    print(f"observability/trace,,events={r['trace_events']};"
          f"metric_keys={len(r['telemetry'])};exactness_ok=1")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"observability/artifact,,{json_path}")
    return r


if __name__ == "__main__":
    main()

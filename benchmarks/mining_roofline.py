"""Mining-kernel roofline: arithmetic intensity + projected TPU throughput.

The pairgen kernel writes 17 bytes/pair (two int32 planes + int32 duration
+ bool mask) and performs ~6 integer VPU ops/pair — arithmetic intensity
~0.35 ops/byte, i.e. the mining pass is PURELY HBM-bandwidth-bound on TPU.
Projection: 819 GB/s / 17 B/pair ≈ 48 G pairs/s/chip — the measured CPU
number here is the correctness-validated baseline, the projection is what
the dry-run-tiled kernel targets.

The cost-model constants live in ``repro.analysis.roofline`` (single
source of truth — the fused-screen tile planner derives its block sizes
from the same numbers); the module-level aliases here are kept for
compat.  Beyond the classic materializing roofline this also prints the
fused memory model: bytes for the full [P, n, n] pair corpus vs the
corpus-free screen pass's peak (one patient block + the bucket table),
and the ``mining_tile_plan`` those constants choose.
"""
from __future__ import annotations

import time

import numpy as np

from repro.analysis import roofline
from repro.analysis.roofline import (
    FUSED_BLOCK_BYTES_PER_PAIR,
    MINING_BYTES_PER_PAIR as BYTES_PER_PAIR,
    MINING_OPS_PER_PAIR as OPS_PER_PAIR,
)
from repro.core import mining
from repro.data import synthea
from repro.data.dbmart import from_rows

HBM_BW = 819e9
PEAK_VPU = 197e12 / 2  # int ops conservatively at half bf16 MXU peak


def main():
    pid, date, xid, _ = synthea.generate_benchmark_rows(512, 96, seed=3)
    db = from_rows(pid.tolist(), date.tolist(),
                   [f"c{v}" for v in xid.tolist()])
    n_pairs = int(mining.count_sequences(db.nevents))

    # measured (CPU, jnp reference path)
    mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
    mined.seq.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
        mined.seq.block_until_ready()
    dt = (time.perf_counter() - t0) / 3

    intensity = OPS_PER_PAIR / BYTES_PER_PAIR
    tpu_bound = min(HBM_BW / BYTES_PER_PAIR, PEAK_VPU / OPS_PER_PAIR)
    print("name,us_per_call,derived")
    print(f"mining_roofline/cpu_measured,{dt*1e6:.0f},"
          f"pairs_per_s={n_pairs/dt:.2e}")
    print(f"mining_roofline/arithmetic_intensity,,ops_per_byte="
          f"{intensity:.3f}")
    print(f"mining_roofline/tpu_projection,,pairs_per_s={tpu_bound:.2e};"
          f"bound=memory")

    # fused memory model: the corpus the materializing path holds vs the
    # peak of the corpus-free screen pass on the same cohort
    E = int(np.max(db.nevents))
    plan = roofline.mining_tile_plan(E, 20)
    corpus = int(np.sum(np.asarray(db.nevents, np.int64) ** 2)) \
        * FUSED_BLOCK_BYTES_PER_PAIR
    fused_peak = plan.block_patients * E * E * FUSED_BLOCK_BYTES_PER_PAIR \
        + (4 << 20)
    print(f"mining_roofline/fused_memory_model,,corpus={corpus};"
          f"fused_peak={fused_peak};ratio={corpus/max(fused_peak,1):.1f}x")
    print(f"mining_roofline/fused_tile_plan,,pb={plan.pb};ti={plan.ti};"
          f"tj={plan.tj};bt={plan.bt};block={plan.block_patients};"
          f"vmem={plan.vmem_bytes};source={plan.source}")
    return {"pairs_per_s_cpu": n_pairs / dt, "tpu_bound": tpu_bound,
            "corpus_bytes": corpus, "fused_peak_bytes": fused_peak}


if __name__ == "__main__":
    main()

"""Mining-kernel roofline: arithmetic intensity + projected TPU throughput.

The pairgen kernel writes 17 bytes/pair (two int32 planes + int32 duration
+ bool mask) and performs ~6 integer VPU ops/pair — arithmetic intensity
~0.35 ops/byte, i.e. the mining pass is PURELY HBM-bandwidth-bound on TPU.
Projection: 819 GB/s / 17 B/pair ≈ 48 G pairs/s/chip — the measured CPU
number here is the correctness-validated baseline, the projection is what
the dry-run-tiled kernel targets.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import mining
from repro.data import synthea
from repro.data.dbmart import from_rows

BYTES_PER_PAIR = 17  # 4 (start) + 4 (end) + 4 (dur) + 1 (mask) + 4 amortized
OPS_PER_PAIR = 6     # shift/or pack, sub, 3 compares for the mask
HBM_BW = 819e9
PEAK_VPU = 197e12 / 2  # int ops conservatively at half bf16 MXU peak


def main():
    pid, date, xid, _ = synthea.generate_benchmark_rows(512, 96, seed=3)
    db = from_rows(pid.tolist(), date.tolist(),
                   [f"c{v}" for v in xid.tolist()])
    n_pairs = int(mining.count_sequences(db.nevents))

    # measured (CPU, jnp reference path)
    mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
    mined.seq.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
        mined.seq.block_until_ready()
    dt = (time.perf_counter() - t0) / 3

    intensity = OPS_PER_PAIR / BYTES_PER_PAIR
    tpu_bound = min(HBM_BW / BYTES_PER_PAIR, PEAK_VPU / OPS_PER_PAIR)
    print("name,us_per_call,derived")
    print(f"mining_roofline/cpu_measured,{dt*1e6:.0f},"
          f"pairs_per_s={n_pairs/dt:.2e}")
    print(f"mining_roofline/arithmetic_intensity,,ops_per_byte="
          f"{intensity:.3f}")
    print(f"mining_roofline/tpu_projection,,pairs_per_s={tpu_bound:.2e};"
          f"bound=memory")
    return {"pairs_per_s_cpu": n_pairs / dt, "tpu_bound": tpu_bound}


if __name__ == "__main__":
    main()

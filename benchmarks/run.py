"""Benchmark harness — one section per paper table/figure.

  comparison      -> paper Table 1 (original tSPM vs tSPM+, x-factor)
  performance     -> paper Table 2 (scaling, in-memory vs file-based)
  mining_roofline -> kernel arithmetic intensity + TPU projection
  postcovid       -> vignette-2 quality (the paper's use-case claim)
  roofline        -> LM-cell roofline table (reads experiments/dryrun/*.json
                     if the dry-run sweep has been run)
  streaming       -> incremental delta-mining ingest vs full re-mine
                     (``--suite streaming`` runs it alone in CPU-interpret
                     mode and writes a BENCH_streaming.json trajectory)
  streaming_sharded -> mesh-sharded streaming service: shards-vs-single
                     tick throughput + merged-screen (psum) cost
                     (``--suite streaming_sharded`` writes
                     BENCH_streaming_sharded.json)
  streaming_rebalance -> live shard rebalancing on a skewed workload:
                     sticky routing vs load-triggered patient migration
                     (``--suite streaming_rebalance`` writes
                     BENCH_streaming_rebalance.json)
  streaming_placement -> device-pinned shards vs host-serial ticks on
                     forced host devices (sets XLA_FLAGS before jax
                     loads; ``--suite streaming_placement`` writes
                     BENCH_streaming_placement.json, exactness asserted
                     against the batch oracle)
  api_overhead    -> unified session façade (repro.api) vs hand-wired
                     mine->flatten->screen; batch-path dispatch overhead
                     must stay < 5% (``--suite api_overhead`` writes
                     BENCH_api_overhead.json)
  observability_overhead -> telemetry-instrumented vs bare streaming
                     ingest; enabling the metrics registry + span tracer
                     must cost < 3% and change zero mined bytes
                     (``--suite observability_overhead`` writes
                     BENCH_observability_overhead.json)
  mining_fused    -> corpus-free fused screen (screen="fused") vs the
                     materializing mine+screen path: collect bytes
                     asserted identical, peak working set asserted below
                     the dense corpus under the BYTES_PER_PAIR model
                     (and P-invariant), wall within a bounded multiple,
                     plus the autotune sweep feeding
                     analysis.roofline.mining_tile_plan
                     (``--suite mining_fused`` writes
                     BENCH_mining_fused.json)
  storage_tiering -> compressed disk tier: codec compression ratio
                     (asserted >= 3x on the synthea shape), tiered
                     ingest with disk demotion on the eviction path,
                     and checkpoint save/restore timing with the
                     restored bytes asserted identical
                     (``--suite storage_tiering`` writes
                     BENCH_storage_tiering.json)
  serving_latency -> batched QueryServer vs lock-serialized per-query
                     frame evaluation at >= 32 concurrent clients:
                     masks asserted byte-identical, p99 speedup
                     asserted >= 2x (``--suite serving_latency`` writes
                     BENCH_serving_latency.json)
  journal_overhead -> hash-chained tick journal on/off ingest: mined
                     bytes asserted identical, the journal verified and
                     replayed byte-exactly, overhead gated < 5%
                     (``--suite journal_overhead`` writes
                     BENCH_journal_overhead.json)

An unknown ``--suite`` prints the available suites instead of failing
opaquely.  Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time


def _section(title):
    print(f"\n## {title}", flush=True)


def postcovid_bench():
    import numpy as np

    from repro.core import mining, postcovid
    from repro.data import dbmart, synthea

    pats, dates, phx, truth = synthea.generate_cohort(
        n_patients=300, avg_events=40, seed=17)
    db = dbmart.from_rows(pats, dates, phx)
    t0 = time.perf_counter()
    mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
    seq, dur, pat, msk = mining.flatten(mined)
    cfg = postcovid.PostCovidConfig(
        covid_id=db.vocab.phenx_index[synthea.COVID])
    pcc, _ = postcovid.identify(seq, dur, pat, msk, db.phenx, db.nevents,
                                cfg, db.n_patients, db.vocab.n_phenx)
    dt = time.perf_counter() - t0
    pcc = np.asarray(pcc)
    pred = postcovid.decode_symptoms(pcc, db.vocab)
    tp = fp = fn = 0
    for p in range(db.n_patients):
        t, pr = truth.symptom_sets[p], pred[p]
        tp += len(t & pr)
        fp += len(pr - t)
        fn += len(t - pr)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    acc = (pcc.any(1) == truth.long_covid).mean()
    print("name,us_per_call,derived")
    print(f"postcovid/pipeline,{dt*1e6:.0f},f1={f1:.3f};patient_acc={acc:.3f}")


def roofline_bench():
    print("name,us_per_call,derived")
    files = sorted(glob.glob("experiments/dryrun/*pod16x16.json"))
    if not files:
        print("roofline/missing,,run `python -m repro.launch.dryrun --all`")
        return
    for f in files:
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            tag = rec.get("status", "?")
            print(f"roofline/{rec['arch']}__{rec['shape']},,{tag}")
            continue
        r = rec["roofline"]
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(f"roofline/{rec['arch']}__{rec['shape']},{bound*1e6:.0f},"
              f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f}")


def streaming_bench(small=True, out_path=None):
    from benchmarks import streaming

    out_path = out_path or "BENCH_streaming.json"
    # kernel backend in interpret mode: exercises the Pallas delta kernel
    # end-to-end on CPU, same as the tier-1 kernel tests
    streaming.main(small=small, json_path=out_path, backend="kernel")


def streaming_sharded_bench(small=True, out_path=None):
    from benchmarks import streaming

    out_path = out_path or "BENCH_streaming_sharded.json"
    streaming.main_sharded(small=small, json_path=out_path, backend="jnp")


def streaming_rebalance_bench(small=True, out_path=None):
    from benchmarks import streaming

    out_path = out_path or "BENCH_streaming_rebalance.json"
    streaming.main_rebalance(small=small, json_path=out_path, backend="jnp")


def _force_host_devices(n: int) -> None:
    """Give the CPU backend ``n`` devices — must happen before jax loads
    (XLA reads the flag at backend init).  A no-op when the process
    already sees >= 2 devices; fails fast when jax is already up with a
    single device (the flag would silently not apply)."""
    if "jax" in sys.modules:
        import jax

        if len(jax.devices()) >= 2:
            return
        raise SystemExit(
            "jax is already initialized with a single device; run "
            "--suite streaming_placement in a fresh process")
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + f" --xla_force_host_platform_device_count={n}").strip()


def streaming_placement_bench(small=True, out_path=None):
    _force_host_devices(2 if small else 4)
    from benchmarks import streaming

    out_path = out_path or "BENCH_streaming_placement.json"
    streaming.main_placement(small=small, json_path=out_path, backend="jnp")


def api_overhead_bench(small=True, out_path=None):
    from benchmarks import api_overhead

    out_path = out_path or "BENCH_api_overhead.json"
    api_overhead.main(small=small, json_path=out_path, backend="jnp")


def observability_overhead_bench(small=True, out_path=None):
    from benchmarks import observability

    out_path = out_path or "BENCH_observability_overhead.json"
    observability.main(small=small, json_path=out_path, backend="jnp")


def mining_fused_bench(small=True, out_path=None):
    from benchmarks import mining_fused

    out_path = out_path or "BENCH_mining_fused.json"
    mining_fused.main(small=small, json_path=out_path, backend="jnp")


def serving_latency_bench(small=True, out_path=None):
    from benchmarks import serving_latency

    out_path = out_path or "BENCH_serving_latency.json"
    serving_latency.main(small=small, json_path=out_path, backend="jnp")


def journal_overhead_bench(small=True, out_path=None):
    from benchmarks import journal_overhead

    out_path = out_path or "BENCH_journal_overhead.json"
    journal_overhead.main(small=small, json_path=out_path, backend="kernel")


def storage_tiering_bench(small=True, out_path=None):
    from benchmarks import storage_tiering

    out_path = out_path or "BENCH_storage_tiering.json"
    storage_tiering.main(small=small, json_path=out_path, backend="jnp")


SUITES = {
    "streaming": ("streaming ingest (delta vs re-mine)", streaming_bench),
    "streaming_sharded": ("mesh-sharded streaming (shards vs single)",
                          streaming_sharded_bench),
    "streaming_rebalance": ("live shard rebalancing (sticky vs migrated)",
                            streaming_rebalance_bench),
    "streaming_placement": ("device-pinned shards vs host-serial ticks",
                            streaming_placement_bench),
    "api_overhead": ("session façade vs hand-wired batch path",
                     api_overhead_bench),
    "observability_overhead": ("telemetry on/off ingest (< 3% ceiling)",
                               observability_overhead_bench),
    "mining_fused": ("corpus-free fused screen vs materializing path",
                     mining_fused_bench),
    "storage_tiering": ("compressed disk tier + checkpoint/resume "
                        "(>= 3x ratio asserted)", storage_tiering_bench),
    "serving_latency": ("batched query serving vs per-query eval "
                        "(>= 2x p99 at 32 clients asserted)",
                        serving_latency_bench),
    "journal_overhead": ("hash-chained tick journal on/off ingest "
                         "(< 5% ceiling, replay asserted exact)",
                         journal_overhead_bench),
}


def main() -> None:
    small = "--full" not in sys.argv
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    if "--suite" in sys.argv:
        i = sys.argv.index("--suite") + 1
        suite = sys.argv[i] if i < len(sys.argv) else None
        if suite not in SUITES:
            listing = "\n".join(f"  {name:22s} {title}"
                                for name, (title, _) in SUITES.items())
            raise SystemExit(
                f"unknown --suite {suite!r}; available suites:\n{listing}")
        title, bench = SUITES[suite]
        _section(title)
        bench(small=small)
        return

    _section("comparison (paper Table 1)")
    from benchmarks import comparison

    comparison.main(small=small)

    _section("performance (paper Table 2)")
    from benchmarks import performance

    performance.main(full=not small)

    _section("mining roofline")
    from benchmarks import mining_roofline

    mining_roofline.main()

    _section("postcovid (vignette 2)")
    postcovid_bench()

    _section("LM-cell roofline (from dry-run)")
    roofline_bench()


if __name__ == "__main__":
    main()

"""Streaming ingest benchmark — delta mining vs full re-mine.

Replays a synthetic cohort in waves through repro.stream and reports:

  * ingest throughput (events/s) and per-tick latency;
  * pairs touched per wave by the delta path (Delta * n) vs what a batch
    re-mine of every resident history would cost (n^2) — the paper's
    n(n-1)/2 count applied to both schedules;
  * wall-clock for one full batch re-mine at the end, as the baseline a
    non-incremental system pays on *every* refresh.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmark
sections; ``main(json_path=...)`` also writes the per-wave trajectory
(used by ``benchmarks/run.py --suite streaming``).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.core import mining
from repro.data import dbmart, synthea
from repro.launch.mesh import make_data_mesh
from repro.launch.stream import replay_waves
from repro.stream.shard import ShardRouter


def one_cohort(n_patients=300, avg_events=32, n_waves=8, tick_patients=16,
               seed=3, backend="jnp"):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    # façade-configured session; the benchmark reads the engine's internals
    # (store residency, per-tick stats) through session.service
    session = MiningSession(MiningConfig(
        tick_patients=tick_patients, backend=backend, n_buckets_log2=18,
        screen="hash", telemetry=True))

    waves = []
    for w in replay_waves(db, session, n_waves, seed):
        svc = session.service
        k0 = len(svc.stats)
        t0 = time.perf_counter()
        svc.run()
        dt = time.perf_counter() - t0
        ticks = svc.stats[k0:]
        # what a batch system would re-mine this wave: all pairs of every
        # patient's *current* history (n^2 schedule)
        nev = np.asarray(svc.store.nevents)
        resident = np.asarray(sorted(svc.store.rows.values()), np.int64)
        full = int(mining.count_sequences(nev[resident])) + int(sum(
            n * (n - 1) // 2
            for k, n in svc.store.event_counts().items()
            if k not in svc.store.rows))
        delta_pairs = int(sum(t.n_pairs for t in ticks))
        waves.append({
            "wave": w, "wall_s": dt,
            "events": int(sum(t.n_events for t in ticks)),
            "ticks": len(ticks),
            "delta_pairs": delta_pairs,
            "remine_pairs": full,
            "tick_latency_s": dt / max(len(ticks), 1),
        })

    # baseline: one full batch re-mine of the final dbmart, same backend as
    # ingest so the wall-clock comparison is apples-to-apples
    t0 = time.perf_counter()
    mined = mining.mine(db.phenx, db.date, db.nevents, backend=backend)
    np.asarray(mined.mask).sum()
    remine_s = time.perf_counter() - t0

    # exactness: the streamed corpus is the batch mine, pair for pair
    svc = session.service
    assert len(svc.snapshot().seq) == int(np.asarray(mined.mask).sum()), \
        "streamed corpus size != batch re-mine"

    total_events = sum(w["events"] for w in waves)
    total_s = sum(w["wall_s"] for w in waves)
    return {
        "patients": n_patients, "avg_events": avg_events, "waves": waves,
        "events_per_s": total_events / max(total_s, 1e-9),
        "ingest_s": total_s, "full_remine_s": remine_s,
        "delta_pairs_total": sum(w["delta_pairs"] for w in waves),
        "remine_pairs_final": int(mining.count_sequences(db.nevents)),
        "telemetry": session.metrics(),
    }


def sharded_cohort(n_patients=120, avg_events=24, n_waves=6,
                   tick_patients=16, seed=3, backend="jnp",
                   shard_counts=(1, 2, 4), threshold=3):
    """Same cohort replayed at several shard counts (LPT-pinned router,
    ('data',) mesh for the psum table merge).

    Shards run host-serial here, so per-row throughput has two readings:
    ``events_per_s`` (serial wall) and ``events_per_s_projected`` (wall =
    the busiest shard's tick time, what a 1-shard-per-device mesh pays —
    the collective adds one psum, measured as ``screen_s``).
    """
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    mesh = make_data_mesh()
    rows = []
    metrics = {}
    for n_shards in shard_counts:
        router = ShardRouter.balanced(
            list(range(db.n_patients)), np.asarray(db.nevents), n_shards)
        # engine='sharded' override: the n_shards=1 row must still go
        # through the sharded service (merged-table screen) for the sweep
        session = MiningSession(MiningConfig(
            engine="sharded", n_shards=n_shards, tick_patients=tick_patients,
            backend=backend, n_buckets_log2=18, screen="hash",
            telemetry=True), mesh=mesh, router=router)
        t0 = time.perf_counter()
        for _ in replay_waves(db, session, n_waves, seed):
            session.service.run()
        svc = session.service
        ingest_s = time.perf_counter() - t0
        # busy = dispatch + device + collect, the non-double-counting
        # decomposition of a tick (wall_s spans begin->finish and would
        # overstate busy under overlapped dispatch)
        per_shard_s = [sum(t.dispatch_s + t.device_s + t.collect_s
                           for t in s.stats) for s in svc.shards]
        events = sum(t.n_events for t in svc.stats)
        metrics[f"shards{n_shards}"] = session.metrics()

        t0 = time.perf_counter()
        keep = svc.screened_keep(threshold)   # merged table + global mask
        screen_s = time.perf_counter() - t0
        rows.append({
            "n_shards": n_shards,
            "ingest_s": ingest_s,
            "ticks": len(svc.stats),
            "events": events,
            "events_per_s": events / max(ingest_s, 1e-9),
            "per_shard_busy_s": per_shard_s,
            "projected_parallel_s": max(per_shard_s) if per_shard_s else 0.0,
            "events_per_s_projected":
                events / max(max(per_shard_s, default=0.0), 1e-9),
            "screen_s": screen_s,
            "kept": int(keep.sum()),
            "corpus": int(len(svc.snapshot().seq)),
        })
    single = next((r for r in rows if r["n_shards"] == 1), rows[0])
    # exactness: the shard count must not change what is mined or kept
    assert all(r["corpus"] == single["corpus"] and r["kept"] == single["kept"]
               for r in rows), "shard count changed results"
    return {
        "patients": n_patients, "avg_events": avg_events, "waves": n_waves,
        "threshold": threshold, "mesh_devices": mesh.devices.size,
        "shards": rows,
        "baseline_shards": single["n_shards"],
        "projected_speedup_vs_single": [
            single["projected_parallel_s"] / max(r["projected_parallel_s"],
                                                 1e-9) for r in rows],
        "telemetry": metrics,
    }


def placement_cohort(n_patients=120, avg_events=24, n_waves=6,
                     tick_patients=16, seed=3, backend="jnp", n_shards=2,
                     threshold=3, n_buckets_log2=18):
    """Device-pinned vs host-serial sharded ticks, exactness asserted.

    Both runs replay the same cohort through the sharded engine; the only
    difference is ``placement``: ``'host'`` ticks shards one after another
    on the default device, ``'devices'`` pins each shard's store planes
    and sketch table to its own device and dispatches every shard's wave
    before collecting any — the serial ingest wall is then the *measured*
    overlap win (not a projection).  Requires >= 2 visible devices
    (``benchmarks/run.py --suite streaming_placement`` forces host
    devices); exactness is asserted three ways — device path == host path
    == one batch mine+screen of the final cohort, corpus and counts
    byte-identical."""
    import jax

    if len(jax.devices()) < 2:
        raise SystemExit(
            "streaming_placement needs >= 2 devices; run it through "
            "benchmarks/run.py --suite streaming_placement, which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count before jax "
            "loads")
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    mesh = make_data_mesh()

    # batch oracle: one mine + bucket count of the final cohort
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    from repro.core import sparsity
    cnt = np.asarray(sparsity.local_bucket_counts(
        np.asarray(mined.seq), np.asarray(mined.mask), n_buckets_log2))
    oracle = sorted(zip(pat[msk], seq[msk], dur[msk]))

    rows = {}
    metrics = {}
    for placement in ("host", "devices"):
        def one_replay():
            router = ShardRouter.balanced(
                list(range(db.n_patients)), np.asarray(db.nevents), n_shards)
            session = MiningSession(MiningConfig(
                engine="sharded", n_shards=n_shards, placement=placement,
                tick_patients=tick_patients, backend=backend,
                n_buckets_log2=n_buckets_log2, screen="hash",
                telemetry=True), mesh=mesh, router=router)
            t0 = time.perf_counter()
            for _ in replay_waves(db, session, n_waves, seed):
                session.service.run()
            return session, session.service, time.perf_counter() - t0

        # warmup replay compiles every slab shape for this placement's
        # devices (the jit cache persists across sessions), so the timed
        # replay measures tick dispatch + mining, not XLA compilation —
        # at toy scale a cold run is retrace-dominated on every path
        one_replay()
        session, svc, ingest_s = one_replay()
        events = sum(t.n_events for t in svc.stats)
        metrics[placement] = session.metrics()

        snap = svc.snapshot()
        p2k = svc.pid_to_key()
        keys = np.asarray([p2k[int(p)] for p in snap.patient]
                          if len(snap.patient) else [], np.int64)
        assert sorted(zip(keys, snap.seq, snap.dur)) == oracle, \
            f"{placement} placement corpus != batch oracle"
        assert (snap.counts == cnt).all(), \
            f"{placement} placement counts != batch bucket counts"
        rows[placement] = {
            "placement": placement,
            "ingest_s": ingest_s,
            "ticks": len(svc.stats),
            "events": events,
            "events_per_s": events / max(ingest_s, 1e-9),
            # per-tick walls span tick_begin -> tick_finish; under
            # 'devices' every shard is dispatched before any is
            # collected, so these windows overlap and their sum
            # overstates busy time — kept only to show the overlap
            # (summed walls > elapsed).  The corrected decomposition is
            # the dispatch/device/collect split: host dispatch and
            # collect are serial (their sums never double-count) and
            # device_s is completion-timed device busy per shard — the
            # same signal shard_load() polls
            "per_shard_tick_wall_s": [sum(t.wall_s for t in s.stats)
                                      for s in svc.shards],
            "tick_walls_overlap": placement == "devices",
            "per_shard_dispatch_s": [sum(t.dispatch_s for t in s.stats)
                                     for s in svc.shards],
            "per_shard_collect_s": [sum(t.collect_s for t in s.stats)
                                    for s in svc.shards],
            "per_shard_device_s": [sum(t.device_s for t in s.stats)
                                   for s in svc.shards],
            "shard_busy_frac": svc.shard_load(),
            "shard_devices": [str(d) for d in svc.devices],
            "kept": int(svc.screened_keep(threshold).sum()),
            "corpus": int(len(snap.seq)),
        }
    assert rows["host"]["corpus"] == rows["devices"]["corpus"] \
        and rows["host"]["kept"] == rows["devices"]["kept"], \
        "placement changed results"
    return {
        "patients": n_patients, "avg_events": avg_events, "waves": n_waves,
        "n_shards": n_shards, "threshold": threshold,
        "n_devices": len(jax.devices()), "mesh_devices": mesh.devices.size,
        "host": rows["host"], "devices": rows["devices"],
        "exactness": "device == host == batch oracle (corpus + counts)",
        "speedup_devices_vs_host": rows["host"]["ingest_s"]
        / max(rows["devices"]["ingest_s"], 1e-9),
        "telemetry": metrics,
    }


def main_placement(small=True, json_path=None, backend="jnp"):
    kw = (dict(n_patients=120, avg_events=24, n_waves=6, n_shards=2)
          if small else
          dict(n_patients=400, avg_events=40, n_waves=8, n_shards=4))
    r = placement_cohort(backend=backend, **kw)
    print("name,us_per_call,derived")
    for tag in ("host", "devices"):
        row = r[tag]
        print(f"streaming_placement/{tag},{row['ingest_s']*1e6:.0f},"
              f"events_per_s={row['events_per_s']:.0f};"
              f"ticks={row['ticks']};kept={row['kept']}")
    print(f"streaming_placement/speedup,,devices_vs_host="
          f"{r['speedup_devices_vs_host']:.2f}x;"
          f"n_devices={r['n_devices']};exactness_ok=1")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"streaming_placement/artifact,,{json_path}")
    return r


def _skewed_rows(n_light, n_heavy, light_events, heavy_events, seed,
                 n_codes=400):
    """Numeric rows for a skewed cohort: a few long-trajectory patients
    (ids 0..n_heavy-1, e.g. the paper's Post COVID-19 care pathways) over
    a light-tailed background."""
    rng = np.random.default_rng(seed)
    counts = np.concatenate([
        np.maximum(rng.poisson(heavy_events, n_heavy), 2),
        np.maximum(rng.poisson(light_events, n_light), 2)])
    pid = np.repeat(np.arange(len(counts), dtype=np.int32), counts)
    total = int(counts.sum())
    date = rng.integers(0, 2000, total, dtype=np.int32)
    xid = rng.integers(0, n_codes, total, dtype=np.int32)
    return pid, date, xid


def rebalance_cohort(n_light=90, n_heavy=10, light_events=8,
                     heavy_events=64, n_waves=6, n_shards=4,
                     tick_patients=16, seed=3, backend="jnp", threshold=3,
                     rebalance_every=2, imbalance_threshold=1.2):
    """Skewed workload, sticky routing vs live rebalancing.

    The heavy patients are all pinned to shard 0 — the sticky-router worst
    case (whichever shard admitted the long trajectories stays hot, and
    pair cost is quadratic in events).  Both runs start from that router;
    the rebalanced one migrates patients off the hot shard every
    ``rebalance_every`` ticks.  Tick throughput is read projected-parallel
    (wall = busiest shard's busy time, the 1-shard-per-device deployment),
    same as the streaming_sharded suite; handoff cost is *not* hidden in
    that figure, so it is reported separately (``migration_wall_s``, host
    copies + shape-change retraces, paid once per move and amortized over
    the stream) and folded into ``events_per_s_projected_with_handoff``
    and the serial ``events_per_s``.
    """
    pid, date, xid = _skewed_rows(n_light, n_heavy, light_events,
                                  heavy_events, seed)
    db = dbmart.from_rows(pid, date, xid)

    def one_run(rebalance: bool) -> dict:
        router = ShardRouter(n_shards,
                             pinned={p: 0 for p in range(n_heavy)})
        session = MiningSession(MiningConfig(
            engine="sharded", n_shards=n_shards,
            rebalance_every=rebalance_every if rebalance else None,
            imbalance_threshold=imbalance_threshold,
            tick_patients=tick_patients, backend=backend, n_buckets_log2=18,
            screen="hash", telemetry=True), router=router)
        t0 = time.perf_counter()
        for _ in replay_waves(db, session, n_waves, seed):
            session.service.run()
        svc = session.service
        ingest_s = time.perf_counter() - t0
        # dispatch + device + collect: the non-overlapping tick split
        # (wall_s double-counts under overlapped dispatch)
        busy = [sum(t.dispatch_s + t.device_s + t.collect_s
                    for t in s.stats) for s in svc.shards]
        events = sum(t.n_events for t in svc.stats)
        parallel = max(busy, default=0.0)
        return {
            "telemetry": session.metrics(),
            "events": events,
            "ticks": len(svc.stats),
            "ingest_s": ingest_s,
            "per_shard_busy_s": busy,
            "projected_parallel_s": parallel,
            "events_per_s": events / max(ingest_s, 1e-9),
            "events_per_s_projected": events / max(parallel, 1e-9),
            "migration_wall_s": svc.migration_wall_s,
            "events_per_s_projected_with_handoff":
                events / max(parallel + svc.migration_wall_s, 1e-9),
            "migrations": len(svc.migrations),
            "shard_load_bytes": svc.shard_loads(),
            "corpus": int(len(svc.snapshot().seq)),
            "kept": int(svc.screened_keep(threshold).sum()),
        }

    sticky = one_run(rebalance=False)
    rebal = one_run(rebalance=True)
    # exactness smoke: migrations must not change what gets mined/kept
    assert rebal["corpus"] == sticky["corpus"] \
        and rebal["kept"] == sticky["kept"], "rebalancing changed results"
    return {
        "patients": n_light + n_heavy, "heavy_patients": n_heavy,
        "light_events": light_events, "heavy_events": heavy_events,
        "waves": n_waves, "n_shards": n_shards,
        "rebalance_every": rebalance_every,
        "imbalance_threshold": imbalance_threshold,
        "sticky": sticky, "rebalanced": rebal,
        "projected_speedup": sticky["projected_parallel_s"]
        / max(rebal["projected_parallel_s"], 1e-9),
        "projected_speedup_with_handoff":
            (sticky["projected_parallel_s"] + sticky["migration_wall_s"])
            / max(rebal["projected_parallel_s"]
                  + rebal["migration_wall_s"], 1e-9),
    }


def main_rebalance(small=True, json_path=None, backend="jnp"):
    kw = dict() if small else dict(n_light=360, n_heavy=40,
                                   heavy_events=128, n_waves=8)
    r = rebalance_cohort(backend=backend, **kw)
    print("name,us_per_call,derived")
    for tag in ("sticky", "rebalanced"):
        row = r[tag]
        print(f"streaming_rebalance/{tag},"
              f"{row['projected_parallel_s']*1e6:.0f},"
              f"events_per_s={row['events_per_s']:.0f};"
              f"projected={row['events_per_s_projected']:.0f};"
              f"projected_with_handoff="
              f"{row['events_per_s_projected_with_handoff']:.0f};"
              f"migration_wall_us={row['migration_wall_s']*1e6:.0f};"
              f"migrations={row['migrations']};kept={row['kept']}")
    print(f"streaming_rebalance/speedup,,projected="
          f"{r['projected_speedup']:.2f}x;with_handoff="
          f"{r['projected_speedup_with_handoff']:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"streaming_rebalance/artifact,,{json_path}")
    return r


def main_sharded(small=True, json_path=None, backend="jnp"):
    scale = (100, 20, 5) if small else (400, 40, 8)
    r = sharded_cohort(n_patients=scale[0], avg_events=scale[1],
                       n_waves=scale[2], backend=backend)
    print("name,us_per_call,derived")
    for row, speedup in zip(r["shards"], r["projected_speedup_vs_single"]):
        print(f"streaming_sharded/shards{row['n_shards']},"
              f"{row['projected_parallel_s']*1e6:.0f},"
              f"events_per_s={row['events_per_s']:.0f};"
              f"projected={row['events_per_s_projected']:.0f};"
              f"screen_us={row['screen_s']*1e6:.0f};"
              f"speedup_vs_single={speedup:.2f}x;kept={row['kept']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"streaming_sharded/artifact,,{json_path}")
    return r


def main(small=True, json_path=None, backend="jnp"):
    scale = (120, 24, 6) if small else (600, 48, 10)
    r = one_cohort(n_patients=scale[0], avg_events=scale[1],
                   n_waves=scale[2], backend=backend)
    print("name,us_per_call,derived")
    for w in r["waves"]:
        print(f"streaming/wave{w['wave']},{w['tick_latency_s']*1e6:.0f},"
              f"events={w['events']};delta_pairs={w['delta_pairs']};"
              f"remine_pairs={w['remine_pairs']}")
    print(f"streaming/ingest,{r['ingest_s']*1e6:.0f},"
          f"events_per_s={r['events_per_s']:.0f}")
    print(f"streaming/full_remine,{r['full_remine_s']*1e6:.0f},"
          f"pairs={r['remine_pairs_final']}")
    # the scaling headline: the delta schedule touches each pair once, a
    # per-wave batch refresh touches the n^2 set every wave
    touched_ratio = sum(w["remine_pairs"] for w in r["waves"]) \
        / max(r["delta_pairs_total"], 1)
    print(f"streaming/pairs_touched_ratio,,batch_over_delta="
          f"{touched_ratio:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"streaming/artifact,,{json_path}")
    return r


if __name__ == "__main__":
    main()

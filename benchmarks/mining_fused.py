"""Corpus-free fused screen vs the materializing mine+screen path.

Three claims, all asserted (not just reported):

  * **exactness** — ``screen="fused"`` collect bytes == the materializing
    batch mine + hash-screen oracle on the same cohort;
  * **peak bytes** — under the shared BYTES_PER_PAIR cost model the fused
    screen pass never allocates the [P, n, n] corpus: its working set is
    one patient block + the [2^H] table, stays flat as P doubles, and
    undercuts the materializing working set;
  * **wall** — the corpus-free fit stays within a small multiple of the
    materializing fit on CPU (it re-mines chunk-by-chunk for survivors,
    so it trades one extra mining pass for never holding the corpus).

Plus the autotune sweep that feeds ``analysis.roofline.mining_tile_plan``:
the fused counting pass is timed at several patient-block sizes and the
measured rows are handed back to the planner, closing the loop between
``benchmarks/mining_roofline.py``'s cost model and the kernel's tile
choice.  Prints ``name,us_per_call,derived`` CSV rows;
``main(json_path=...)`` writes BENCH_mining_fused.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.analysis import roofline
from repro.api import MiningConfig, MiningSession
from repro.api.planner import _fused_working_set, _working_set
from repro.data import dbmart, synthea
from repro.kernels.tspm_fused import ops as fused_ops

# the corpus-free fit runs the counting pass plus a full re-mine for
# survivors: ~2x the mining math of the one-pass materializing fit, traded
# for never holding the corpus.  CPU wall must stay under this multiple.
MAX_WALL_RATIO = 6.0


def _best_times(fns: dict, repeats: int) -> tuple[dict, dict]:
    """Interleaved best-of-N (same harness as api_overhead)."""
    times = {name: [] for name in fns}
    outs = {}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            outs[name] = fn()
            times[name].append(time.perf_counter() - t0)
    return {n: float(np.min(ts)) for n, ts in times.items()}, outs


def mining_fused(n_patients=2048, avg_events=24, threshold=3, repeats=3,
                 backend="jnp", n_buckets_log2=12, seed=13):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    E = int(np.max(db.nevents))
    hash_cfg = MiningConfig(threshold=threshold, screen="hash",
                            n_buckets_log2=n_buckets_log2, backend=backend)
    fused_cfg = hash_cfg.replace(screen="fused")

    # --- exactness ---------------------------------------------------------
    def fit_hash():
        return MiningSession(hash_cfg).fit(db)

    def fit_fused():
        return MiningSession(fused_cfg).fit(db)

    oracle = fit_hash().screen().collect()
    got = fit_fused().screen().collect()
    for field, a, b in zip(oracle._fields, oracle, got):
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), \
            f"fused screen diverged from mine+screen on {field}"

    # --- peak bytes (BYTES_PER_PAIR cost model) ----------------------------
    # the acceptance criterion: no [P, n, n] pair corpus on the screen
    # pass.  The fused working set is one patient block + the table; it
    # must undercut the materializing set and stay flat as P doubles
    # (a corpus-shaped allocation would scale with P).
    ws_dense = _working_set(np.asarray(db.nevents), hash_cfg)
    ws_fused = _fused_working_set(np.asarray(db.nevents), fused_cfg)
    assert ws_fused < ws_dense, (ws_fused, ws_dense)
    nev2 = np.concatenate([db.nevents, db.nevents])
    assert _fused_working_set(nev2, fused_cfg) == ws_fused, \
        "fused screen working set scales with P: a corpus is hiding in it"
    peak_ratio = ws_dense / max(ws_fused, 1)

    # --- wall --------------------------------------------------------------
    ts, _ = _best_times({"hash": lambda: fit_hash().screen().n_kept,
                         "fused": lambda: fit_fused().screen().n_kept},
                        repeats)
    wall_ratio = ts["fused"] / max(ts["hash"], 1e-12)
    assert wall_ratio <= MAX_WALL_RATIO, \
        f"fused fit {wall_ratio:.1f}x slower than materializing (cap " \
        f"{MAX_WALL_RATIO}x)"

    # --- autotune sweep -> tile plan ---------------------------------------
    analytic = roofline.mining_tile_plan(E, n_buckets_log2)
    rows = []
    for pb in (4, 8, 16):
        def count(pb=pb):
            return np.asarray(fused_ops.fused_bucket_counts(
                db.phenx, db.date, db.nevents, n_buckets_log2=n_buckets_log2,
                backend=backend, block_patients=pb * 16))
        t, _ = _best_times({"c": count}, max(repeats - 2, 2))
        rows.append({"pb": pb, "wall_s": t["c"]})
    plan = roofline.mining_tile_plan(E, n_buckets_log2, rows=rows)
    assert plan.source == "measured"

    return {
        "patients": n_patients, "avg_events": avg_events, "max_events": E,
        "threshold": threshold, "backend": backend,
        "n_buckets_log2": n_buckets_log2, "repeats": repeats,
        "n_kept": int(len(got.seq)),
        "working_set_dense_bytes": int(ws_dense),
        "working_set_fused_bytes": int(ws_fused),
        "peak_ratio": float(peak_ratio),
        "exact": True,              # asserted above, recorded for the gate
        "corpus_free": True,        # P-doubling invariance asserted above
        "wall_hash_s": ts["hash"], "wall_fused_s": ts["fused"],
        "wall_ratio": float(wall_ratio), "max_wall_ratio": MAX_WALL_RATIO,
        "autotune_rows": rows,
        "tile_plan": {"pb": plan.pb, "ti": plan.ti, "tj": plan.tj,
                      "bt": plan.bt, "block_patients": plan.block_patients,
                      "vmem_bytes": plan.vmem_bytes, "source": plan.source},
        "tile_plan_analytic": {"pb": analytic.pb,
                               "block_patients": analytic.block_patients},
    }


def main(small=True, json_path=None, backend="jnp"):
    kw = dict() if small else dict(n_patients=8192, avg_events=40, repeats=5)
    r = mining_fused(backend=backend, **kw)
    print("name,us_per_call,derived")
    print(f"mining_fused/fit_materializing,{r['wall_hash_s']*1e6:.0f},"
          f"kept={r['n_kept']}")
    print(f"mining_fused/fit_corpus_free,{r['wall_fused_s']*1e6:.0f},"
          f"wall_ratio={r['wall_ratio']:.2f}x (cap {r['max_wall_ratio']}x);"
          f"exact=asserted")
    print(f"mining_fused/peak_bytes,,dense={r['working_set_dense_bytes']};"
          f"fused={r['working_set_fused_bytes']};"
          f"ratio={r['peak_ratio']:.1f}x (P-invariance asserted)")
    p = r["tile_plan"]
    print(f"mining_fused/tile_plan,,pb={p['pb']};bt={p['bt']};"
          f"block={p['block_patients']};source={p['source']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"mining_fused/artifact,,{json_path}")
    return r


if __name__ == "__main__":
    main()

"""Tiered storage benchmark — codec compression, disk-tier ingest, resume.

Three sections, every one with its exactness check inline (a benchmark
that silently mines different bytes is worse than no benchmark):

  * **codec**: every synthea patient history encoded into a
    CompressedBlockStore with a cohort dictionary — compression ratio
    (asserted >= 3x on this clinical shape: monotone dates, small code
    vocabulary), encode and decode throughput, exact roundtrip on every
    block;
  * **tiered ingest**: the same cohort replayed through a MiningSession
    with a device budget tight enough to spill and a disk budget tight
    enough to demote — ingest throughput with the disk tier on the
    eviction path, demotion/restore counts from the ``storage.*``
    metrics, corpus asserted equal to the batch mine;
  * **checkpoint/resume**: the live session checkpointed and restored —
    save/restore wall clock, checkpoint size on disk, and the restored
    snapshot asserted byte-identical (seq/dur/patient/counts) before the
    replay continues.

Prints ``name,us_per_call,derived`` CSV rows; ``main(json_path=...)``
writes the numbers for the CI smoke artifact.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.core import mining
from repro.data import dbmart, synthea
from repro.launch.stream import replay_waves
from repro.storage.blockstore import CompressedBlockStore
from repro.storage.codec import CodeDictionary


def _cohort(n_patients, avg_events, seed=11):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=n_patients, avg_events=avg_events, seed=seed)
    return dbmart.from_rows(pats, dates, phx)


def codec_bench(db, root: str) -> dict:
    """Blockstore over the whole cohort: ratio + encode/decode rates."""
    histories = [(p, db.phenx[p, : int(db.nevents[p])],
                  db.date[p, : int(db.nevents[p])])
                 for p in range(db.n_patients) if int(db.nevents[p])]
    dictionary = CodeDictionary.from_histories([h[1] for h in histories])
    bs = CompressedBlockStore(root, dictionary=dictionary, auto_flush=False)
    n_events = sum(len(h[1]) for h in histories)

    t0 = time.perf_counter()
    for p, ph, dt in histories:
        bs.put(p, ph, dt)
    bs.flush()
    encode_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for p, ph, dt in histories:
        got_ph, got_dt = bs.get(p)
        assert (got_ph == ph).all() and (got_dt == dt).all(), \
            f"codec roundtrip mismatch for patient {p}"
    decode_s = time.perf_counter() - t0

    ratio = bs.compression_ratio()
    assert ratio >= 3.0, (
        f"compression ratio {ratio:.2f}x < 3x on a synthea-shaped cohort — "
        "the delta/varint/dictionary codec regressed")
    out = {
        "patients": len(histories), "events": n_events,
        "raw_bytes": bs.raw_bytes_held, "encoded_bytes": bs.bytes_held,
        "compression_ratio": ratio,
        "encode_s": encode_s, "decode_s": decode_s,
        "encode_events_per_s": n_events / max(encode_s, 1e-9),
        "decode_events_per_s": n_events / max(decode_s, 1e-9),
    }
    bs.close()
    return out


def tiered_ingest_bench(db, n_waves, tick_patients, backend, seed):
    """Replay with the disk tier on the eviction path; batch-exact."""
    session = MiningSession(MiningConfig(
        tick_patients=tick_patients, backend=backend, n_buckets_log2=18,
        screen="hash", budget_bytes=60_000, disk_bytes=20_000,
        telemetry=True))
    t0 = time.perf_counter()
    for _ in replay_waves(db, session, n_waves, seed):
        session.service.run()
    ingest_s = time.perf_counter() - t0

    svc = session.service
    mined = mining.mine(db.phenx, db.date, db.nevents, backend=backend)
    assert len(svc.snapshot().seq) == int(np.asarray(mined.mask).sum()), \
        "tiered streamed corpus size != batch mine"

    m = session.metrics()
    tiers = {k: v for k, v in m.items() if k.startswith("storage.")}
    demotions = sum(v for k, v in m.items()
                    if k.startswith("storage.demotions"))
    assert demotions > 0, (
        "disk budget never demoted anyone — the benchmark is not "
        "exercising the disk tier; tighten budget_bytes/disk_bytes")
    events = int(sum(s.n_events for s in svc.stats))
    return {
        "events": events, "ingest_s": ingest_s,
        "events_per_s": events / max(ingest_s, 1e-9),
        "demotions": int(demotions),
        "disk_restores": sum(
            v for k, v in m.items()
            if k.startswith("storage.restores") and "disk" in k),
        "storage_metrics": tiers,
    }, session


def checkpoint_bench(session, ckpt_dir: str) -> dict:
    """Save + restore the live session; restored bytes must be identical."""
    before = session.service.snapshot()

    t0 = time.perf_counter()
    path = session.checkpoint(ckpt_dir)
    save_s = time.perf_counter() - t0
    ckpt_bytes = sum(os.path.getsize(os.path.join(path, f))
                     for f in os.listdir(path))

    t0 = time.perf_counter()
    restored = MiningSession.restore(path)
    after = restored.service.snapshot()
    restore_s = time.perf_counter() - t0

    assert (before.seq == after.seq).all() \
        and (before.dur == after.dur).all() \
        and (before.patient == after.patient).all() \
        and (before.counts == after.counts).all(), \
        "restored snapshot is not byte-identical to the checkpointed one"
    return {
        "save_s": save_s, "restore_s": restore_s,
        "checkpoint_bytes": ckpt_bytes,
        "corpus_rows": int(len(before.seq)),
        "restore_rows_per_s": len(before.seq) / max(restore_s, 1e-9),
        "restore_bytes_per_s": ckpt_bytes / max(restore_s, 1e-9),
    }


def main(small=True, json_path=None, backend="jnp", seed=11):
    n_patients = 80 if small else 400
    avg_events = 24 if small else 40
    db = _cohort(n_patients, avg_events, seed)

    with tempfile.TemporaryDirectory(prefix="tspm_bench_") as tmp:
        codec = codec_bench(db, os.path.join(tmp, "blocks"))
        ingest, session = tiered_ingest_bench(
            db, n_waves=6 if small else 10,
            tick_patients=8 if small else 16, backend=backend, seed=seed)
        ckpt = checkpoint_bench(session, os.path.join(tmp, "ckpt"))

    print("name,us_per_call,derived")
    print(f"storage/codec_encode,{codec['encode_s']*1e6:.0f},"
          f"ratio={codec['compression_ratio']:.2f}x;"
          f"events_per_s={codec['encode_events_per_s']:.0f}")
    print(f"storage/codec_decode,{codec['decode_s']*1e6:.0f},"
          f"events_per_s={codec['decode_events_per_s']:.0f}")
    print(f"storage/tiered_ingest,{ingest['ingest_s']*1e6:.0f},"
          f"events_per_s={ingest['events_per_s']:.0f};"
          f"demotions={ingest['demotions']};"
          f"disk_restores={ingest['disk_restores']}")
    print(f"storage/checkpoint_save,{ckpt['save_s']*1e6:.0f},"
          f"bytes={ckpt['checkpoint_bytes']}")
    print(f"storage/checkpoint_restore,{ckpt['restore_s']*1e6:.0f},"
          f"rows_per_s={ckpt['restore_rows_per_s']:.0f}")

    record = {"patients": n_patients, "avg_events": avg_events,
              "backend": backend, "codec": codec, "tiered_ingest": ingest,
              "checkpoint": ckpt}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return record


if __name__ == "__main__":
    main()

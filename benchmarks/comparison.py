"""Paper Table 1 — comparison benchmark: original tSPM vs tSPM+.

Reproduces the protocol: cohort with first-occurrence filtering (the AD
study protocol), six rows
  tSPM  {without, with} sparsity screening      (original algorithm)
  tSPM+ {in-memory, file-based} x {without, with} screening
measuring wall time and memory.  Cohort size defaults to a CPU-friendly
scale (the paper's 4 985 x 471 runs for hours on the ORIGINAL algorithm);
--full restores paper scale for the tSPM+ rows.

Memory accounting: peak RSS delta (the paper uses /usr/bin/time's maxrss)
plus the analytic working-set bytes of the mining buffers.
"""
from __future__ import annotations

import gc
import resource
import time

import numpy as np

from repro.core import baseline_tspm, chunking, mining, sparsity
from repro.data import synthea
from repro.data.dbmart import DBMart, first_occurrence_filter, from_rows


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def make_cohort(n_patients: int, avg_events: int, seed: int = 0) -> DBMart:
    pid, date, xid, counts = synthea.generate_benchmark_rows(
        n_patients, avg_events, seed)
    db = from_rows(pid.tolist(), date.tolist(),
                   [f"phx{v}" for v in xid.tolist()])
    return first_occurrence_filter(db)


def _timed(fn, iters=1):
    gc.collect()
    rss0 = _rss_mb()
    times = []
    out = None
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return min(times), _rss_mb() - rss0, out


def run(n_patients=400, avg_events=60, threshold=4, iters=2,
        baseline_iters=1, spill_dir="/tmp/tspm_bench"):
    db = make_cohort(n_patients, avg_events)
    n_seq = int(mining.count_sequences(db.nevents))
    rows = []

    # --- original tSPM (string-based row loops), the paper's baseline ---
    t, m, out = _timed(lambda: baseline_tspm.mine_strings(db),
                       baseline_iters)
    rows.append(("tspm_original_noscreen", t, m, len(out)))
    t2, m2, out2 = _timed(
        lambda: baseline_tspm.mine_and_screen(db, threshold), baseline_iters)
    rows.append(("tspm_original_screen", t2, m2, len(out2)))

    # --- tSPM+ in-memory (vectorized jnp path) ---
    def tspm_plus_mem():
        mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
        return mined.seq.block_until_ready(), mined

    t3, m3, (_, mined) = _timed(tspm_plus_mem, iters)
    rows.append(("tspm_plus_mem_noscreen", t3, m3, int(mined.n_mined)))

    def tspm_plus_mem_screen():
        mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
        seq, dur, pat, msk = mining.flatten(mined)
        scr = sparsity.screen_sorted(seq, dur, pat, msk, threshold)
        return int(scr.n_kept)

    t4, m4, kept = _timed(tspm_plus_mem_screen, iters)
    rows.append(("tspm_plus_mem_screen", t4, m4, kept))

    # --- tSPM+ file-based (chunked spill, the paper's low-memory mode) ---
    def tspm_plus_file():
        paths = chunking.mine_to_files(db, spill_dir, budget_bytes=64 << 20)
        return len(paths)

    t5, m5, nchunks = _timed(tspm_plus_file, 1)
    rows.append(("tspm_plus_file_noscreen", t5, m5, n_seq))

    def tspm_plus_file_screen():
        total = 0
        for part in chunking.screen_files(spill_dir, threshold):
            total += len(part["seq"])
        return total

    t6, m6, kept_f = _timed(tspm_plus_file_screen, 1)
    rows.append(("tspm_plus_file_screen", t5 + t6, m6, kept_f))

    # --- consistency + speedups ---
    assert len(out) == int(mined.n_mined) == n_seq
    assert len(out2) == kept, "sorted screen must match the dict oracle"
    # the file path uses the hash screen: one-sided (collisions only KEEP
    # extra sparse sequences, never drop) — report the excess
    assert kept_f >= kept
    hash_excess = (kept_f - kept) / max(kept, 1)
    speed_nos = rows[0][1] / max(rows[2][1], 1e-9)
    speed_scr = rows[1][1] / max(rows[3][1], 1e-9)
    return {
        "rows": rows,
        "n_sequences": n_seq,
        "speedup_noscreen": speed_nos,
        "speedup_screen": speed_scr,
        "hash_excess": hash_excess,
        "cohort": (n_patients, avg_events),
    }


def main(small=True):
    res = run() if small else run(n_patients=2000, avg_events=120, iters=3)
    print("# paper Table 1 analogue "
          f"(cohort {res['cohort'][0]} patients x ~{res['cohort'][1]} "
          f"events, {res['n_sequences']} sequences)")
    print("name,us_per_call,derived")
    for name, t, mem, count in res["rows"]:
        print(f"comparison/{name},{t*1e6:.0f},count={count};rss_mb={mem:.0f}")
    print(f"comparison/speedup_noscreen,,x{res['speedup_noscreen']:.1f}")
    print(f"comparison/speedup_screen,,x{res['speedup_screen']:.1f}")
    print(f"comparison/hash_screen_excess,,{res['hash_excess']:.4f}")
    return res


if __name__ == "__main__":
    main()

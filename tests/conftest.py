import numpy as np
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def small_cohort():
    from repro.data import dbmart, synthea

    pats, dates, phx, truth = synthea.generate_cohort(
        n_patients=48, avg_events=24, seed=5)
    return dbmart.from_rows(pats, dates, phx), truth


def brute_force_pairs(db):
    """Independent O(n^2) oracle: set of (patient, start, end, duration)."""
    out = []
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        for i in range(n):
            for j in range(i + 1, n):
                out.append((p, int(db.phenx[p, i]), int(db.phenx[p, j]),
                            int(db.date[p, j]) - int(db.date[p, i])))
    return out


@pytest.fixture
def brute_force():
    return brute_force_pairs


def random_dbmart(rng: np.random.Generator, n_patients=None, max_events=None,
                  n_codes=None, date_range=400):
    """Random numeric dbmart for property tests."""
    from repro.data.dbmart import DBMart

    P = n_patients or int(rng.integers(1, 12))
    E = max_events or int(rng.integers(2, 24))
    V = n_codes or int(rng.integers(2, 30))
    nevents = rng.integers(0, E + 1, P).astype(np.int32)
    e_pad = -(-max(int(nevents.max(initial=1)), 1) // 8) * 8
    phenx = rng.integers(0, V, (P, e_pad)).astype(np.int32)
    date = np.sort(rng.integers(0, date_range, (P, e_pad)).astype(np.int32), axis=1)
    for p in range(P):
        n = int(nevents[p])
        if n < e_pad:
            date[p, n:] = date[p, n - 1] if n else 0
    return DBMart(phenx, date, nevents, None)

import inspect
import sys
import types

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    # Offline environments ship without hypothesis.  Install a minimal stub
    # so test modules still *import* (they do `from hypothesis import given,
    # strategies as st` at module top); @given-decorated tests skip, every
    # other test in those modules runs normally.
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def _given(*given_args, **given_kwargs):
        def deco(fn):
            # Mirror hypothesis: positional strategies bind the RIGHTMOST
            # params, keyword strategies bind by name.  The skipper keeps
            # the remaining params visible so parametrize args and
            # fixtures on @given tests still collect and inject.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            bound = set(given_kwargs)
            if given_args:
                bound |= {p.name for p in params[-len(given_args):]}

            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__signature__ = sig.replace(
                parameters=[p for p in params if p.name not in bound])
            skipper.pytestmark = list(getattr(fn, "pytestmark", []))
            return skipper

        return deco

    class _Settings:
        def __init__(self, *a, **k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    def _identity_deco(*a, **k):
        return lambda fn: fn

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()
    _hyp.strategies = _st
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.seed = _identity_deco
    _hyp.example = _identity_deco
    _hyp.assume = lambda *a, **k: True
    _hyp.HealthCheck = _Strategy()
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

def pytest_configure(config):
    # chaos/property suites (deep sweeps, hypothesis schedules) are marked
    # slow so tier-1 can stay fast with `-m "not slow"`
    config.addinivalue_line(
        "markers", "slow: deep chaos/property sweeps; deselect with "
        '-m "not slow"')


if HAVE_HYPOTHESIS:
    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("repro")


@pytest.fixture(scope="session")
def small_cohort():
    from repro.data import dbmart, synthea

    pats, dates, phx, truth = synthea.generate_cohort(
        n_patients=48, avg_events=24, seed=5)
    return dbmart.from_rows(pats, dates, phx), truth


def brute_force_pairs(db):
    """Independent O(n^2) oracle: set of (patient, start, end, duration)."""
    out = []
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        for i in range(n):
            for j in range(i + 1, n):
                out.append((p, int(db.phenx[p, i]), int(db.phenx[p, j]),
                            int(db.date[p, j]) - int(db.date[p, i])))
    return out


@pytest.fixture
def brute_force():
    return brute_force_pairs


def random_dbmart(rng: np.random.Generator, n_patients=None, max_events=None,
                  n_codes=None, date_range=400):
    """Random numeric dbmart for property tests."""
    from repro.data.dbmart import DBMart

    P = n_patients or int(rng.integers(1, 12))
    E = max_events or int(rng.integers(2, 24))
    V = n_codes or int(rng.integers(2, 30))
    nevents = rng.integers(0, E + 1, P).astype(np.int32)
    e_pad = -(-max(int(nevents.max(initial=1)), 1) // 8) * 8
    phenx = rng.integers(0, V, (P, e_pad)).astype(np.int32)
    date = np.sort(rng.integers(0, date_range, (P, e_pad)).astype(np.int32), axis=1)
    for p in range(P):
        n = int(nevents[p])
        if n < e_pad:
            date[p, n:] = date[p, n - 1] if n else 0
    return DBMart(phenx, date, nevents, None)

"""MoE routing/dispatch and the shared chunked linear recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ModelConfig
from repro.models import moe, ssm_common


def _moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=4, d_ff=64, vocab_size=64, n_experts=8,
                n_shared_experts=0, experts_per_token=2, moe_d_ff=16,
                fsdp=False)
    base.update(kw)
    return ModelConfig(**base)


def test_moe_capacity_drops_are_counted():
    """With tiny capacity, outputs for dropped tokens are exactly the
    shared-expert path (zero here): dropping is explicit, not silent."""
    cfg = _moe_cfg(capacity_factor=0.01)
    p, _ = moe.init(jax.random.PRNGKey(0), cfg, None)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 32)),
                    jnp.float32)
    y, aux = moe.apply(p, x, cfg)
    n_zero = int((np.abs(np.asarray(y)).sum(-1) < 1e-9).sum())
    assert n_zero > 0  # capacity 8 slots/expert < demand


def test_moe_unbounded_capacity_matches_dense_mixture():
    """With no drops, output == sum_k gate_k * expert_k(x) computed densely."""
    cfg = _moe_cfg(capacity_factor=32.0)
    p, _ = moe.init(jax.random.PRNGKey(1), cfg, None)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 8, 32)), jnp.float32)
    y, _ = moe.apply(p, x, cfg)

    xf = x.reshape(-1, 32)
    probs = jax.nn.softmax(xf @ p["router"], axis=-1)
    gate, eid = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    want = np.zeros((8, 32), np.float32)
    for t in range(8):
        for j in range(cfg.experts_per_token):
            e = int(eid[t, j])
            h = np.asarray(jax.nn.silu(xf[t] @ p["w_gate"][e]) *
                           (xf[t] @ p["w_up"][e]))
            want[t] += float(gate[t, j]) * (h @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(y).reshape(8, 32), want,
                               atol=2e-4, rtol=2e-4)


def test_moe_aux_loss_bounds():
    """Switch aux >= coef (perfect balance) and small for random routers."""
    cfg = _moe_cfg()
    p, _ = moe.init(jax.random.PRNGKey(2), cfg, None)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((4, 64, 32)),
                    jnp.float32)
    _, aux = moe.apply(p, x, cfg)
    assert float(aux) >= cfg.router_aux_coef * 0.9
    assert float(aux) < cfg.router_aux_coef * cfg.n_experts


def _naive_recurrence(q, k, v, log_f, normalize=False):
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    C = np.zeros((B, H, dk, dv))
    n = np.zeros((B, H, dk))
    ys, qns = [], []
    for t in range(S):
        f = np.exp(np.asarray(log_f[:, t], np.float64))[..., None]
        C = C * f[..., None] + np.einsum("bhd,bhv->bhdv", k[:, t], v[:, t])
        n = n * f + np.asarray(k[:, t], np.float64)
        ys.append(np.einsum("bhd,bhdv->bhv", q[:, t], C))
        qns.append(np.einsum("bhd,bhd->bh", q[:, t], n))
    return np.stack(ys, 1), np.stack(qns, 1), C, n


@given(st.integers(0, 500), st.sampled_from([4, 8, 16]))
@settings(max_examples=8, deadline=None)
def test_chunked_scan_matches_naive(seed, chunk):
    rng = np.random.default_rng(seed)
    B, S, H, dk, dv = 2, 16, 2, 4, 6
    q = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    k = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    v = rng.standard_normal((B, S, H, dv)).astype(np.float32)
    log_f = -np.abs(rng.standard_normal((B, S, H))).astype(np.float32)
    y, qn, st_ = ssm_common.chunked_scan(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(log_f),
        chunk=chunk, normalize=True)
    y_ref, qn_ref, C_ref, n_ref = _naive_recurrence(q, k, v, log_f)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(qn), qn_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_.C), C_ref, atol=1e-4,
                               rtol=1e-4)


def test_decode_steps_continue_chunked_scan():
    rng = np.random.default_rng(7)
    B, S, H, dk, dv = 1, 12, 2, 4, 4
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = mk(B, S, H, dk), mk(B, S, H, dk), mk(B, S, H, dv)
    log_f = -jnp.abs(mk(B, S, H))
    y_all, _, _ = ssm_common.chunked_scan(q, k, v, log_f, chunk=4)
    y8, _, st8 = ssm_common.chunked_scan(q[:, :8], k[:, :8], v[:, :8],
                                         log_f[:, :8], chunk=4)
    st = st8
    for t in range(8, 12):
        y_t, _, st = ssm_common.decode_step(q[:, t], k[:, t], v[:, t],
                                            log_f[:, t], st)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_all[:, t]),
                                   atol=1e-4, rtol=1e-4)


def test_causal_conv_matches_decode_chain():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((2, 10, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 6)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(6), jnp.float32)
    full = ssm_common.causal_conv1d(x, w, b)
    state = jnp.zeros((2, 3, 6))
    for t in range(10):
        y_t, state = ssm_common.conv_decode_step(x[:, t], state, w, b)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(full[:, t]),
                                   atol=1e-5, rtol=1e-5)

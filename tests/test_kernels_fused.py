"""Conformance suite for the fused mine+screen kernel (kernels/tspm_fused).

The contract: ``fused_bucket_counts`` is byte-identical to materializing
the corpus and screening it — ``sparsity.local_bucket_counts`` over
``mining.mine(...)`` — for every codec, fused/unfused duration ids, both
backends, and every edge the tiling can hit (tile-boundary E, duplicate
values/timestamps, empty cohorts, adversarial hash collisions).  Plus the
limb-hash unit contract (hash_parts == hash_bucket(pack) without ever
forming the int64 id) and the roofline tile-selection pins.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_dbmart
from repro.analysis import roofline
from repro.core import encoding, mining, sparsity
from repro.kernels.tspm_fused import fused, ops, ref

BACKENDS = ("kernel", "jnp")


def oracle_counts(db, codec="bit", fuse_duration=False, bucket_days=30,
                  n_buckets_log2=12):
    """The materializing path's table: mine the whole corpus, then count."""
    m = mining.mine_triangular(db.phenx, db.date, db.nevents, codec,
                               fuse_duration, bucket_days)
    return np.asarray(sparsity.local_bucket_counts(
        m.seq, m.mask, n_buckets_log2))


def fused_counts(db, backend, codec="bit", fuse_duration=False,
                 bucket_days=30, n_buckets_log2=12, **kw):
    return np.asarray(ops.fused_bucket_counts(
        db.phenx, db.date, db.nevents, codec=codec,
        fuse_duration=fuse_duration, bucket_days=bucket_days,
        n_buckets_log2=n_buckets_log2, backend=backend, **kw))


# --- limb hash unit contract -------------------------------------------------
@pytest.mark.parametrize("codec", ("bit", "paper"))
@pytest.mark.parametrize("H", (1, 8, 12, 14, 20, 24))
def test_hash_parts_equals_hash_bucket(codec, H):
    """The int32 13-bit-limb hash == hash_bucket(pack) for unfused ids and
    hash_bucket(fuse_duration(pack)) for fused ones, across the whole H
    range the kernel admits."""
    rng = np.random.default_rng(7 * H)
    s = rng.integers(0, encoding.max_vocab(codec), 512).astype(np.int32)
    e = rng.integers(0, encoding.max_vocab(codec), 512).astype(np.int32)
    b = rng.integers(0, 1 << encoding.DUR_BITS, 512).astype(np.int32)
    want = np.asarray(sparsity.hash_bucket(encoding.pack(s, e, codec), H))
    got = np.asarray(fused.hash_parts(s, e, codec=codec, n_buckets_log2=H))
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)
    fid = encoding.fuse_duration(encoding.pack(s, e, codec), b)
    wantf = np.asarray(sparsity.hash_bucket(fid, H))
    gotf = np.asarray(fused.hash_parts(s, e, b, codec=codec,
                                       n_buckets_log2=H, fused_ids=True))
    np.testing.assert_array_equal(gotf, wantf)


def test_hash_parts_rejects_out_of_range_tables():
    with pytest.raises(AssertionError):
        fused.hash_parts(np.int32(1), np.int32(2), n_buckets_log2=25)
    with pytest.raises(AssertionError):
        fused.hash_parts(np.int32(1), np.int32(2), n_buckets_log2=0)


def test_hash_constants_linear_in_fields():
    """hash(pack(s, e)) == top bits of (s*C1 + e*C2) mod 2^64 — the
    linearity the kernel's corpus-free hashing rests on."""
    for codec in ("bit", "paper"):
        c_start, c_end, c_bucket = fused.hash_constants(codec)
        mult = ((1 << encoding.BIT_SHIFT) if codec == "bit"
                else encoding.PAPER_SHIFT)
        assert c_start == (sparsity.HASH_MULT * mult) % (1 << 64)
        assert c_end == sparsity.HASH_MULT
        assert c_bucket == sparsity.HASH_MULT
        cf_start, cf_end, _ = fused.hash_constants(codec, fused_ids=True)
        assert cf_start == (c_start << encoding.DUR_BITS) % (1 << 64)
        assert cf_end == (c_end << encoding.DUR_BITS) % (1 << 64)


# --- kernel vs materializing oracle -----------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("codec", ("bit", "paper"))
@pytest.mark.parametrize("P,E", [(1, 8), (3, 16), (8, 48), (16, 30), (7, 19)])
def test_conformance_random_cohorts(backend, codec, P, E):
    rng = np.random.default_rng(P * 100 + E)
    db = random_dbmart(rng, n_patients=P, max_events=E)
    want = oracle_counts(db, codec=codec)
    got = fused_counts(db, backend, codec=codec)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_fused_duration_ids(backend):
    """Fused-duration ids take the blocked jnp fallback on both backends
    (cross-row dedup does not decompose over tiles) and still match."""
    rng = np.random.default_rng(11)
    db = random_dbmart(rng, n_patients=9, max_events=24, date_range=900)
    want = oracle_counts(db, fuse_duration=True, bucket_days=30)
    got = fused_counts(db, backend, fuse_duration=True, bucket_days=30,
                       block_patients=4)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_block_partition_invariance(backend):
    """Counts are additive over patient blocks: any block size gives the
    same table."""
    rng = np.random.default_rng(23)
    db = random_dbmart(rng, n_patients=13, max_events=20)
    tables = [fused_counts(db, backend, block_patients=blk)
              for blk in (1, 3, 13, 64)]
    for t in tables[1:]:
        np.testing.assert_array_equal(t, tables[0])


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20)
def test_conformance_hypothesis_sweep(seed):
    """Random cohorts x random codec/backend: fused table == oracle."""
    rng = np.random.default_rng(seed)
    db = random_dbmart(rng)
    codec = ("bit", "paper")[int(rng.integers(2))]
    backend = BACKENDS[int(rng.integers(2))]
    H = int(rng.integers(4, 13))
    want = oracle_counts(db, codec=codec, n_buckets_log2=H)
    got = fused_counts(db, backend, codec=codec, n_buckets_log2=H)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_support_equals_threshold_edge(backend):
    """The screen keep decision at support == threshold is identical
    whether counts come from the fused path or the materialized corpus —
    at the exact threshold and one past it."""
    rng = np.random.default_rng(5)
    db = random_dbmart(rng, n_patients=10, max_events=16, n_codes=4)
    H = 10
    want = oracle_counts(db, n_buckets_log2=H)
    got = fused_counts(db, backend, n_buckets_log2=H)
    np.testing.assert_array_equal(got, want)
    m = mining.mine_triangular(db.phenx, db.date, db.nevents)
    supports = want[want > 0]
    assert supports.size, "degenerate cohort: no support mass"
    thr = int(supports.max())          # some bucket sits exactly at thr
    for t in (thr, thr + 1):
        keep_oracle = np.asarray(sparsity.screen_hash_from_counts(
            m.seq, m.mask, want, t, H))
        keep_fused = np.asarray(sparsity.screen_hash_from_counts(
            m.seq, m.mask, got, t, H))
        np.testing.assert_array_equal(keep_fused, keep_oracle)
    assert keep_oracle.sum() == 0      # thr+1 kills the max bucket's ids


# --- edge cases --------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("P,E", [(0, 8), (4, 0)])
def test_zero_width_slab_guard(backend, P, E):
    """Mirrors tspm_delta/ops.py: an empty patient or event axis yields an
    all-zero table of the right shape instead of a degenerate grid."""
    db_phenx = np.zeros((P, E), np.int32)
    got = np.asarray(ops.fused_bucket_counts(
        db_phenx, np.zeros((P, E), np.int32), np.zeros(P, np.int32),
        n_buckets_log2=8, backend=backend))
    assert got.shape == (256,) and got.sum() == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_no_pairable_patients(backend):
    """P > 0 but every patient has 0 or 1 events: no pairs, empty table."""
    phenx = np.tile(np.arange(6, dtype=np.int32), (4, 1))
    date = np.zeros((4, 6), np.int32)
    nev = np.array([0, 1, 0, 1], np.int32)
    got = np.asarray(ops.fused_bucket_counts(
        phenx, date, nev, n_buckets_log2=8, backend=backend))
    assert got.sum() == 0


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("E", (127, 128, 129))
def test_tile_boundary_event_counts(backend, E):
    """E exactly on and one past the 128 tile boundary."""
    rng = np.random.default_rng(E)
    db = random_dbmart(rng, n_patients=2, max_events=E, n_codes=6)
    assert int(db.nevents.max()) > 0
    want = oracle_counts(db, n_buckets_log2=10)
    got = fused_counts(db, backend, n_buckets_log2=10)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_duplicate_timestamps_and_codes(backend):
    """Same-day events and repeated codes: dedup must keep exactly one
    contribution per distinct (patient, id), including the a == b runs."""
    phenx = np.array([[2, 2, 2, 5, 5, 2, 7, 7],
                      [1, 1, 1, 1, 1, 1, 1, 1]], np.int32)
    date = np.array([[3, 3, 3, 3, 9, 9, 9, 9],
                     [0, 0, 0, 0, 0, 0, 0, 0]], np.int32)
    nev = np.array([8, 8], np.int32)
    from repro.data.dbmart import DBMart
    db = DBMart(phenx, date, nev, None)
    want = oracle_counts(db, n_buckets_log2=10)
    got = fused_counts(db, backend, n_buckets_log2=10)
    np.testing.assert_array_equal(got, want)
    # patient 1 mines only (1 -> 1): exactly one distinct contribution
    h = int(np.asarray(sparsity.hash_bucket(encoding.pack(1, 1), 10)))
    assert got[h] >= 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_hash_adversary_single_bucket(backend):
    """H=1 + identical codes: every id collides into few buckets; counts
    must still match the oracle exactly (collisions merge identically)."""
    rng = np.random.default_rng(31)
    db = random_dbmart(rng, n_patients=6, max_events=12, n_codes=1)
    for H in (1, 2):
        want = oracle_counts(db, n_buckets_log2=H)
        got = fused_counts(db, backend, n_buckets_log2=H)
        np.testing.assert_array_equal(got, want)
        assert got.sum() == want.sum()


def test_kernel_dispatch_regime():
    """backend='kernel' falls back to the jnp block path past
    KERNEL_MAX_LOG2 and for fused ids — and stays exact there."""
    rng = np.random.default_rng(41)
    db = random_dbmart(rng, n_patients=5, max_events=10)
    H = ops.KERNEL_MAX_LOG2 + 1
    want = oracle_counts(db, n_buckets_log2=H)
    got = fused_counts(db, "kernel", n_buckets_log2=H)
    np.testing.assert_array_equal(got, want)


# --- roofline tile selection -------------------------------------------------
def test_tile_plan_analytic_defaults():
    plan = roofline.mining_tile_plan(96, 12)
    assert plan.source == "analytic"
    assert plan.ti == plan.tj == 128
    assert (1 << 12) % plan.bt == 0
    assert plan.block_patients % plan.pb == 0
    assert plan.vmem_bytes <= roofline.VMEM_BYTES // 2
    # bigger tables never pick a bucket tile wider than the table
    small = roofline.mining_tile_plan(96, 8)
    assert small.bt == 256


def test_tile_plan_pins_measured_rows():
    """Known autotune rows: the fastest VMEM-fitting row wins; a faster
    row that blows VMEM is rejected."""
    rows = [{"pb": 4, "wall_s": 5e-3},
            {"pb": 8, "wall_s": 3e-3},
            {"pb": 512, "wall_s": 1e-3}]   # fastest, but never fits VMEM
    plan = roofline.mining_tile_plan(96, 12, rows=rows)
    assert plan.source == "measured"
    assert plan.pb == 8
    assert roofline.fused_kernel_vmem(512, 128, 128, 512, 96) \
        > roofline.VMEM_BYTES // 2
    # no fitting row at all -> analytic fallback
    plan2 = roofline.mining_tile_plan(96, 12, rows=[rows[2]])
    assert plan2.source == "analytic"


def test_tile_plan_feeds_the_kernel():
    """ops.fused_bucket_counts actually consumes the plan: overriding the
    block size against the plan's choice changes nothing in the result
    (partition invariance) but the default block comes from the plan."""
    plan = roofline.mining_tile_plan(24, 10)
    assert plan.block_patients >= plan.pb
    rng = np.random.default_rng(53)
    db = random_dbmart(rng, n_patients=4, max_events=12)
    a = fused_counts(db, "kernel", n_buckets_log2=10)
    b = fused_counts(db, "kernel", n_buckets_log2=10,
                     block_patients=plan.block_patients)
    np.testing.assert_array_equal(a, b)


def test_ref_block_counts_is_the_contract():
    """ref.block_bucket_counts == local_bucket_counts(mine_dense) — the
    documented semantic contract of the kernel."""
    rng = np.random.default_rng(61)
    db = random_dbmart(rng, n_patients=3, max_events=10)
    m = mining.mine_dense(db.phenx, db.date, db.nevents)
    P = m.seq.shape[0]
    want = np.asarray(sparsity.local_bucket_counts(
        m.seq.reshape(P, -1), m.mask.reshape(P, -1), 10))
    got = np.asarray(ref.block_bucket_counts(
        db.phenx, db.date, db.nevents, n_buckets_log2=10))
    np.testing.assert_array_equal(got, want)

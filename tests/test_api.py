"""Unified session API: conformance, planner, and frame semantics.

The façade's headline guarantee: for a fixed cohort,
``MiningSession.fit`` output — kept sequences, durations, patients,
supports, decoded strings — is **byte-identical** across every engine the
planner can select (batch, chunked, file-based, streaming n_shards=1,
sharded n_shards=4), in both screen modes, with and without duration
fusing.  Plus: the planner is inspectable/overridable, chained frame masks
match the hand-wired core flows, and incremental submit/tick converges to
the batch fit.
"""
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.api import ENGINES, MiningConfig, MiningSession
from repro.core import mining, msmr, queries, sparsity
from repro.data import dbmart, synthea
from tests.conftest import random_dbmart

H = 12   # small hash table: collisions happen, all engines must agree anyway


def fit_engine(engine, db, tmp_path=None, **cfg_kw):
    kw = dict(engine=engine, n_buckets_log2=H, budget_bytes=48 << 10,
              tick_patients=3)
    kw.update(cfg_kw)
    if engine == "sharded":
        kw.setdefault("n_shards", 4)
    if engine == "files" and tmp_path is not None:
        kw.setdefault("spill_dir", str(tmp_path / f"spill_{engine}"))
    return MiningSession(MiningConfig(**kw)).fit(db)


def assert_frames_identical(frames: dict, decode=False):
    base_name, base = next(iter(frames.items()))
    br = base.screen().collect()
    for name, frame in frames.items():
        r = frame.screen().collect()
        for field, a, b in zip(br._fields, br, r):
            assert a.dtype == b.dtype, (name, field)
            assert a.tobytes() == b.tobytes(), (name, field, base_name)
        if decode:
            assert [tuple(d) for d in frame.screen().decode()] \
                == [tuple(d) for d in base.screen().decode()], name


@pytest.mark.parametrize("screen", ["sorted", "hash"])
def test_conformance_all_engines(tmp_path, screen):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=32, avg_events=14, seed=21)
    db = dbmart.from_rows(pats, dates, phx)
    frames = {e: fit_engine(e, db, tmp_path, threshold=3, screen=screen)
              for e in ENGINES}
    assert_frames_identical(frames, decode=True)
    # unscreened corpora are identical too (not just the kept prefix)
    for e, f in frames.items():
        seq, dur, pat, _ = f.arrays()
        bseq, bdur, bpat, _ = frames["batch"].arrays()
        assert seq.tobytes() == bseq.tobytes(), e
        assert dur.tobytes() == bdur.tobytes(), e
        assert pat.tobytes() == bpat.tobytes(), e


def test_conformance_fused_screen(tmp_path):
    """screen='fused' (corpus-free counting + survivors-only
    materialization) is byte-identical to the batch mine+screen oracle
    across every engine.  Fused frames hold only survivors, so the
    comparison is the screened collect (seq/dur/patient/support bytes +
    decoded strings), not the raw corpus."""
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=32, avg_events=14, seed=21)
    db = dbmart.from_rows(pats, dates, phx)
    frames = {"oracle": fit_engine("batch", db, threshold=3, screen="hash")}
    frames.update({e: fit_engine(e, db, tmp_path, threshold=3,
                                 screen="fused")
                   for e in ENGINES})
    assert_frames_identical(frames, decode=True)
    for e in ENGINES:
        assert frames[e].screen_mode == "fused"
        # survivors-only: the fused frame's corpus is exactly the oracle's
        # kept rows (nothing sparse was ever materialized)
        assert len(frames[e]) == frames["oracle"].screen().n_kept


def test_conformance_fused_screen_threshold_edge(tmp_path):
    """The support == threshold edge: fit at the exact max support and one
    past it; fused and materializing paths agree at both."""
    rng = np.random.default_rng(207)
    db = random_dbmart(rng, n_patients=10, max_events=14, n_codes=5)
    probe = fit_engine("batch", db, threshold=1, screen="hash")
    sup = probe.collect().support
    assert len(sup), "degenerate cohort"
    thr = int(sup.max())              # some id sits exactly at the edge
    for t in (thr, thr + 1):
        frames = {"oracle": fit_engine("batch", db, threshold=t,
                                       screen="hash")}
        frames.update({e: fit_engine(e, db, tmp_path, threshold=t,
                                     screen="fused")
                       for e in ENGINES})
        assert_frames_identical(frames)
    # above every support, the fused fit materializes nothing at all
    empty = fit_engine("batch", db, threshold=int(sup.max()) + 1,
                       screen="fused")
    assert len(empty) == 0


def test_fused_screen_streaming_sketch_path():
    """Incremental submit/tick under screen='fused': the live sketch table
    (stream/counts) drives survivor compaction, matching the batch fused
    fit — and OnlineSupportSketch.survivors agrees with the frame."""
    rng = np.random.default_rng(77)
    db = random_dbmart(rng, n_patients=8, max_events=14)
    batch = MiningSession(MiningConfig(threshold=2, n_buckets_log2=H,
                                       screen="fused")).fit(db)

    sess = MiningSession(MiningConfig(threshold=2, n_buckets_log2=H,
                                      screen="fused", tick_patients=2))
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        cut = n // 2
        if cut:
            sess.submit(p, db.date[p, :cut], db.phenx[p, :cut])
        if n - cut:
            sess.submit(p, db.date[p, cut:n], db.phenx[p, cut:n])
    sess.tick()                          # one wave, then drain
    final = sess.run()

    br, fr = batch.screen().collect(), final.screen().collect()
    for field, a, b in zip(br._fields, br, fr):
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), field

    # the sketch's survivors() is the same compaction the frame went
    # through: applying it to the raw snapshot reproduces the frame corpus
    # (frames canonicalize row order, so compare in the same lexsort)
    snap = sess.service.snapshot()
    seq, dur, pat = sess.service.sketch.survivors(
        snap.seq, snap.dur, snap.patient, 2)
    order = np.lexsort((dur, pat, seq))
    fseq, fdur, fpat, _ = final.arrays()
    assert seq[order].tobytes() == np.asarray(fseq).tobytes()
    assert dur[order].tobytes() == np.asarray(fdur).tobytes()
    assert pat[order].tobytes() == np.asarray(fpat).tobytes()


def test_conformance_fused_duration(tmp_path):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=24, avg_events=12, seed=3)
    db = dbmart.from_rows(pats, dates, phx)
    frames = {e: fit_engine(e, db, tmp_path, threshold=2, screen="hash",
                            fuse_duration=True)
              for e in ENGINES}
    assert_frames_identical(frames, decode=True)
    # fuse-aware queries on the fused corpus match the unfused corpus
    plain = fit_engine("batch", db, threshold=2)
    x = int(np.asarray(db.phenx)[0, 0])
    for f in frames.values():
        assert f.starts_with(x).n_kept == plain.starts_with(x).n_kept
        assert f.ends_with(x).n_kept == plain.ends_with(x).n_kept


@pytest.mark.parametrize("case", range(4))
def test_conformance_random_dbmarts(tmp_path, case):
    rng = np.random.default_rng(500 + case)
    db = random_dbmart(rng)
    thr = int(rng.integers(1, 4))
    frames = {e: fit_engine(e, db, tmp_path, threshold=thr,
                            screen=("hash", "sorted")[case % 2],
                            router=("hash", "balance")[case % 2],
                            n_shards=4 if e == "sharded" else 1)
              for e in ENGINES}
    assert_frames_identical(frames)


@given(st.integers(0, 5000))
def test_conformance_property(s):
    rng = np.random.default_rng(s)
    db = random_dbmart(rng, n_patients=int(rng.integers(1, 8)),
                       max_events=int(rng.integers(2, 12)))
    thr = int(rng.integers(1, 4))
    screen = ("sorted", "hash")[int(rng.integers(2))]
    engines = ("batch", "chunked", "stream", "sharded")
    frames = {e: fit_engine(e, db, threshold=thr, screen=screen,
                            budget_bytes=int(rng.integers(8, 64)) << 10)
              for e in engines}
    assert_frames_identical(frames)


# --- planner -----------------------------------------------------------------
def test_plan_inspectable_and_overridable():
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=32, avg_events=16, seed=1)
    db = dbmart.from_rows(pats, dates, phx)
    sess = MiningSession(MiningConfig())
    assert sess.plan(db).engine == "batch"

    small = MiningConfig(budget_bytes=16 << 10)
    p = MiningSession(small).plan(db)
    assert p.engine == "chunked" and p.n_chunks > 1
    assert "chunked" in str(p) and "chunks" in str(p)

    p = MiningSession(small.replace(spill_bytes=1)).plan(db)
    assert p.engine == "files"
    # spill is a host-RAM decision: it must fire without a device budget too
    p = MiningSession(MiningConfig(spill_bytes=1)).plan(db)
    assert p.engine == "files"

    p = MiningSession(MiningConfig(n_shards=2)).plan(db)
    assert p.engine == "sharded"

    p = MiningSession(MiningConfig(engine="stream")).plan(db)
    assert p.engine == "stream" and "override" in p.reason

    # incremental sessions plan stream/sharded
    assert MiningSession(MiningConfig()).plan().engine == "stream"
    assert MiningSession(MiningConfig(n_shards=4)).plan().engine == "sharded"

    # fit records the plan it executed
    sess = MiningSession(small)
    sess.fit(db)
    assert sess.plan().engine == "chunked"


def test_config_validation():
    with pytest.raises(ValueError):
        MiningConfig(codec="nope")
    with pytest.raises(ValueError):
        MiningConfig(screen="exact")
    with pytest.raises(ValueError):
        MiningConfig(engine="gpu")
    with pytest.raises(ValueError):
        MiningConfig(n_shards=0)
    # fused screening compacts survivors during fit: threshold is required
    with pytest.raises(ValueError):
        MiningConfig(screen="fused")
    assert MiningConfig(screen="fused", threshold=3).screen == "fused"


def test_fused_plan_is_corpus_free():
    """The planner's second budget regime: under screen='fused' the
    working set is one patient block + the table, not the whole corpus —
    so a budget that forces chunking on the materializing path stays
    'batch' on the fused one, and the plan says why."""
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=1024, avg_events=16, seed=9)
    db = dbmart.from_rows(pats, dates, phx)
    budget = 1 << 24
    dense = MiningSession(MiningConfig(budget_bytes=budget,
                                       screen="hash")).plan(db)
    fused = MiningSession(MiningConfig(budget_bytes=budget, threshold=3,
                                       screen="fused",
                                       n_buckets_log2=H)).plan(db)
    assert dense.engine == "chunked" and not dense.corpus_free
    assert fused.engine == "batch" and fused.corpus_free
    assert fused.working_set_bytes < dense.working_set_bytes
    assert "corpus-free" in str(fused)


# --- frame semantics vs hand-wired core flows --------------------------------
def _handwired(db):
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    return tuple(np.asarray(x) for x in mining.flatten(mined))


def _triples(seq, dur, pat, keep):
    return sorted(zip(seq[keep].tolist(), dur[keep].tolist(),
                      pat[keep].tolist()))


def test_frame_masks_match_handwired():
    rng = np.random.default_rng(11)
    db = random_dbmart(rng, n_patients=10, max_events=16)
    seq, dur, pat, msk = _handwired(db)
    frame = MiningSession(MiningConfig(threshold=2)).fit(db)
    x = int(np.asarray(db.phenx)[0, 0])

    got = frame.starts_with(x).collect()
    ref = np.asarray(queries.starts_with(seq, x)) & msk
    assert _triples(got.seq, got.dur, got.patient,
                    np.ones(len(got.seq), bool)) == _triples(seq, dur, pat, ref)

    got = frame.min_duration(30).collect()
    ref = np.asarray(queries.min_duration(dur, 30)) & msk
    assert _triples(got.seq, got.dur, got.patient,
                    np.ones(len(got.seq), bool)) == _triples(seq, dur, pat, ref)

    got = frame.transitive_ends_with(x).collect()
    ref = np.asarray(queries.transitive_ends_with(seq, msk, x)) & msk
    assert _triples(got.seq, got.dur, got.patient,
                    np.ones(len(got.seq), bool)) == _triples(seq, dur, pat, ref)

    # exact screen == screen_sorted's kept multiset
    scr = sparsity.screen_sorted(seq, dur, pat, msk, 2)
    got = frame.screen().collect()
    n = int(scr.n_kept)
    assert _triples(got.seq, got.dur, got.patient,
                    np.ones(len(got.seq), bool)) \
        == sorted(zip(np.asarray(scr.seq)[:n].tolist(),
                      np.asarray(scr.dur)[:n].tolist(),
                      np.asarray(scr.patient)[:n].tolist()))
    # support column matches support_counts' per-sequence table
    _, _, _, u_key, u_sup, _ = sparsity.support_counts(seq, pat, msk)
    table = dict(zip(np.asarray(u_key).tolist(), np.asarray(u_sup).tolist()))
    assert all(table[s] == sup
               for s, sup in zip(got.seq.tolist(), got.support.tolist()))


def test_frame_top_k_and_features():
    rng = np.random.default_rng(7)
    db = random_dbmart(rng, n_patients=12, max_events=16)
    frame = MiningSession(MiningConfig()).fit(db)
    ids, sup = frame.unique()
    k = min(5, len(ids))
    top = frame.top_k(k)
    tids, tsup = top.unique()
    assert len(tids) == k
    # every kept id's support >= any dropped id's support
    dropped = np.setdiff1d(ids, tids)
    if len(dropped) and len(tids):
        drop_sup = sup[np.searchsorted(ids, dropped)]
        assert tsup.min() >= drop_sup.max()

    # degenerate k never crashes: empty result, empty feature matrix
    assert frame.top_k(0).n_kept == 0
    assert np.asarray(frame.to_features(k=0).x).shape[1] == 0

    fm = frame.to_features()
    seq, dur, pat, msk = _handwired(db)
    ref = msmr.feature_matrix(seq, pat, msk, np.sort(ids),
                              n_patients=db.n_patients)
    assert np.asarray(fm.x).tobytes() == np.asarray(ref.x).tobytes()
    # lazy chaining doesn't mutate the source frame
    assert frame.n_kept == len(frame)


def test_frame_empty_cohort():
    from repro.data.dbmart import DBMart

    db = DBMart(np.zeros((2, 8), np.int32), np.zeros((2, 8), np.int32),
                np.zeros(2, np.int32), None)
    frame = MiningSession(MiningConfig(threshold=1)).fit(db)
    assert len(frame) == 0 and frame.screen().n_kept == 0
    r = frame.screen().top_k(3).collect()
    assert len(r.seq) == 0
    fm = frame.to_features()
    assert np.asarray(fm.x).shape[1] == 0


# --- incremental input -------------------------------------------------------
def test_incremental_equals_batch_fit():
    rng = np.random.default_rng(23)
    db = random_dbmart(rng, n_patients=8, max_events=14)
    batch = MiningSession(MiningConfig(threshold=2, n_buckets_log2=H,
                                       screen="hash")).fit(db)

    sess = MiningSession(MiningConfig(threshold=2, n_buckets_log2=H,
                                      screen="hash", tick_patients=2))
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        cut = n // 2
        if cut:
            sess.submit(p, db.date[p, :cut], db.phenx[p, :cut])
        if n - cut:
            sess.submit(p, db.date[p, cut:n], db.phenx[p, cut:n])
    f = sess.tick()                      # one wave, then drain
    assert f is not None
    final = sess.run()
    assert sess.plan().engine == "stream"

    br, fr = batch.screen().collect(), final.screen().collect()
    for a, b in zip(br, fr):
        assert a.tobytes() == b.tobytes()


def test_frame_after_batch_fit_and_mode_guards():
    """frame() after a batch fit returns the fit result (it must not
    silently spawn an empty streaming service), and a fitted session
    refuses incremental submit."""
    rng = np.random.default_rng(31)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    sess = MiningSession(MiningConfig(threshold=1))
    fitted = sess.fit(db)
    assert sess.frame() is fitted
    assert sess.service is None
    with pytest.raises(RuntimeError):
        sess.submit(0, [1], [2])
    # frame() before any input must not spawn a service as a side effect
    fresh = MiningSession(MiningConfig())
    with pytest.raises(RuntimeError):
        fresh.frame()
    assert fresh.service is None
    fresh.submit(0, [1, 2], [3, 4])
    assert fresh.run().n_kept == 1


def test_files_engine_cleans_tmp_spill(tmp_path, monkeypatch):
    import os
    import tempfile as tf

    monkeypatch.setattr(tf, "tempdir", str(tmp_path))
    rng = np.random.default_rng(5)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    MiningSession(MiningConfig(engine="files", threshold=1)).fit(db)
    assert not [d for d in os.listdir(tmp_path)
                if d.startswith("tspm_spill_")]
    # an explicit spill_dir is the caller's: artifacts stay
    keep = tmp_path / "keep"
    MiningSession(MiningConfig(engine="files", threshold=1,
                               spill_dir=str(keep))).fit(db)
    assert (keep / "bucket_counts.npy").exists()


def test_service_queries_fuse_aware():
    """Regression (code review): StreamService.query_starts_with on a
    fused corpus unpacked raw ids — duration bits read as phenX."""
    from repro.stream.service import StreamService
    from repro.stream.shard import ShardedStreamService

    for svc in (StreamService(fuse_duration=True, n_buckets_log2=H),
                ShardedStreamService(n_shards=2, fuse_duration=True,
                                     n_buckets_log2=H)):
        svc.submit(0, [0, 40, 95], [2, 3, 4])
        svc.run()
        assert int(svc.query_starts_with(2).sum()) == 2
        assert int(svc.query_ends_with(4).sum()) == 2


def test_incremental_sharded_and_guards():
    sess = MiningSession(MiningConfig(n_shards=3, tick_patients=2,
                                      n_buckets_log2=H))
    sess.submit("a", [1, 2], [3, 4])
    sess.submit("b", [1], [5])
    frame = sess.run()
    assert sess.plan().engine == "sharded"
    assert len(frame) == 1               # only patient 'a' mined one pair
    with pytest.raises(RuntimeError):
        sess.fit(random_dbmart(np.random.default_rng(0)))
    with pytest.raises(ValueError):
        MiningSession(MiningConfig(engine="batch")).submit("a", [1], [2])


def test_keep_mask_memoized_per_prefix(monkeypatch):
    """Chained frames share forced-op work: ``f.screen().starts_with(x)``
    and its extensions run each underlying query op exactly once per
    op-chain prefix on the shared corpus, whichever frame forces first."""
    rng = np.random.default_rng(41)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    frame = MiningSession(MiningConfig(threshold=2, screen="hash",
                                       n_buckets_log2=H)).fit(db)
    code = int(np.unique(db.phenx[db.phenx >= 0])[0])
    calls = {"starts_with": 0, "min_duration": 0}
    real_sw, real_md = queries.starts_with, queries.min_duration

    def counting_sw(*a, **kw):
        calls["starts_with"] += 1
        return real_sw(*a, **kw)

    def counting_md(*a, **kw):
        calls["min_duration"] += 1
        return real_md(*a, **kw)

    monkeypatch.setattr(queries, "starts_with", counting_sw)
    monkeypatch.setattr(queries, "min_duration", counting_md)

    f1 = frame.screen().starts_with(code)      # ONE starts_with closure,
    f2 = f1.min_duration(10)                   # shared by every extension
    f3 = f1.min_duration(10).top_k(4)
    want = f2.keep_mask().copy()               # forces screen+sw+md once
    assert calls == {"starts_with": 1, "min_duration": 1}
    f1.keep_mask()                             # pure prefix: fully cached
    f3.keep_mask()                             # new md closure: runs once
    assert calls == {"starts_with": 1, "min_duration": 2}
    f2.top_k(3).keep_mask()                    # extends a cached prefix
    f2.collect(); f2.unique(); f3.n_kept       # terminals reuse the cache
    assert calls == {"starts_with": 1, "min_duration": 2}
    assert f2.keep_mask().tobytes() == want.tobytes()
    # memoization never leaks across corpora
    other = MiningSession(MiningConfig(threshold=2, screen="hash",
                                       n_buckets_log2=H)).fit(db)
    other.screen().starts_with(code).keep_mask()
    assert calls["starts_with"] == 2

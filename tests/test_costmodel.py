"""Validate the analytic FLOP model against fully-unrolled compiled HLO.

With runtime_flags.UNROLL_SCANS every lax.scan unrolls, so XLA's cost
analysis counts every executed op — ground truth the analytic model must
match (tolerance covers elementwise ops the model ignores).
"""
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.analysis import costmodel
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch import specs
from repro.models import model as model_lib
from repro.models import runtime_flags
from repro.training import train_loop


@pytest.fixture
def unrolled():
    runtime_flags.UNROLL_SCANS = True
    yield
    runtime_flags.UNROLL_SCANS = False


def _hlo_flops(fn, *args):
    return compat.hlo_flops(jax.jit(fn).lower(*args))


FAMILIES = ["tspm-mlho", "gemma2-2b", "deepseek-moe-16b", "xlstm-125m",
            "zamba2-2.7b", "seamless-m4t-large-v2", "pixtral-12b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_train_flops_model_matches_unrolled_hlo(arch, unrolled):
    cfg = get_config(arch, reduced=True).replace(remat="none",
                                                 capacity_factor=1.25)
    mdl = model_lib.build(cfg)
    params, _ = mdl.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("t", 64, 2, "train")
    batch = specs.train_batch(cfg, shape, concrete=True)
    loss_fn = train_loop.make_loss_fn(mdl, z_coef=0.0)

    got = _hlo_flops(
        lambda p, b: jax.value_and_grad(lambda q: loss_fn(q, b)[0])(p),
        params, batch)
    want = costmodel.step_flops(cfg, shape)
    ratio = got / want
    assert 0.75 < ratio < 1.45, (arch, got, want, ratio)


@pytest.mark.parametrize("arch", ["gemma2-2b", "xlstm-125m", "zamba2-2.7b"])
def test_decode_flops_model(arch, unrolled):
    cfg = get_config(arch, reduced=True)
    mdl = model_lib.build(cfg)
    params, _ = mdl.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("d", 32, 2, "decode")
    caches = mdl.init_caches(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)

    got = _hlo_flops(
        lambda p, c: mdl.apply(p, {"tokens": tok}, mode="decode", caches=c),
        params, caches)
    want = costmodel.step_flops(cfg, shape)
    ratio = got / want
    assert 0.5 < ratio < 2.0, (arch, got, want, ratio)


def test_flops_scale_linearly_in_depth():
    cfg = get_config("tspm-mlho", reduced=True)
    s1 = costmodel.step_flops(cfg.replace(n_layers=2),
                              ShapeConfig("t", 128, 4, "train"))
    s2 = costmodel.step_flops(cfg.replace(n_layers=4),
                              ShapeConfig("t", 128, 4, "train"))
    per_layer = s2 - s1
    s3 = costmodel.step_flops(cfg.replace(n_layers=6),
                              ShapeConfig("t", 128, 4, "train"))
    assert abs((s3 - s2) - per_layer) / per_layer < 1e-6


def test_bytes_model_orders():
    """Train touches optimizer state; decode is weight-dominated."""
    cfg = get_config("gemma2-2b")
    _, active = __import__("repro.analysis.roofline",
                           fromlist=["count_params"]).count_params(cfg)
    train = costmodel.step_bytes(cfg, ShapeConfig("t", 4096, 256, "train"),
                                 active)
    decode = costmodel.step_bytes(cfg, ShapeConfig("d", 32768, 128, "decode"),
                                  active)
    assert train > active * 20          # adam states dominate
    assert decode > active * 2          # weights read once per token

"""Tiered storage: codec exactness, blockstore durability, tier walk,
checkpoint state trees, and the Saver concurrency contract.

The codec invariant everything above relies on is *exact roundtrip for any
int32 input* — not just clinically-shaped monotone dates — so the property
tests here throw adversarial blocks at it (empty, single-event, duplicate
timestamps, unsorted dates, int32 extremes, dictionary escapes).  The
hypothesis variants explore deeper when hypothesis is installed; seeded
loops cover offline environments.
"""
import json
import threading

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.storage import blockstore as blockstore_lib
from repro.storage.blockstore import CompressedBlockStore
from repro.storage.codec import (CodeDictionary, decode_block, decode_key,
                                 encode_block, encode_key, varint_decode,
                                 varint_encode, zigzag_decode, zigzag_encode)
from repro.storage.state import pack_tree, unpack_tree
from repro.storage.tiers import DiskTier, HostTier, ResidencyTier
from repro.stream.store import PatientStore
from repro.training import checkpoint as ckpt_lib

I32 = np.iinfo(np.int32)


def _roundtrip(phenx, date, dictionary=None):
    blob = encode_block(phenx, date, dictionary)
    ph, dt = decode_block(blob, dictionary)
    assert ph.dtype == np.int32 and dt.dtype == np.int32
    np.testing.assert_array_equal(ph, np.asarray(phenx, np.int32))
    np.testing.assert_array_equal(dt, np.asarray(date, np.int32))
    return blob


# --- codec ------------------------------------------------------------------
def test_codec_roundtrip_edge_blocks():
    empty = np.zeros(0, np.int32)
    _roundtrip(empty, empty)                            # empty history
    _roundtrip([7], [100])                              # single event
    _roundtrip([3, 3, 3], [50, 50, 50])                 # duplicate timestamps
    _roundtrip([1, 2, 3], [300, 200, 100])              # unsorted (neg deltas)
    _roundtrip([I32.min, I32.max, 0, -1],
               [I32.max, I32.min, 0, -1])               # int32 extremes


def test_codec_roundtrip_seeded_random():
    rng = np.random.default_rng(42)
    for trial in range(200):
        n = int(rng.integers(0, 40))
        if rng.random() < 0.5:   # clinical shape: small codes, sorted dates
            ph = rng.integers(0, 200, n).astype(np.int32)
            dt = np.sort(rng.integers(0, 2000, n)).astype(np.int32)
        else:                    # adversarial: full int32 range, unsorted
            ph = rng.integers(I32.min, I32.max, n, dtype=np.int64) \
                .astype(np.int32)
            dt = rng.integers(I32.min, I32.max, n, dtype=np.int64) \
                .astype(np.int32)
        d = (CodeDictionary.from_histories([ph[: n // 2]])
             if rng.random() < 0.5 else None)
        _roundtrip(ph, dt, d)


@given(st.lists(st.tuples(st.integers(I32.min, I32.max),
                          st.integers(I32.min, I32.max)), max_size=60),
       st.booleans())
def test_codec_roundtrip_hypothesis(events, use_dict):
    ph = np.asarray([e[0] for e in events], np.int32)
    dt = np.asarray([e[1] for e in events], np.int32)
    d = CodeDictionary.from_histories([ph[::2]]) if use_dict else None
    _roundtrip(ph, dt, d)


def test_codec_compresses_clinical_shape():
    """>=3x on synthea-shaped monotone histories (the bench floor)."""
    rng = np.random.default_rng(0)
    raw = enc = 0
    d = CodeDictionary(list(range(200)))
    for _ in range(50):
        n = int(rng.integers(10, 60))
        ph = rng.integers(0, 200, n).astype(np.int32)
        dt = np.sort(rng.integers(0, 700, n)).astype(np.int32)
        enc += len(encode_block(ph, dt, d))
        raw += 8 * n
    assert raw / enc >= 3.0


def test_varint_vectorized_matches_scalar():
    rng = np.random.default_rng(3)
    vals = np.concatenate([
        np.zeros(3, np.uint64),
        rng.integers(0, 1 << 35, 100, dtype=np.uint64),
        np.asarray([1, 127, 128, (1 << 35) - 1], np.uint64)])
    buf = varint_encode(vals)
    np.testing.assert_array_equal(varint_decode(buf, len(vals)), vals)
    with pytest.raises(ValueError):
        varint_encode(np.asarray([1 << 35], np.uint64))
    with pytest.raises(ValueError):
        varint_decode(buf[:1], len(vals))   # truncated stream


def test_zigzag_small_magnitudes_stay_small():
    v = np.asarray([0, -1, 1, -2, 2], np.int64)
    u = zigzag_encode(v)
    np.testing.assert_array_equal(u, [0, 1, 2, 3, 4])
    np.testing.assert_array_equal(zigzag_decode(u), v)


def test_dictionary_escape_side_stream():
    d = CodeDictionary([10, 20, 30])
    ph = np.asarray([10, 999, 20, -5, 30], np.int32)   # 999/-5 escape
    dt = np.asarray([1, 2, 3, 4, 5], np.int32)
    _roundtrip(ph, dt, d)
    assert CodeDictionary.from_json(d.to_json()) == d
    with pytest.raises(ValueError):
        decode_block(encode_block(ph, dt, d), None)  # dict required


def test_encode_key_typed_roundtrip():
    for key in [0, -3, 2**40, "p1", ("a", 7), (1, ("x", 2))]:
        assert decode_key(json.loads(json.dumps(encode_key(key)))) == key
    assert decode_key(encode_key(np.int32(5))) == 5
    with pytest.raises(TypeError):
        encode_key(True)
    with pytest.raises(TypeError):
        encode_key(3.5)


# --- blockstore -------------------------------------------------------------
def test_blockstore_persist_reopen(tmp_path):
    root = str(tmp_path / "bs")
    d = CodeDictionary([1, 2, 3])
    bs = CompressedBlockStore(root, dictionary=d)
    bs.put("a", [1, 2], [10, 20])
    bs.put(("t", 5), [3], [7])
    bs.close()
    re = CompressedBlockStore(root)          # dictionary loads from index
    assert re.dictionary == d
    ph, dt = re.get("a")
    assert ph.tolist() == [1, 2] and dt.tolist() == [10, 20]
    assert re.n_events(("t", 5)) == 1
    assert len(re) == 2 and set(re.keys()) == {"a", ("t", 5)}
    with pytest.raises(ValueError):
        CompressedBlockStore(root, dictionary=CodeDictionary([9]))


def test_blockstore_checksum_detects_corruption(tmp_path):
    root = str(tmp_path / "bs")
    bs = CompressedBlockStore(root)
    bs.put("k", list(range(20)), list(range(20)))
    bs.close()
    with open(str(tmp_path / "bs" / blockstore_lib.DATA_NAME), "r+b") as f:
        f.seek(4)
        f.write(b"\xff\xff")
    re = CompressedBlockStore(root)
    with pytest.raises(IOError):
        re.get("k")


def test_blockstore_compaction_bounds_garbage(tmp_path, monkeypatch):
    monkeypatch.setattr(blockstore_lib, "COMPACT_FLOOR_BYTES", 64)
    bs = CompressedBlockStore(str(tmp_path / "bs"))
    keep = {}
    rng = np.random.default_rng(5)
    for i in range(60):
        ph = rng.integers(0, 50, 10).astype(np.int32)
        dt = np.sort(rng.integers(0, 300, 10)).astype(np.int32)
        bs.put(i, ph, dt)
        keep[i] = (ph, dt)
        if i >= 3:                    # churn: drop an old block each round
            bs.discard(i - 3)
            del keep[i - 3]
    assert bs.dead_bytes <= max(bs.bytes_held, 64)
    for k, (ph, dt) in keep.items():  # survivors intact post-compaction
        got = bs.get(k)
        assert got[0].tolist() == ph.tolist()
        assert got[1].tolist() == dt.tolist()


# --- tiers ------------------------------------------------------------------
@pytest.mark.parametrize("tier_cls", [HostTier, DiskTier])
def test_tier_contract(tier_cls, tmp_path):
    tier = (DiskTier(str(tmp_path / "d")) if tier_cls is DiskTier
            else HostTier())
    assert isinstance(tier, ResidencyTier)
    tier.hold("a", [1, 2], [5, 6])
    tier.hold("b", [3], [9])
    assert "a" in tier and len(tier) == 2
    assert tier.keys() == ["a", "b"]          # insertion order: LRU walk
    tier.hold("a", [1, 2], [5, 6])            # re-hold moves to the back
    assert tier.keys() == ["b", "a"]
    assert tier.event_counts() == {"b": 1, "a": 2}
    ph, dt = tier.peek("b")
    assert ph.tolist() == [3] and "b" in tier  # peek does not withdraw
    ph, dt = tier.restore("b")
    assert ph.tolist() == [3] and "b" not in tier
    assert tier.bytes_held() > 0
    tier.drop("a")
    assert len(tier) == 0


# --- tiered store -----------------------------------------------------------
def _fill_store(store, rng, n=12):
    hist = {}
    for k in range(n):
        m = int(rng.integers(3, 15))
        ph = rng.integers(1, 50, m).astype(np.int32)
        dt = np.sort(rng.integers(0, 300, m)).astype(np.int32)
        hist[k] = (ph, dt)
        rows, _ = store.admit([k])
        store.append(rows, ph[None], dt[None], np.asarray([m], np.int32))
        store.evict_over_budget()
    return hist


def test_store_demotes_host_spill_to_disk():
    rng = np.random.default_rng(0)
    store = PatientStore(budget_bytes=4000, disk_bytes=2000)
    hist = _fill_store(store, rng)
    tiers = {k: store.tier_of(k) for k in hist}
    assert "disk" in tiers.values(), "disk budget never demoted"
    assert None not in tiers.values()
    for k, (ph, dt) in hist.items():          # every tier restores exactly
        got = store.history(k)
        assert got[0].tolist() == ph.tolist()
        assert got[1].tolist() == dt.tolist()
    assert store.event_counts() == {k: len(v[0]) for k, v in hist.items()}
    held = {k for k, _, _ in store.iter_held()}
    assert held == {k for k in hist if k not in store.rows}
    for k in hist:                            # promotion through admit
        store.admit([k])
        assert store.tier_of(k) == "device"
        got = store.history(k)
        assert got[0].tolist() == hist[k][0].tolist()


def test_store_without_disk_budget_keeps_host_tier_only():
    rng = np.random.default_rng(1)
    store = PatientStore(budget_bytes=4000)
    hist = _fill_store(store, rng)
    assert store.disk is None
    assert all(store.tier_of(k) in ("device", "host") for k in hist)


def test_store_extract_from_disk_tier():
    rng = np.random.default_rng(2)
    store = PatientStore(budget_bytes=4000, disk_bytes=0)  # everything demotes
    hist = _fill_store(store, rng, n=6)
    key = next(k for k in hist if store.tier_of(k) == "disk")
    pid, ph, dt = store.extract(key)
    assert ph.tolist() == hist[key][0].tolist()
    assert store.tier_of(key) is None and key not in store.pids


def test_store_state_dict_roundtrip_preserves_tiers():
    rng = np.random.default_rng(3)
    store = PatientStore(budget_bytes=4000, disk_bytes=2000)
    hist = _fill_store(store, rng)
    packed, arrays = pack_tree(store.state_dict())
    json.dumps(packed)                         # manifest-serializable
    other = PatientStore(budget_bytes=4000, disk_bytes=2000)
    other.load_state_dict(unpack_tree(packed, arrays))
    assert np.array_equal(np.asarray(store.phenx), np.asarray(other.phenx))
    assert store.rows == other.rows and store.pids == other.pids
    assert store._free == other._free
    assert {k: store.tier_of(k) for k in hist} \
        == {k: other.tier_of(k) for k in hist}
    for k in hist:
        a, b = store.history(k), other.history(k)
        assert a[0].tolist() == b[0].tolist()
        assert a[1].tolist() == b[1].tolist()


# --- state trees ------------------------------------------------------------
def test_pack_tree_roundtrip():
    tree = {"a": np.arange(5), "b": [np.zeros((2, 3), np.int64), "x", None],
            "c": {"d": np.int32(7), "e": 1.5, "f": True}}
    packed, arrays = pack_tree(tree)
    json.dumps(packed)
    out = unpack_tree(packed, arrays)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"][0], tree["b"][0])
    assert out["b"][1:] == ["x", None]
    assert out["c"] == {"d": 7, "e": 1.5, "f": True}


def test_pack_tree_rejects_non_json_leaves():
    with pytest.raises(TypeError):
        pack_tree({"bad": object()})
    with pytest.raises(ValueError):
        pack_tree({"__ndarray__": 1})


# --- checkpoint layer -------------------------------------------------------
def test_checkpoint_load_without_reference_tree(tmp_path):
    arrays = [np.arange(4), np.ones((2, 2), np.float32)]
    path = ckpt_lib.save(str(tmp_path), 3, arrays, extra={"k": "v"})
    leaves, manifest = ckpt_lib.load(path)
    assert manifest["extra"] == {"k": "v"} and manifest["step"] == 3
    np.testing.assert_array_equal(leaves[0], arrays[0])
    np.testing.assert_array_equal(leaves[1], arrays[1])


def test_concurrent_savers_drop_no_writes(tmp_path):
    """Two independent Savers flushing concurrently must both land (the
    pre-refactor module-global pending thread could forget one)."""
    savers = [ckpt_lib.Saver() for _ in range(2)]
    dirs = [str(tmp_path / f"s{i}") for i in range(2)]
    barrier = threading.Barrier(2)

    def work(i):
        barrier.wait()
        for step in range(5):
            savers[i].save_async(dirs[i], step, [np.full(8, step)])
        savers[i].wait()

    threads = [threading.Thread(target=work, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(2):
        path = ckpt_lib.latest(dirs[i])
        assert path is not None and path.endswith("step_00000004")
        leaves, _ = ckpt_lib.load(path)
        np.testing.assert_array_equal(leaves[0], np.full(8, 4))


def test_saver_wait_is_idempotent(tmp_path):
    s = ckpt_lib.Saver()
    s.wait()                                   # nothing pending: no-op
    s.save_async(str(tmp_path), 0, [np.arange(3)])
    s.wait()
    s.wait()
    assert ckpt_lib.latest(str(tmp_path)) is not None


def test_module_shims_still_work(tmp_path):
    ckpt_lib.save_async(str(tmp_path), 1, [np.arange(2)])
    ckpt_lib.wait()
    leaves, manifest = ckpt_lib.load(ckpt_lib.latest(str(tmp_path)))
    assert manifest["step"] == 1


def test_random_store_tier_walk_vs_dict_oracle():
    """Chaos: random admits/appends/evicts/extracts against a plain dict
    oracle — whatever tier a history lands in, reads stay exact."""
    rng = np.random.default_rng(11)
    store = PatientStore(budget_bytes=3000, disk_bytes=1000)
    oracle: dict = {}
    next_key = 0
    for _ in range(150):
        r = rng.random()
        if r < 0.45 or not oracle:
            k, next_key = next_key, next_key + 1
            m = int(rng.integers(1, 10))
            ph = rng.integers(0, 99, m).astype(np.int32)
            dt = np.sort(rng.integers(0, 400, m)).astype(np.int32)
            rows, _ = store.admit([k])
            store.append(rows, ph[None], dt[None], np.asarray([m], np.int32))
            oracle[k] = (ph, dt)
        elif r < 0.7:
            store.evict_over_budget()
        elif r < 0.85:
            k = list(oracle)[int(rng.integers(len(oracle)))]
            _, ph, dt = store.extract(k)
            np.testing.assert_array_equal(ph, oracle.pop(k)[0])
        else:
            k = list(oracle)[int(rng.integers(len(oracle)))]
            ph, dt = store.history(k)
            np.testing.assert_array_equal(ph, oracle[k][0])
            np.testing.assert_array_equal(dt, oracle[k][1])
    assert store.event_counts() == {k: len(v[0]) for k, v in oracle.items()}

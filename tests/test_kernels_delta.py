"""Delta Pallas kernel vs jnp oracle: shape sweeps + slab-union property."""
import numpy as np
import pytest

from repro.core import mining
from repro.kernels.tspm_delta import delta as delta_kernel
from repro.kernels.tspm_delta import ops, ref
from repro.stream import delta as stream_delta
from tests.conftest import random_dbmart


def split_delta(db, frac=0.5):
    """(n_old, n_new, new_phenx, new_date) splitting each history at frac."""
    n_old = (db.nevents * frac).astype(np.int32)
    n_new = (db.nevents - n_old).astype(np.int32)
    D = max(int(n_new.max(initial=1)), 1)
    new_ph = np.zeros((db.n_patients, D), np.int32)
    new_dt = np.zeros((db.n_patients, D), np.int32)
    for p in range(db.n_patients):
        o, n = int(n_old[p]), int(db.nevents[p])
        new_ph[p, : n - o] = db.phenx[p, o:n]
        new_dt[p, : n - o] = db.date[p, o:n]
    return n_old, n_new, new_ph, new_dt


@pytest.mark.parametrize("P,E", [(1, 8), (3, 16), (8, 48), (7, 130)])
def test_delta_kernel_matches_jnp(P, E):
    db = random_dbmart(np.random.default_rng(P * 100 + E),
                       n_patients=P, max_events=E)
    n_old, n_new, new_ph, new_dt = split_delta(db)
    got = ops.delta_pairgen(db.phenx, db.date, n_old, n_new, new_ph, new_dt,
                            interpret=True)
    want = stream_delta.delta_mine_jnp(db.phenx, db.date, n_old, n_new,
                                       new_ph, new_dt)
    m = np.asarray(want.mask)
    assert (np.asarray(got.mask) == m).all()
    assert (np.asarray(got.seq)[m] == np.asarray(want.seq)[m]).all()
    assert (np.asarray(got.dur)[m] == np.asarray(want.dur)[m]).all()


def test_delta_planes_kernel_matches_planes_ref():
    db = random_dbmart(np.random.default_rng(2), n_patients=8, max_events=32)
    n_old, n_new, new_ph, new_dt = split_delta(db)
    ph = np.zeros((8, 128), np.int32)
    dt = np.zeros((8, 128), np.int32)
    ph[:, :32] = db.phenx[:, :32]
    dt[:, :32] = db.date[:, :32]
    nph = np.zeros((8, 128), np.int32)
    ndt = np.zeros((8, 128), np.int32)
    nph[:, : new_ph.shape[1]] = new_ph
    ndt[:, : new_dt.shape[1]] = new_dt
    outs = delta_kernel.delta_planes(ph, dt, n_old, n_new, nph, ndt,
                                     pb=8, ti=128, tj=128, interpret=True)
    refs = ref.delta_planes_ref(ph, dt, n_old, n_new, nph, ndt)
    for got, want in zip(outs, refs):
        assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("codec,fuse", [("bit", False), ("paper", True)])
def test_delta_codecs_and_fusion(codec, fuse):
    db = random_dbmart(np.random.default_rng(5), n_patients=6, max_events=20)
    n_old, n_new, new_ph, new_dt = split_delta(db)
    got = ops.delta_pairgen(db.phenx, db.date, n_old, n_new, new_ph, new_dt,
                            codec=codec, fuse_duration=fuse, interpret=True)
    want = stream_delta.delta_mine_jnp(db.phenx, db.date, n_old, n_new,
                                       new_ph, new_dt, codec=codec,
                                       fuse_duration=fuse)
    m = np.asarray(want.mask)
    assert (np.asarray(got.seq)[m] == np.asarray(want.seq)[m]).all()


def test_old_pairs_plus_delta_slab_is_full_mine():
    """The streaming invariant at one split point: mine(n_old) + delta slab
    == mine(n) as multisets of (patient, seq, dur)."""
    for s in range(4):
        db = random_dbmart(np.random.default_rng(s), n_patients=5)
        n_old, n_new, new_ph, new_dt = split_delta(db, frac=0.4)
        slab = stream_delta.delta_mine_jnp(db.phenx, db.date, n_old, n_new,
                                           new_ph, new_dt)
        old = mining.mine_triangular(db.phenx, db.date, n_old)
        os_, od, op, om = (np.asarray(x) for x in mining.flatten(old))
        sm = np.asarray(slab.mask)
        got = sorted(
            list(zip(op[om], os_[om], od[om]))
            + [(p, s_, d_) for p in range(db.n_patients)
               for s_, d_ in zip(np.asarray(slab.seq)[p][sm[p]],
                                 np.asarray(slab.dur)[p][sm[p]])])
        full = mining.mine_triangular(db.phenx, db.date, db.nevents)
        fs, fd, fp, fm = (np.asarray(x) for x in mining.flatten(full))
        assert got == sorted(zip(fp[fm], fs[fm], fd[fm]))


def _assert_kernel_matches_jnp(phenx, date, n_old, n_new, new_ph, new_dt):
    got = ops.delta_pairgen(phenx, date, n_old, n_new, new_ph, new_dt,
                            interpret=True)
    want = stream_delta.delta_mine_jnp(phenx, date, n_old, n_new,
                                       new_ph, new_dt)
    assert got.mask.shape == want.mask.shape
    m = np.asarray(want.mask)
    assert (np.asarray(got.mask) == m).all()
    assert (np.asarray(got.seq)[m] == np.asarray(want.seq)[m]).all()
    assert (np.asarray(got.dur)[m] == np.asarray(want.dur)[m]).all()
    return m


def test_delta_kernel_empty_delta_window():
    """d == 0 for every patient: the j-grid is all padding, no pair is
    valid, and the D == 0 slab shape round-trips."""
    db = random_dbmart(np.random.default_rng(0), n_patients=4, max_events=16)
    zeros = np.zeros(db.n_patients, np.int32)
    # D > 0 planes but no new events anywhere
    m = _assert_kernel_matches_jnp(
        db.phenx, db.date, np.asarray(db.nevents, np.int32), zeros,
        np.zeros((db.n_patients, 4), np.int32),
        np.zeros((db.n_patients, 4), np.int32))
    assert not m.any()
    # literally zero-width delta planes (D == 0)
    m = _assert_kernel_matches_jnp(
        db.phenx, db.date, np.asarray(db.nevents, np.int32), zeros,
        np.zeros((db.n_patients, 0), np.int32),
        np.zeros((db.n_patients, 0), np.int32))
    assert m.size == 0


def test_delta_kernel_mixed_empty_rows():
    """Some patients contribute no delta this wave (d == 0 rows inside a
    nonempty batch) — their slab rows must be fully masked."""
    db = random_dbmart(np.random.default_rng(1), n_patients=6, max_events=12)
    n_old, n_new, new_ph, new_dt = split_delta(db)
    n_new[::2] = 0
    m = _assert_kernel_matches_jnp(db.phenx, db.date, n_old, n_new,
                                   new_ph, new_dt)
    assert not m[::2].any()


def test_delta_kernel_single_event_history():
    """n_old == 1 everywhere: the smallest non-degenerate i-extent, plus
    the first-ever delta case n_old == 0 for one patient."""
    rng = np.random.default_rng(2)
    P, E, D = 3, 8, 5
    phenx = rng.integers(0, 30, (P, E)).astype(np.int32)
    date = np.sort(rng.integers(0, 100, (P, E)).astype(np.int32), axis=1)
    n_old = np.asarray([1, 1, 0], np.int32)
    n_new = np.asarray([D, 1, 2], np.int32)
    new_ph = rng.integers(0, 30, (P, D)).astype(np.int32)
    new_dt = np.sort(rng.integers(100, 200, (P, D)).astype(np.int32), axis=1)
    m = _assert_kernel_matches_jnp(phenx, date, n_old, n_new, new_ph, new_dt)
    # patient 0: each new event pairs with the 1 old + earlier new events
    assert m[0].sum() == D + D * (D - 1) // 2
    # patient 2 (empty history): only new-x-new pairs
    assert m[2].sum() == 1


def test_delta_kernel_at_pad_and_tile_boundary():
    """E and D exactly at the 128 tile edge: no padding inserted, masks
    must still cut at n_old + j / n_new, not the tile."""
    rng = np.random.default_rng(3)
    P, E, D = 2, 128, 128
    phenx = rng.integers(0, 50, (P, E)).astype(np.int32)
    date = np.sort(rng.integers(0, 500, (P, E)).astype(np.int32), axis=1)
    n_old = np.asarray([E - D // 2, 96], np.int32)
    n_new = np.asarray([D // 2, D], np.int32)
    new_ph = rng.integers(0, 50, (P, D)).astype(np.int32)
    new_dt = np.sort(rng.integers(500, 900, (P, D)).astype(np.int32), axis=1)
    _assert_kernel_matches_jnp(phenx, date, n_old, n_new, new_ph, new_dt)


def test_delta_kernel_history_at_full_plane_capacity():
    """n_old + d == E: the updated history fills every plane slot (the
    store's regrowth edge just before a geometric doubling)."""
    rng = np.random.default_rng(4)
    P, E, D = 3, 16, 4
    phenx = rng.integers(0, 30, (P, E)).astype(np.int32)
    date = np.sort(rng.integers(0, 300, (P, E)).astype(np.int32), axis=1)
    n_new = np.asarray([D, D, D], np.int32)
    n_old = np.asarray([E - D] * P, np.int32)     # planes exactly full
    new_ph = phenx[:, E - D:]                      # delta lives at the tail
    new_dt = date[:, E - D:]
    m = _assert_kernel_matches_jnp(phenx, date, n_old, n_new, new_ph, new_dt)
    # every (i, j) with i < n_old + j is real: sum the closed form
    want = int(stream_delta.count_delta_pairs(n_old, n_new))
    assert m.sum() == want


def test_count_delta_pairs_closed_form():
    db = random_dbmart(np.random.default_rng(9), n_patients=7)
    n_old, n_new, new_ph, new_dt = split_delta(db, frac=0.3)
    slab = stream_delta.delta_mine_jnp(db.phenx, db.date, n_old, n_new,
                                       new_ph, new_dt)
    assert int(stream_delta.count_delta_pairs(n_old, n_new)) \
        == int(np.asarray(slab.mask).sum())


def test_delta_kernel_is_lowerable_for_tpu_style_blocks():
    import jax

    db = random_dbmart(np.random.default_rng(4), n_patients=8, max_events=100)
    n_old, n_new, new_ph, new_dt = split_delta(db)
    fn = lambda *a: ops.delta_pairgen(*a, interpret=True)
    jax.jit(fn).lower(db.phenx, db.date, n_old, n_new, new_ph, new_dt)

"""Utility-query helpers vs brute force (the paper's C++ helper functions)."""
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from repro.core import encoding, mining, queries
from tests.conftest import brute_force_pairs, random_dbmart


@given(st.integers(0, 5000))
def test_start_end_min_duration_masks(s):
    rng = np.random.default_rng(s)
    db = random_dbmart(rng)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    pairs = brute_force_pairs(db)
    if not pairs:
        return
    x = pairs[rng.integers(len(pairs))][1]
    d = int(rng.integers(0, 100))
    m_start = np.asarray(queries.starts_with(seq, x)) & msk
    m_end = np.asarray(queries.ends_with(seq, x)) & msk
    m_dur = np.asarray(queries.min_duration(dur, d)) & msk
    assert int(m_start.sum()) == sum(1 for (_, a, _, _) in pairs if a == x)
    assert int(m_end.sum()) == sum(1 for (_, _, b, _) in pairs if b == x)
    assert int(m_dur.sum()) == sum(1 for (_, _, _, dd) in pairs if dd >= d)


@given(st.integers(0, 5000))
def test_transitive_ends_with(s):
    rng = np.random.default_rng(s)
    db = random_dbmart(rng)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    pairs = brute_force_pairs(db)
    if not pairs:
        return
    x = pairs[rng.integers(len(pairs))][1]
    ends = {b for (_, a, b, _) in pairs if a == x}
    got = np.asarray(queries.transitive_ends_with(seq, msk, x)) & msk
    expect = sum(1 for (_, _, b, _) in pairs if b in ends)
    assert int(got.sum()) == expect


def test_fused_queries_match_unfused():
    """Duration-fused ids must decode through the fuse-aware path: the raw
    unpack reads duration bits as phenX (the pre-fix bug).  Fused masks
    must equal the unfused masks pair-for-pair (fusing only appends bucket
    bits; it never changes which (start, end) a row carries)."""
    rng = np.random.default_rng(77)
    db = random_dbmart(rng, n_patients=10, max_events=16, date_range=2000)
    plain = mining.mine_triangular(db.phenx, db.date, db.nevents)
    fused = mining.mine_triangular(db.phenx, db.date, db.nevents,
                                   fuse_duration=True, bucket_days=30)
    pseq, _, _, msk = (np.asarray(x) for x in mining.flatten(plain))
    fseq, _, _, _ = (np.asarray(x) for x in mining.flatten(fused))
    pairs = brute_force_pairs(db)
    xs = {a for (_, a, _, _) in pairs} | {b for (_, _, b, _) in pairs}
    for x in sorted(xs)[:6]:
        ref_start = np.asarray(queries.starts_with(pseq, x)) & msk
        ref_end = np.asarray(queries.ends_with(pseq, x)) & msk
        got_start = np.asarray(queries.starts_with(fseq, x, fused=True)) & msk
        got_end = np.asarray(queries.ends_with(fseq, x, fused=True)) & msk
        assert (got_start == ref_start).all()
        assert (got_end == ref_end).all()
        ref_set = np.asarray(queries.end_set(pseq, msk, x))
        got_set = np.asarray(queries.end_set(fseq, msk, x, fused=True))
        assert (ref_set == got_set).all()
        ref_t = np.asarray(queries.transitive_ends_with(pseq, msk, x))
        got_t = np.asarray(queries.transitive_ends_with(fseq, msk, x,
                                                        fused=True))
        assert (ref_t == got_t).all()
    # regression: on a corpus with nonzero buckets the raw path *does*
    # mis-decode (this is what made fused snapshots silently wrong)
    buckets = np.asarray(encoding.split_duration(fseq[msk])[1])
    if (buckets > 0).any():
        x = next(a for (_, a, _, _) in pairs)
        raw = np.asarray(queries.starts_with(fseq, x)) & msk
        ref = np.asarray(queries.starts_with(pseq, x)) & msk
        assert (raw != ref).any()


def test_decode_sequence_fused():
    from repro.core.encoding import build_vocab, pack

    vocab = build_vocab([0], ["A", "B"])
    sid = int(np.asarray(pack(0, 1)))
    assert vocab.decode_sequence(sid) == "A -> B"
    fused_id = int(np.asarray(encoding.fuse_duration(sid, 3)))
    assert vocab.decode_sequence(fused_id, fused=True) == "A -> B [bucket 3]"


def test_end_set_padding_and_sorting():
    db = random_dbmart(np.random.default_rng(9))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, _, _, msk = mining.flatten(mined)
    x = int(np.asarray(db.phenx)[0, 0])
    table = np.asarray(queries.end_set(seq, msk, x))
    real = table[table != encoding.SENTINEL]
    assert (np.diff(real) > 0).all()  # strictly sorted = unique

"""Utility-query helpers vs brute force (the paper's C++ helper functions)."""
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from repro.core import encoding, mining, queries
from tests.conftest import brute_force_pairs, random_dbmart


@given(st.integers(0, 5000))
def test_start_end_min_duration_masks(s):
    rng = np.random.default_rng(s)
    db = random_dbmart(rng)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    pairs = brute_force_pairs(db)
    if not pairs:
        return
    x = pairs[rng.integers(len(pairs))][1]
    d = int(rng.integers(0, 100))
    m_start = np.asarray(queries.starts_with(seq, x)) & msk
    m_end = np.asarray(queries.ends_with(seq, x)) & msk
    m_dur = np.asarray(queries.min_duration(dur, d)) & msk
    assert int(m_start.sum()) == sum(1 for (_, a, _, _) in pairs if a == x)
    assert int(m_end.sum()) == sum(1 for (_, _, b, _) in pairs if b == x)
    assert int(m_dur.sum()) == sum(1 for (_, _, _, dd) in pairs if dd >= d)


@given(st.integers(0, 5000))
def test_transitive_ends_with(s):
    rng = np.random.default_rng(s)
    db = random_dbmart(rng)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    pairs = brute_force_pairs(db)
    if not pairs:
        return
    x = pairs[rng.integers(len(pairs))][1]
    ends = {b for (_, a, b, _) in pairs if a == x}
    got = np.asarray(queries.transitive_ends_with(seq, msk, x)) & msk
    expect = sum(1 for (_, _, b, _) in pairs if b in ends)
    assert int(got.sum()) == expect


def test_end_set_padding_and_sorting():
    db = random_dbmart(np.random.default_rng(9))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, _, _, msk = mining.flatten(mined)
    x = int(np.asarray(db.phenx)[0, 0])
    table = np.asarray(queries.end_set(seq, msk, x))
    real = table[table != encoding.SENTINEL]
    assert (np.diff(real) > 0).all()  # strictly sorted = unique

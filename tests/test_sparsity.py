"""Sparsity screening: sort-based exactness + hash-based one-sided error."""
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from repro.core import baseline_tspm, encoding, mining, sparsity
from tests.conftest import random_dbmart


def _oracle_support(db):
    """distinct-patient support per (start, end) string pair."""
    from collections import defaultdict

    pats = defaultdict(set)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        for i in range(n):
            for j in range(i + 1, n):
                pats[(int(db.phenx[p, i]), int(db.phenx[p, j]))].add(p)
    return {k: len(v) for k, v in pats.items()}


@given(st.integers(0, 10_000), st.integers(1, 6))
def test_screen_sorted_exact(s, threshold):
    db = random_dbmart(np.random.default_rng(s))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = mining.flatten(mined)
    scr = sparsity.screen_sorted(seq, dur, pat, msk, threshold)
    support = _oracle_support(db)
    expect = sum(1 for p in range(db.n_patients)
                 for i in range(int(db.nevents[p]))
                 for j in range(i + 1, int(db.nevents[p]))
                 if support[(int(db.phenx[p, i]), int(db.phenx[p, j]))] >= threshold)
    assert int(scr.n_kept) == expect
    # kept prefix is sorted and sentinel-free
    kept = np.asarray(scr.seq)[: int(scr.n_kept)]
    assert (kept != encoding.SENTINEL).all()
    assert (np.diff(kept) >= 0).all()


@given(st.integers(0, 10_000), st.integers(1, 5))
def test_screen_hash_one_sided(s, threshold):
    """hash screen NEVER drops a non-sparse sequence; with a large table it
    is exact on small universes."""
    db = random_dbmart(np.random.default_rng(s))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    keep = np.asarray(sparsity.screen_hash(mined.seq, mined.mask, threshold,
                                           n_buckets_log2=22))
    support = _oracle_support(db)
    seqs = np.asarray(mined.seq)
    msk = np.asarray(mined.mask)
    s_arr, e_arr = (np.asarray(x) for x in encoding.unpack(seqs, "bit"))
    for p in range(seqs.shape[0]):
        for t in range(seqs.shape[1]):
            if not msk[p, t]:
                assert not keep[p, t]
                continue
            sup = support[(int(s_arr[p, t]), int(e_arr[p, t]))]
            if sup >= threshold:
                assert keep[p, t], "non-sparse sequence dropped (one-sided!)"


def test_screen_hash_matches_exact_on_cohort(small_cohort):
    db, _ = small_cohort
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = mining.flatten(mined)
    for threshold in (2, 4, 8):
        scr = sparsity.screen_sorted(seq, dur, pat, msk, threshold)
        keep = np.asarray(sparsity.screen_hash(mined.seq, mined.mask, threshold,
                                               n_buckets_log2=22))
        assert int(scr.n_kept) == int(keep.sum())
        rows = baseline_tspm.mine_and_screen(db, threshold)
        assert len(rows) == int(scr.n_kept)


def test_support_counts_unique_table():
    db = random_dbmart(np.random.default_rng(42))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = mining.flatten(mined)
    _, _, _, u_key, u_sup, n_unique = sparsity.support_counts(seq, pat, msk)
    support = _oracle_support(db)
    assert int(n_unique) == len(support)
    u_key, u_sup = np.asarray(u_key), np.asarray(u_sup)
    got = {}
    for k in range(int(n_unique)):
        s, e = encoding.unpack(np.int64(u_key[k]), "bit")
        got[(int(s), int(e))] = int(u_sup[k])
    assert got == support


def test_hash_bucket_deterministic_and_in_range():
    ids = np.random.default_rng(0).integers(0, 2**48, 1000).astype(np.int64)
    h1 = np.asarray(sparsity.hash_bucket(ids, 16))
    h2 = np.asarray(sparsity.hash_bucket(ids, 16))
    assert (h1 == h2).all() and (h1 >= 0).all() and (h1 < 2**16).all()

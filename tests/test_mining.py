"""Mining correctness vs independent oracles (incl. the original-tSPM port)."""
import numpy as np
import pytest
from hypothesis import given, seed
from hypothesis import strategies as st

from repro.core import baseline_tspm, encoding, mining
from repro.data import dbmart as dbm
from tests.conftest import brute_force_pairs, random_dbmart


def _mined_tuples(mined, codec="bit"):
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    s, e = (np.asarray(x) for x in encoding.unpack(seq[msk], codec))
    return sorted(zip(pat[msk].tolist(), s.tolist(), e.tolist(),
                      dur[msk].tolist()))


@given(st.integers(0, 10_000))
def test_triangular_matches_brute_force(s):
    db = random_dbmart(np.random.default_rng(s))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    assert _mined_tuples(mined) == sorted(brute_force_pairs(db))


@given(st.integers(0, 10_000))
def test_dense_matches_triangular(s):
    db = random_dbmart(np.random.default_rng(s))
    tri = mining.mine_triangular(db.phenx, db.date, db.nevents)
    den = mining.mine_dense(db.phenx, db.date, db.nevents)
    assert _mined_tuples(tri) == _mined_tuples(den)


@given(st.integers(0, 10_000))
def test_count_formula(s):
    db = random_dbmart(np.random.default_rng(s))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    assert int(mined.n_mined) == int(mining.count_sequences(db.nevents))


def test_paper_codec_identical_pairs():
    db = random_dbmart(np.random.default_rng(1), n_codes=50)
    a = _mined_tuples(mining.mine_triangular(db.phenx, db.date, db.nevents,
                                             codec="bit"), "bit")
    b = _mined_tuples(mining.mine_triangular(db.phenx, db.date, db.nevents,
                                             codec="paper"), "paper")
    assert a == b


def test_durations_non_negative():
    db = random_dbmart(np.random.default_rng(7))
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    dur = np.asarray(mined.dur)[np.asarray(mined.mask)]
    assert (dur >= 0).all()


def test_fused_duration_mining():
    db = random_dbmart(np.random.default_rng(3))
    plain = mining.mine_triangular(db.phenx, db.date, db.nevents)
    fused = mining.mine_triangular(db.phenx, db.date, db.nevents,
                                   fuse_duration=True, bucket_days=30)
    m = np.asarray(plain.mask)
    seq2, buck = (np.asarray(x) for x in encoding.split_duration(fused.seq))
    assert (seq2[m] == np.asarray(plain.seq)[m]).all()
    assert (buck[m] == np.asarray(plain.dur)[m] // 30).all()


def test_matches_original_tspm_strings(small_cohort):
    """tSPM+ mines exactly the sequences the original tSPM mines."""
    db, _ = small_cohort
    rows = baseline_tspm.mine_strings(db)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    got = set()
    v = db.vocab
    s, e = (np.asarray(x) for x in encoding.unpack(seq, "bit"))
    for k in np.nonzero(msk)[0]:
        got.add((int(pat[k]),
                 v.phenx_strings[int(s[k])] + "-" + v.phenx_strings[int(e[k])],
                 int(dur[k])))
    assert got == {(p, st_, d) for p, st_, d in rows} or \
        sorted(got) == sorted((p, st_, d) for p, st_, d in rows)
    assert len(rows) == int(mined.n_mined)


def test_first_occurrence_filter():
    rows_p = [0, 0, 0, 0]
    rows_d = [1, 2, 3, 4]
    rows_x = ["A", "B", "A", "C"]
    db = dbm.from_rows(rows_p, rows_d, rows_x)
    f = dbm.first_occurrence_filter(db)
    assert int(f.nevents[0]) == 3
    kept = [f.vocab.phenx_strings[int(f.phenx[0, i])] for i in range(3)]
    assert kept == ["A", "B", "C"]


def test_ingest_sort_order():
    # unsorted rows in, time-sorted patient rows out (paper's ips4o step)
    db = dbm.from_rows([1, 0, 1, 0], [5, 9, 2, 1], ["X", "Y", "Z", "W"])
    assert db.n_patients == 2
    assert db.date[0, 0] <= db.date[0, 1]
    assert db.date[1, 0] <= db.date[1, 1]


def test_empty_patient_ok():
    db = random_dbmart(np.random.default_rng(11))
    db.nevents[0] = 0
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    flat_mask = np.asarray(mined.mask)
    assert not flat_mask[0].any()

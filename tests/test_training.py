"""Optimizer, train step, microbatching, checkpoint/restart, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import Request, ServeEngine
from repro.training import checkpoint, elastic
from repro.training import optimizer as opt_lib
from repro.training import train_loop


def _setup(arch="tspm-mlho", seed=0):
    cfg = get_config(arch, reduced=True)
    mdl = model_lib.build(cfg)
    state, pspecs = train_loop.init_state(mdl, jax.random.PRNGKey(seed))
    return cfg, mdl, state, pspecs


def _batch(cfg, b=4, s=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, cfg.vocab_size, (b, s)).astype(np.int32)
    labels = np.concatenate([toks[:, 1:], toks[:, :1]], 1)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
            "loss_mask": jnp.ones((b, s), bool)}


def test_loss_decreases():
    cfg, mdl, state, _ = _setup()
    opt_cfg = opt_lib.OptConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=50)
    step = jax.jit(train_loop.make_train_step(mdl, opt_cfg))
    batch = _batch(cfg)
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["ce"]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_schedule_shape():
    c = opt_lib.OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt_lib.schedule(c, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 1000)]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[3] < 1.0 and lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_microbatch_equivalence():
    """grad accumulation over k microbatches == one big batch step."""
    cfg, mdl, state, _ = _setup()
    opt_cfg = opt_lib.OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
    batch = _batch(cfg, b=8)
    s1, m1 = jax.jit(train_loop.make_train_step(mdl, opt_cfg))(state, batch)
    s2, m2 = jax.jit(train_loop.make_train_step(mdl, opt_cfg,
                                                microbatches=4))(state, batch)
    for a, b_ in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-5, rtol=1e-5)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0), "b": jnp.full((2,), -100.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100 * np.sqrt(6), rel=1e-5)
    assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg, mdl, state, _ = _setup()
    opt_cfg = opt_lib.OptConfig(warmup_steps=0, decay_steps=10)
    step = jax.jit(train_loop.make_train_step(mdl, opt_cfg))
    batch = _batch(cfg)
    for _ in range(3):
        state, _ = step(state, batch)
    path = checkpoint.save(str(tmp_path), 3, state, {"note": "t"})
    assert checkpoint.latest(str(tmp_path)) == path

    # resume-exactness: restored state continues bitwise-identically
    restored, manifest = checkpoint.restore(path, state)
    assert manifest["step"] == 3
    s_a, _ = step(state, batch)
    s_b, _ = step(train_loop.TrainState(*restored), batch) if isinstance(
        restored, tuple) else (None, None)
    for a, b_ in zip(jax.tree.leaves(s_a.params),
                     jax.tree.leaves(s_b.params)):
        assert (np.asarray(a) == np.asarray(b_)).all()

    # a .tmp dir (simulated crash mid-write) is never picked up
    os.makedirs(str(tmp_path / "step_00000099.tmp"))
    assert checkpoint.latest(str(tmp_path)) == path


def test_checkpoint_async(tmp_path):
    cfg, mdl, state, _ = _setup()
    checkpoint.save_async(str(tmp_path), 1, state)
    checkpoint.wait()
    restored, _ = checkpoint.restore(checkpoint.latest(str(tmp_path)), state)
    for a, b_ in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b_)).all()


def test_preemption_guard_checkpoints(tmp_path):
    cfg, mdl, state, _ = _setup()
    opt_cfg = opt_lib.OptConfig(warmup_steps=0, decay_steps=10)
    step = jax.jit(train_loop.make_train_step(mdl, opt_cfg))
    guard = elastic.PreemptionGuard()
    batch = _batch(cfg)
    done = 0
    for i in range(10):
        if i == 4:
            guard.trigger()          # simulated SIGTERM from the pod manager
        if guard.preempted:
            checkpoint.save(str(tmp_path), i, state)
            break
        state, _ = step(state, batch)
        done += 1
    assert done == 4 and checkpoint.latest(str(tmp_path)) is not None


def test_watchdog_flags_straggler():
    wd = elastic.StepWatchdog(factor=2.0, window=8)
    import time

    for i in range(6):
        wd.start()
        time.sleep(0.02 if i != 4 else 0.1)
        wd.stop(i)
    assert 4 in wd.flagged


def test_serve_engine_greedy_matches_manual():
    cfg, mdl, state, _ = _setup("tspm-mlho", seed=1)
    eng = ServeEngine(mdl, state.params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(4)]
    for i, pr in enumerate(prompts):
        eng.submit(Request(i, pr, max_new_tokens=6))
    results = eng.run()
    assert set(results) == {0, 1, 2, 3}

    # manual greedy for request 0 must match the engine
    toks = list(prompts[0])
    for _ in range(6):
        logits, _ = mdl.apply(state.params,
                              {"tokens": jnp.asarray([toks], jnp.int32)},
                              mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        toks.append(nxt)
        if nxt == 2:
            break
    manual = np.asarray(toks[len(prompts[0]):], np.int32)
    got = results[0][: len(manual)]
    assert (got == manual).all(), (got, manual)

"""Serving read path: plans, replicas, batched waves, caches, features.

The headline guarantee mirrors the engine-conformance bar: for any plan
chain the batched :class:`QueryServer` evaluator returns the **byte-
identical** keep mask to replaying the same chain through
``SequenceFrame`` ops on the same snapshot — across every engine, both
screen modes, fused duration codecs, and threshold edges.  On top of
that: snapshot isolation (same-tick snapshots are the identical cached
arrays; published views are immutable; queries racing live ingest never
observe a half-applied tick), LRU result caching keyed on (canonical
plan, snapshot version), and the streaming feature store staying byte-
identical to ``to_features`` recomputation at every tick boundary.
"""
import threading

import numpy as np
import pytest

from repro.api import ENGINES, MiningConfig, MiningSession
from repro.data import dbmart, synthea
from repro.serving.tspm import (FeatureStore, QueryPlan, ResultCache, plan,
                                uncompacted_rows)
from repro.stream.service import StreamService
from repro.stream.shard import ShardedStreamService
from tests.conftest import random_dbmart
from tests.test_api import H, fit_engine
from tests.test_stream_migration import chaos_replay


def fitted_session(engine, db, tmp_path=None, **cfg_kw):
    kw = dict(engine=engine, n_buckets_log2=H, budget_bytes=48 << 10,
              tick_patients=3, threshold=3)
    kw.update(cfg_kw)
    if engine == "sharded":
        kw.setdefault("n_shards", 4)
    if engine == "files" and tmp_path is not None:
        kw.setdefault("spill_dir", str(tmp_path / f"spill_{engine}"))
    s = MiningSession(MiningConfig(**kw))
    s.fit(db)
    return s


def random_plans(rng, codes, n=32, barriers=True):
    """Random chains over the full op vocabulary (the property input)."""
    kinds = ["screen", "starts_with", "ends_with", "min_duration"]
    if barriers:
        kinds += ["transitive_ends_with", "top_k"]
    out = []
    for _ in range(n):
        p = plan()
        for _ in range(int(rng.integers(1, 5))):
            k = kinds[int(rng.integers(len(kinds)))]
            if k == "screen":
                p = p.screen(int(rng.integers(1, 4)))
            elif k == "min_duration":
                p = p.min_duration(int(rng.integers(0, 200)))
            elif k == "top_k":
                p = p.top_k(int(rng.integers(1, 12)))
            else:
                p = getattr(p, k)(int(rng.choice(codes)))
        out.append(p)
    return out


def assert_serves_exactly(server, plans):
    """Every plan through the batched server == the frame-chain oracle on
    the same view, byte for byte."""
    base = server.view().frame
    thr = server.default_threshold
    for p in plans:
        keep = server.query(p).keep
        want = p.resolve(thr).apply(base).keep_mask()
        assert keep.dtype == want.dtype and keep.shape == want.shape, str(p)
        assert keep.tobytes() == want.tobytes(), str(p)


# --- plan IR ----------------------------------------------------------------

def test_canonical_is_order_insensitive_and_dedups():
    a = plan().screen(2).starts_with(7).min_duration(30)
    b = plan().min_duration(30).starts_with(7).screen(2).starts_with(7)
    assert a.canonical() == b.canonical()
    assert a.ops != b.ops          # original order is preserved on the plan
    # distinct args are NOT merged
    assert plan().starts_with(7).starts_with(8).canonical() \
        != plan().starts_with(7).canonical()


def test_barriers_pin_evaluation_order():
    a = plan().screen(2).top_k(4).min_duration(30)
    b = plan().min_duration(30).top_k(4).screen(2)
    assert a.canonical() != b.canonical()   # runs straddle the barrier
    vec, suffix = a.split_canonical()
    assert vec == (("screen", 2),)
    assert suffix == (("top_k", 4), ("min_duration", 30))
    # a pure predicate chain has no suffix at all
    vec, suffix = plan().screen(2).starts_with(1).split_canonical()
    assert suffix == () and len(vec) == 2


def test_resolve_fills_deferred_screen_or_raises():
    p = plan().screen().starts_with(3)
    assert p.resolve(5).ops[0] == ("screen", 5)
    assert p.resolve(5).resolve(9).ops[0] == ("screen", 5)   # idempotent
    with pytest.raises(ValueError):
        p.resolve(None)
    with pytest.raises(ValueError):
        p.canonical()              # unresolved plans have no canonical form
    # resolved plans pass through untouched (same object)
    q = plan().screen(2)
    assert q.resolve(5) is q


def test_plan_hashable_and_printable():
    assert hash(plan().screen(2)) == hash(QueryPlan((("screen", 2),)))
    assert "screen(?)" in str(plan().screen())
    assert str(plan()) == "(all)"


# --- batched conformance: server == frame, every engine ---------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_serve_conformance_all_engines(tmp_path, engine):
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=24, avg_events=12, seed=33)
    db = dbmart.from_rows(pats, dates, phx)
    rng = np.random.default_rng(100)
    codes = np.unique(db.phenx[db.phenx >= 0])
    session = fitted_session(engine, db, tmp_path, screen="hash")
    server = session.serve(batch_size=8)
    assert_serves_exactly(server, random_plans(rng, codes, n=24))


@pytest.mark.parametrize("screen", ["sorted", "fused"])
def test_serve_conformance_screen_modes(screen):
    rng = np.random.default_rng(300 + len(screen))
    db = random_dbmart(rng, n_patients=10, max_events=14)
    codes = np.unique(db.phenx[db.phenx >= 0])
    session = fitted_session("batch", db, screen=screen, threshold=2)
    server = session.serve(batch_size=4)
    assert_serves_exactly(server, random_plans(rng, codes, n=24))


def test_serve_conformance_fused_duration_codec():
    rng = np.random.default_rng(91)
    db = random_dbmart(rng, n_patients=9, max_events=12)
    codes = np.unique(db.phenx[db.phenx >= 0])
    for engine in ("batch", "stream"):
        session = fitted_session(engine, db, screen="hash",
                                 fuse_duration=True, threshold=2)
        server = session.serve(batch_size=8)
        assert_serves_exactly(server, random_plans(rng, codes, n=16))


def test_serve_threshold_edges():
    """screen at 0, the exact max support, one past it, and huge — the
    kernel's >= comparison must agree with the frame screen everywhere."""
    rng = np.random.default_rng(207)
    db = random_dbmart(rng, n_patients=10, max_events=14, n_codes=5)
    probe = fit_engine("batch", db, threshold=1, screen="hash")
    sup = probe.collect().support
    assert len(sup), "degenerate cohort"
    thr = int(sup.max())
    session = fitted_session("batch", db, screen="hash", threshold=1)
    server = session.serve()
    code = int(np.unique(db.phenx[db.phenx >= 0])[0])
    edges = [plan().screen(t) for t in (0, 1, thr, thr + 1, 10**9)]
    edges += [plan().screen(t).starts_with(code) for t in (thr, thr + 1)]
    assert_serves_exactly(server, edges)
    assert server.query(plan().screen(10**9)).n_kept == 0


def test_equivalent_plans_share_one_cache_entry():
    """Canonicalization makes permuted chains one entry and one program."""
    rng = np.random.default_rng(5)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    session = fitted_session("batch", db, screen="hash")
    server = session.serve()
    c = int(np.unique(db.phenx[db.phenx >= 0])[0])
    a = server.query(plan().screen(2).starts_with(c).min_duration(10))
    h0 = server.stats()["cache_hits"]
    b = server.query(plan().min_duration(10).screen(2).starts_with(c))
    assert server.stats()["cache_hits"] == h0 + 1
    assert a.keep.tobytes() == b.keep.tobytes()
    assert len(server.cache) == 1


def test_query_result_terminals_match_frame():
    rng = np.random.default_rng(11)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    session = fitted_session("batch", db, screen="hash")
    server = session.serve()
    c = int(np.unique(db.phenx[db.phenx >= 0])[0])
    p = plan().screen(2).starts_with(c)
    r = server.query(p)
    want = p.resolve(3).apply(server.view().frame)
    for a, b in zip(r.collect(), want.collect()):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    if want.vocab is not None:
        assert r.decode() == want.decode()
    assert r.n_kept == want.n_kept
    ids, sup = r.unique()
    wids, wsup = want.unique()
    assert ids.tobytes() == wids.tobytes()
    assert sup.tobytes() == wsup.tobytes()


def test_server_input_validation():
    rng = np.random.default_rng(2)
    db = random_dbmart(rng, n_patients=6, max_events=8)
    session = fitted_session("batch", db)
    with pytest.raises(ValueError):
        session.serve(batch_size=0)
    server = session.serve()
    with pytest.raises(TypeError):
        server.query("screen")
    with pytest.raises(RuntimeError):
        server.features()          # built without feature_ids


# --- snapshot isolation -----------------------------------------------------

def test_snapshot_same_tick_identity_single_shard():
    """Two snapshot() calls at the same version return the identical
    cached object; only mutations (tick / extract / admit) invalidate."""
    svc = StreamService(tick_patients=2, n_buckets_log2=H)
    svc.submit(0, [1, 2], [5, 6])
    svc.submit(1, [3], [7])
    svc.tick()
    v = svc.snapshot_version
    s1 = svc.snapshot()
    assert svc.snapshot() is s1
    svc.submit(0, [4], [8])        # queueing alone is not a mutation
    assert svc.snapshot() is s1 and svc.snapshot_version == v
    svc.tick()
    assert svc.snapshot_version > v
    s2 = svc.snapshot()
    assert s2 is not s1 and svc.snapshot() is s2
    v2 = svc.snapshot_version
    state = svc.extract_patient(0)
    assert svc.snapshot_version > v2
    assert svc.snapshot() is not s2
    svc.admit_patient(state)
    assert svc.snapshot() is svc.snapshot()


def test_snapshot_same_tick_identity_sharded():
    svc = ShardedStreamService(n_shards=2, tick_patients=2, n_buckets_log2=H)
    svc.submit(0, [1, 2], [5, 6])
    svc.submit(1, [3, 4], [7, 8])
    svc.run()
    s1 = svc.snapshot()
    assert svc.snapshot() is s1
    v = svc.snapshot_version
    svc.migrate(0, 1 - svc.router.route(0))
    assert svc.snapshot_version > v
    assert svc.snapshot() is not s1


def test_replica_publishes_at_tick_boundaries():
    rng = np.random.default_rng(17)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    session = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H))
    server = session.serve()
    v0 = server.view()
    assert server.view() is v0     # stable between ticks
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.service.tick()
    v1 = server.view()
    assert v1 is not v0
    assert v1.tick == session.service.n_ticks
    assert v1.version == session.service.snapshot_version
    assert server.replica.staleness_ticks() == 0
    # old views are frozen: their frames still answer on the old corpus
    assert v0.n_rows <= v1.n_rows


def test_manual_publish_and_staleness():
    rng = np.random.default_rng(19)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    session = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H))
    server = session.serve(auto_publish=False)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
    ticks_before = server.view().tick
    session.service.run()
    assert server.view().tick == ticks_before          # nothing auto-published
    assert server.replica.staleness_ticks() \
        == session.service.n_ticks - ticks_before
    server.publish()
    assert server.replica.staleness_ticks() == 0
    assert server.view().tick == session.service.n_ticks


def test_chaos_queries_never_see_partial_ticks():
    """Client threads hammer the background server while the ingest thread
    replays the migration-chaos schedule (submits, ticks, migrations,
    rebalances).  Every result must be self-consistent with the snapshot
    it reports (oracle replay on its own view), that snapshot must be one
    the ingest thread actually published (byte-identical corpus to the
    frame recorded inside the tick hook), and each client's view ticks
    must be non-decreasing."""
    rng = np.random.default_rng(4242)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    codes = np.unique(db.phenx[db.phenx >= 0])
    session = MiningSession(MiningConfig(
        engine="sharded", n_shards=2, threshold=2, tick_patients=2,
        n_buckets_log2=H))
    server = session.serve(batch_size=4)

    published = {}      # version -> corpus triple bytes, from the hook

    def record(svc):
        fr = session.frame()
        published[svc.snapshot_version] = (
            fr._corpus.seq.tobytes(), fr._corpus.dur.tobytes(),
            fr._corpus.patient.tobytes())
    session.service.subscribe_tick(record)
    record(session.service)        # the pre-ingest (empty) publication

    plans = random_plans(np.random.default_rng(1), codes, n=48)
    results: list[list] = [[] for _ in range(4)]

    def client(i):
        # a fixed per-client query count (not a stop flag): coverage does
        # not depend on how fast the chaos schedule drains under load
        r = np.random.default_rng(i)
        for _ in range(12):
            p = plans[int(r.integers(len(plans)))]
            results[i].append((p, server.submit(p).result(timeout=120)))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    server.start()
    for t in threads:
        t.start()
    chaos_replay(db, session.service, rng)
    for t in threads:
        t.join()
    server.stop()

    checked = 0
    for chunk in results:
        ticks = [r.view.tick for _, r in chunk]
        assert ticks == sorted(ticks), "a client saw time go backwards"
        for p, r in chunk:
            want = p.resolve(2).apply(r.view.frame).keep_mask()
            assert r.keep.tobytes() == want.tobytes(), str(p)
            assert r.view.version in published, \
                "query saw a snapshot no tick boundary ever published"
            c = r.view.frame._corpus
            assert (c.seq.tobytes(), c.dur.tobytes(),
                    c.patient.tobytes()) == published[r.view.version]
            checked += 1
    assert checked == 48, "a client dropped queries"
    # post-chaos: the server answers on the final corpus exactly
    server.publish()
    assert_serves_exactly(server, plans[:12])


# --- background loop --------------------------------------------------------

def test_submit_matches_sync_query_and_context_manager():
    rng = np.random.default_rng(23)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    codes = np.unique(db.phenx[db.phenx >= 0])
    session = fitted_session("batch", db, screen="hash")
    plans = random_plans(rng, codes, n=16)
    with session.serve(batch_size=4) as server:
        tickets = [server.submit(p) for p in plans]
        got = [t.result(timeout=60) for t in tickets]
    base = server.view().frame
    for p, r in zip(plans, got):
        assert r.keep.tobytes() \
            == p.resolve(3).apply(base).keep_mask().tobytes()
    st = server.stats()
    assert st["queries"] >= len(plans)
    assert 0 < st["waves"] <= st["queries"]


def test_background_errors_surface_on_tickets():
    rng = np.random.default_rng(29)
    db = random_dbmart(rng, n_patients=6, max_events=8)
    session = fitted_session("batch", db)
    server = session.serve()
    boom = RuntimeError("kernel exploded")

    def bad_wave(view, plans):
        raise boom
    server._eval_wave = bad_wave
    t = server.submit(plan().screen(2))
    with pytest.raises(RuntimeError, match="kernel exploded"):
        t.result(timeout=60)
    server.stop()


# --- result cache -----------------------------------------------------------

def test_result_cache_lru_semantics():
    c = ResultCache(capacity=2)
    with pytest.raises(ValueError):
        ResultCache(capacity=0)
    a, b, d = (np.ones(1), np.zeros(1), np.ones(2))
    c.put(("a", 0), a)
    c.put(("b", 0), b)
    assert c.get(("a", 0)) is a            # touches a: b is now LRU
    c.put(("d", 0), d)                     # evicts b
    assert c.get(("b", 0)) is None
    assert c.get(("d", 0)) is d
    assert (c.hits, c.misses, c.evictions) == (2, 1, 1)
    assert c.hit_ratio() == pytest.approx(2 / 3)
    assert len(c) == 2


def test_result_cache_invalidate_below_is_gc():
    c = ResultCache(capacity=8)
    for v in range(4):
        c.put((("screen", 2), v), np.ones(1))
    assert c.invalidate_below(2) == 2
    assert len(c) == 2
    assert c.get((("screen", 2), 1)) is None
    assert c.get((("screen", 2), 3)) is not None


def test_publication_invalidates_server_cache():
    rng = np.random.default_rng(31)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    session = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H))
    server = session.serve()
    for p in range(3):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.service.run()
    p = plan().screen(2)
    server.query(p)
    m0 = server.stats()["cache_misses"]
    server.query(p)
    assert server.stats()["cache_misses"] == m0          # warm hit
    for q in range(3, db.n_patients):
        n = int(db.nevents[q])
        session.submit(q, db.date[q, :n], db.phenx[q, :n])
    session.service.run()                                # publishes + GCs
    server.query(p)
    assert server.stats()["cache_misses"] == m0 + 1      # new version: miss
    assert len(server.cache) == 1                        # old entry GC'd


# --- streaming feature store ------------------------------------------------

def _feature_ids_for(db):
    """A strictly-increasing id list spanning present and absent pairs."""
    fr = fit_engine("batch", db, threshold=1, screen="hash")
    ids = np.unique(np.asarray(fr._corpus.seq))
    picked = ids[:: max(1, len(ids) // 12)]
    return np.unique(np.concatenate(
        [picked, [int(ids.max()) + 7]])).astype(np.int64)


def assert_features_identical(server, ids):
    got = server.features()
    want = server.view().frame.to_features(feature_ids=ids)
    assert np.asarray(got.x).tobytes() == np.asarray(want.x).tobytes()
    assert np.asarray(got.feature_ids).tobytes() \
        == np.asarray(want.feature_ids).tobytes()
    assert int(got.n_features) == int(want.n_features)


@pytest.mark.parametrize("screen", ["hash", "fused"])
def test_feature_store_tracks_every_tick(screen):
    """Incremental per-tick maintenance == full to_features recomputation
    on the matching snapshot, at every tick boundary, both screen modes."""
    rng = np.random.default_rng(61)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    ids = _feature_ids_for(db)
    session = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H, screen=screen))
    server = session.serve(feature_ids=ids)
    assert_features_identical(server, ids)       # empty bootstrap
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
        session.service.tick()
        assert_features_identical(server, ids)
    session.run()
    assert_features_identical(server, ids)


def test_feature_store_bootstrap_midstream():
    """serve() attached after ticks already ran: the bootstrap snapshot
    plus subsequent deltas still reproduce to_features exactly."""
    rng = np.random.default_rng(67)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    ids = _feature_ids_for(db)
    session = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H))
    half = db.n_patients // 2
    for p in range(half):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.service.run()
    server = session.serve(feature_ids=ids)      # bootstrap path
    assert_features_identical(server, ids)
    for p in range(half, db.n_patients):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
        session.service.tick()
        assert_features_identical(server, ids)


@pytest.mark.parametrize("engine", ["stream", "sharded"])
def test_feature_store_covers_migration_admitted_patients(engine):
    """A patient admitted by cross-service migration gets feature rows:
    its already-mined corpus rows never appear in any tick's delta feed,
    so the store must pick them up from the Migrated(src=None) event —
    the PR 9 scope gap.  Byte-identical to to_features recomputation
    both right after the admit and after further ticks."""
    rng = np.random.default_rng(79)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    donors = [p for p in range(db.n_patients) if db.nevents[p] > 1][-2:]
    donor = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H))
    for p in donors:
        n = int(db.nevents[p])
        donor.submit(p, db.date[p, :n], db.phenx[p, :n])
    donor.service.run()
    states = [donor.service.extract_patient(p) for p in donors
              if p in donor.service.store.pids]
    assert states, "no donor patient survived to extraction"
    # ids spanning the cohort *plus* the admitted states' own mined rows,
    # so the assertion cannot pass vacuously
    ids = np.unique(np.concatenate(
        [_feature_ids_for(db)]
        + [np.asarray(s.corpus_seq, np.int64)[:3] for s in states]))

    kw = dict(threshold=2, tick_patients=2, n_buckets_log2=H, engine=engine)
    if engine == "sharded":
        kw["n_shards"] = 2
    session = MiningSession(MiningConfig(**kw))
    server = session.serve(feature_ids=ids)
    for p in range(db.n_patients):
        if p in donors:
            continue
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.service.run()
    assert_features_identical(server, ids)

    for state in states:
        session.service.admit_patient(state)
    server.publish()
    assert_features_identical(server, ids)
    # non-vacuous: the admitted patients actually own feature columns
    x = np.asarray(server.features().x)
    assert all(x[int(s.key)].any() for s in states
               if len(s.corpus_seq) and int(s.key) < len(x))
    # and the store keeps tracking ticks that arrive after the admit
    p = donors[0]
    session.submit(p, db.date[p, :1], db.phenx[p, :1])
    session.service.run()
    assert_features_identical(server, ids)


def test_feature_store_batch_session():
    rng = np.random.default_rng(71)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    ids = _feature_ids_for(db)
    session = fitted_session("batch", db, screen="hash", threshold=2)
    server = session.serve(feature_ids=ids)
    assert_features_identical(server, ids)


def test_feature_store_validation():
    with pytest.raises(ValueError):
        FeatureStore([3, 1, 2])                  # not sorted
    with pytest.raises(ValueError):
        FeatureStore([1, 1])                     # not strictly increasing
    s = FeatureStore([])
    s.stage_rows(np.asarray([0]), np.asarray([5]))   # no-op, no raise
    with pytest.raises(TypeError):
        FeatureStore([1, 2]).stage_rows(np.asarray(["a"]), np.asarray([1]))


def test_feature_store_rejects_keyed_cohorts():
    session = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H))
    session.submit("patient-a", [1, 2], [5, 6])
    session.service.run()
    with pytest.raises(TypeError):
        session.serve(feature_ids=np.asarray([5, 6], np.int64))
    # feature-free serving of the same cohort is fine
    server = session.serve()
    assert server.query(plan().screen(1)).n_kept >= 0


def test_feature_matrices_are_point_in_time():
    """A view captured before later ticks keeps its original matrix."""
    rng = np.random.default_rng(73)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    ids = _feature_ids_for(db)
    session = MiningSession(MiningConfig(
        threshold=2, tick_patients=2, n_buckets_log2=H))
    server = session.serve(feature_ids=ids)
    half = db.n_patients // 2
    for p in range(half):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.service.run()
    early = server.view()
    frozen = None if early.feature_x is None else early.feature_x.copy()
    for p in range(half, db.n_patients):
        n = int(db.nevents[p])
        session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.service.run()
    if frozen is None:
        assert early.feature_x is None
    else:
        assert early.feature_x.tobytes() == frozen.tobytes()
    assert_features_identical(server, ids)       # and the front view moved on


def test_uncompacted_rows_batch_and_stream_agree():
    """Bootstrap rows from a drained live service match the batch fit's
    corpus as multisets (the live snapshot is unsorted)."""
    rng = np.random.default_rng(79)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    batch = fitted_session("batch", db, threshold=2, screen="hash")
    stream = fitted_session("stream", db, threshold=2, screen="hash")
    bs, bp = uncompacted_rows(batch)
    ss, sp = uncompacted_rows(stream)
    assert sorted(zip(bp.tolist(), bs.tolist())) \
        == sorted(zip(sp.tolist(), ss.tolist()))

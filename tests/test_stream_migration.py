"""Chaos conformance for live migration: any interleaving is exact.

Random interleavings of submit / tick / evict / migrate / rebalance are
replayed through ShardedStreamService and checked against the batch
mine+screen oracle (core.mining + core.sparsity) and the single-shard
StreamService — corpus, support counts, and query masks must match
byte-for-byte for n_shards 1/2/4, including under per-shard byte-budget
eviction and with the Pallas delta kernel.  Seeded-loop chaos runs in
offline environments; a hypothesis-driven variant (marked ``slow``)
explores deeper schedules when hypothesis is installed.

Unit tests at the bottom pin the handoff invariants one mechanism at a
time: queued-delta movement, subtract/add sketch transfer, spill-format
store handoff + plane shrinking, pid retirement, and the greedy LPT
rebalance policy.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import MiningConfig, MiningSession
from repro.core import queries, sparsity
from repro.stream.counts import OnlineSupportSketch
from repro.stream.service import StreamService
from repro.stream.shard import ShardedStreamService, ShardRouter
from repro.stream.store import PatientStore
from tests.conftest import random_dbmart
from tests.test_stream import H, batch_reference, replay
from tests.test_stream_sharded import sharded_triples


def chaos_replay(db, svc: ShardedStreamService, rng,
                 p_migrate=0.2, p_rebalance=0.1):
    """test_stream.replay with migrations and rebalances interleaved at
    random points — including while the migrated patient still has queued
    deltas, the adversarial case for sticky-until-migrated routing."""
    cursors = np.zeros(db.n_patients, np.int64)
    alive = [p for p in range(db.n_patients) if db.nevents[p] > 0]
    while alive:
        p = alive[int(rng.integers(len(alive)))]
        lo = int(cursors[p])
        hi = min(lo + int(rng.integers(1, 4)), int(db.nevents[p]))
        svc.submit(p, db.date[p, lo:hi], db.phenx[p, lo:hi])
        cursors[p] = hi
        if hi == int(db.nevents[p]):
            alive.remove(p)
        r = rng.random()
        if r < 0.15:
            svc.tick()
        elif r < 0.3:
            svc.run()
        if svc.pids and rng.random() < p_migrate:
            keys = list(svc.pids)
            key = keys[int(rng.integers(len(keys)))]
            svc.migrate(key, int(rng.integers(svc.n_shards)))
        if rng.random() < p_rebalance:
            svc.rebalance(imbalance_threshold=1.0 + float(rng.random()))
    svc.run()
    # post-drain churn: migrations of fully-ingested patients are exact too
    for key in list(svc.pids):
        if rng.random() < p_migrate:
            svc.migrate(key, int(rng.integers(svc.n_shards)))


def assert_matches_batch(svc, db, rng):
    """Corpus, support counts, screened corpus, and query masks against the
    batch mine+screen oracle on the same dbmart."""
    seq, dur, pat, msk, cnt = batch_reference(db)
    snap, keys = sharded_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()

    thr = int(rng.integers(1, 4))
    bkeep = np.asarray(sparsity.screen_hash_from_counts(seq, msk, cnt, thr, H))
    keep = svc.screened_keep(thr)
    assert sorted(zip(keys[keep], snap.seq[keep], snap.dur[keep])) \
        == sorted(zip(pat[bkeep], seq[bkeep], dur[bkeep]))

    x = int(rng.integers(0, 30))
    for smask, bmask in [
        (svc.query_starts_with(x),
         np.asarray(queries.starts_with(seq, x)) & msk),
        (svc.query_ends_with(x, threshold=thr),
         np.asarray(queries.ends_with(seq, x)) & bkeep),
        (svc.query_min_duration(30),
         np.asarray(queries.min_duration(dur, 30)) & msk),
    ]:
        assert sorted(zip(keys[smask], snap.seq[smask], snap.dur[smask])) \
            == sorted(zip(pat[bmask], seq[bmask], dur[bmask]))


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("budget", [None, 40_000])
def test_chaos_migration_equals_batch(n_shards, budget):
    rng = np.random.default_rng(7_000 + 10 * n_shards + (budget or 0))
    db = random_dbmart(rng, n_patients=int(rng.integers(5, 11)))
    svc = ShardedStreamService(
        n_shards=n_shards, tick_patients=int(rng.integers(1, 4)),
        n_buckets_log2=H, budget_bytes=budget)
    chaos_replay(db, svc, rng)
    assert_matches_batch(svc, db, rng)


def test_chaos_migration_equals_single_shard_stream():
    """Byte-identical to single-shard streaming, not just to batch: the
    same replay schedule with and without sharding+migration."""
    rng = np.random.default_rng(55)
    db = random_dbmart(rng, n_patients=9, max_events=14)
    seed = 17
    kw = dict(tick_patients=2, n_buckets_log2=H)
    sh = ShardedStreamService(n_shards=4, **kw)
    chaos_replay(db, sh, np.random.default_rng(seed))
    single = StreamService(**kw)
    replay(db, single, np.random.default_rng(seed))

    snap, keys = sharded_triples(sh)
    ssnap = single.snapshot()
    p2k = {pid: k for k, pid in single.store.pids.items()}
    skeys = np.asarray([p2k[int(p)] for p in ssnap.patient]
                       if len(ssnap.patient) else [], np.int64)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(skeys, ssnap.seq, ssnap.dur))
    assert (snap.counts == ssnap.counts).all()
    thr = 2
    keep, skeep = sh.screened_keep(thr), single.screened_keep(thr)
    assert sorted(zip(keys[keep], snap.seq[keep])) \
        == sorted(zip(skeys[skeep], ssnap.seq[skeep]))


def test_chaos_migration_with_kernel_backend():
    """The Pallas delta kernel mines migrated-in patients exactly (their
    history restores through the spill path before the next delta slab)."""
    rng = np.random.default_rng(23)
    db = random_dbmart(rng, n_patients=6, max_events=12)
    svc = ShardedStreamService(n_shards=2, tick_patients=2,
                               n_buckets_log2=H, backend="kernel",
                               interpret=True)
    chaos_replay(db, svc, rng)
    assert_matches_batch(svc, db, rng)


def test_chaos_auto_rebalance_equals_batch():
    """rebalance_every triggers migrations from inside tick(); the replay
    stays exact and actually migrates on a skewed pinned placement."""
    rng = np.random.default_rng(31)
    db = random_dbmart(rng, n_patients=10, max_events=20)
    router = ShardRouter(3, pinned={p: 0 for p in range(db.n_patients)})
    svc = ShardedStreamService(
        n_shards=3, router=router, tick_patients=2, n_buckets_log2=H,
        rebalance_every=2, imbalance_threshold=1.1)
    replay(db, svc, rng)
    assert svc.migrations, "skewed placement never rebalanced"
    assert_matches_batch(svc, db, rng)


@pytest.mark.slow
@pytest.mark.parametrize("n_shards", [2, 4])
def test_chaos_deep_sweep(n_shards):
    """More schedules per shard count (slow tier: run with -m slow)."""
    for case in range(4):
        rng = np.random.default_rng(9_000 + 100 * n_shards + case)
        db = random_dbmart(rng, n_patients=int(rng.integers(4, 14)))
        svc = ShardedStreamService(
            n_shards=n_shards, tick_patients=int(rng.integers(1, 5)),
            n_buckets_log2=H,
            budget_bytes=40_000 if case % 2 else None)
        chaos_replay(db, svc, rng)
        assert_matches_batch(svc, db, rng)


@pytest.mark.slow
@settings(max_examples=15)
@given(data=st.data())
def test_chaos_migration_hypothesis(data):
    """Hypothesis drives the whole schedule: dbmart shape, chunk sizes,
    tick/migrate/rebalance interleaving, shard count, eviction budget."""
    n_shards = data.draw(st.sampled_from([1, 2, 4]), label="n_shards")
    n_patients = data.draw(st.integers(2, 8), label="n_patients")
    budget = data.draw(st.sampled_from([None, 40_000]), label="budget")
    db = random_dbmart(np.random.default_rng(
        data.draw(st.integers(0, 2**16), label="db_seed")),
        n_patients=n_patients, max_events=10)
    svc = ShardedStreamService(
        n_shards=n_shards, n_buckets_log2=H, budget_bytes=budget,
        tick_patients=data.draw(st.integers(1, 4), label="tick_patients"))
    cursors = np.zeros(db.n_patients, np.int64)
    alive = [p for p in range(db.n_patients) if db.nevents[p] > 0]
    while alive:
        i = data.draw(st.integers(0, len(alive) - 1))
        p = alive[i]
        lo = int(cursors[p])
        hi = min(lo + data.draw(st.integers(1, 3)), int(db.nevents[p]))
        svc.submit(p, db.date[p, lo:hi], db.phenx[p, lo:hi])
        cursors[p] = hi
        if hi == int(db.nevents[p]):
            alive.remove(p)
        op = data.draw(st.integers(0, 5))
        if op == 0:
            svc.tick()
        elif op == 1:
            svc.run()
        elif op == 2 and svc.pids:
            keys = sorted(svc.pids)
            svc.migrate(keys[data.draw(st.integers(0, len(keys) - 1))],
                        data.draw(st.integers(0, n_shards - 1)))
        elif op == 3:
            svc.rebalance(imbalance_threshold=1.25)
    svc.run()
    assert_matches_batch(svc, db, np.random.default_rng(0))


# --- handoff mechanisms, one at a time -------------------------------------

def test_migrate_moves_queued_deltas_in_order():
    """Sticky-until-migrated: queued deltas follow the patient before any
    tick, so nothing is ever mined against a partial history."""
    svc = ShardedStreamService(n_shards=2, tick_patients=4, n_buckets_log2=H)
    key = 0
    src = svc.router.route(key)
    svc.submit(key, [1, 2], [5, 6])
    svc.submit(key, [3], [7])
    svc.migrate(key, 1 - src)
    assert not svc.shards[src].queue
    assert [d.phenx.tolist() for d in svc.shards[1 - src].queue] \
        == [[5, 6], [7]]
    assert svc.router.route(key) == 1 - src
    svc.run()
    ph, dt = svc.shards[1 - src].store.history(key)
    assert ph.tolist() == [5, 6, 7] and dt.tolist() == [1, 2, 3]


def test_migrate_unknown_key_raises_and_same_shard_is_noop():
    svc = ShardedStreamService(n_shards=2, n_buckets_log2=H)
    with pytest.raises(KeyError):
        svc.migrate("ghost", 1)
    svc.submit(3, [1], [2])
    svc.run()
    home = svc.router.route(3)
    svc.migrate(3, home)
    assert svc.migrations == []
    assert 3 in svc.shards[home].store.pids


def test_migrate_out_of_range_dst_rejected_before_mutation():
    """A bad dst (negative would silently index shards[-1]) must fail
    before any state moves — queue, store, and router stay intact."""
    svc = ShardedStreamService(n_shards=3, n_buckets_log2=H)
    svc.submit(0, [1], [2])
    svc.run()
    svc.submit(0, [3], [4])            # leave a queued delta too
    home = svc.router.route(0)
    for bad in (-1, 3, 17):
        with pytest.raises(ValueError):
            svc.migrate(0, bad)
    assert svc.router.route(0) == home
    assert 0 in svc.shards[home].store.pids
    assert len(svc.shards[home].queue) == 1 and svc.migrations == []
    svc.run()
    ph, dt = svc.shards[home].store.history(0)
    assert ph.tolist() == [2, 4] and dt.tolist() == [1, 3]


def test_migrate_spilled_patient_moves_host_copy():
    """A patient evicted to host at the source migrates from the spill
    slot; the destination restores it on the next touch."""
    rng = np.random.default_rng(13)
    db = random_dbmart(rng, n_patients=12, max_events=20)
    svc = ShardedStreamService(n_shards=2, tick_patients=3,
                               n_buckets_log2=H, budget_bytes=20_000)
    replay(db, svc, rng)
    spilled = [(s, k) for s, sv in enumerate(svc.shards)
               for k in sv.store.held_keys()]
    assert spilled, "budget never spilled anyone"
    s, key = spilled[0]
    svc.migrate(key, 1 - s)
    assert svc.shards[1 - s].store.tier_of(key) in ("host", "disk")
    assert key not in svc.shards[s].store.pids
    assert_matches_batch(svc, db, rng)


def test_sketch_row_handoff_is_subtract_add_exact():
    rng = np.random.default_rng(3)
    src, dst = OnlineSupportSketch(H), OnlineSupportSketch(H)
    seq = rng.integers(0, 1 << 40, (2, 9)).astype(np.int64)
    mask = np.ones((2, 9), bool)
    src.update([0, 1], seq, mask)
    before = np.asarray(src.counts).copy()
    ids = src.extract_row(0)
    assert sorted(ids) == sorted(set(seq[0].tolist()))
    dst.admit_row(5, ids)
    # global table (the psum merge) is invariant under the transfer
    assert (np.asarray(src.counts) + np.asarray(dst.counts) == before).all()
    # source row is zeroed; destination row continues to dedupe correctly
    assert src.n_distinct[0] == 0
    novel = dst.update([5], seq[0][None, :3], np.ones((1, 3), bool))
    assert novel == 0   # ids already in the migrated set


def test_store_extract_shrinks_high_water_planes():
    st_ = PatientStore(init_patients=2, init_events=8)
    ph = np.arange(100, dtype=np.int32)
    rows, _ = st_.admit(["big"])
    st_.append(rows, ph[None], ph[None], np.asarray([100], np.int32))
    for k in range(5):
        r, _ = st_.admit([f"s{k}"])
        st_.append(r, ph[None, :3], ph[None, :3], np.asarray([3], np.int32))
    assert st_.max_events >= 100
    cap_before = st_.max_events
    pid, hph, hdt = st_.extract("big")
    assert hph.tolist() == ph.tolist() and hdt.tolist() == ph.tolist()
    # one doubling step released per call (true hysteresis: a ping-ponging
    # patient costs O(log) retraces, not full-depth thrash)
    assert st_.max_events < cap_before
    for _ in range(6):
        st_.shrink_to_fit()
    assert st_.max_events <= 16   # converges to the survivors' extent
    for k in range(5):            # survivors intact after the shrinks
        gp, _ = st_.history(f"s{k}")
        assert gp.tolist() == ph[:3].tolist()


def test_store_pids_never_reused_after_extract():
    st_ = PatientStore()
    st_.admit(["a", "b"])
    pid_a, *_ = st_.extract("a")
    st_.admit(["c"])
    assert st_.pids["c"] != pid_a
    assert st_.pid_capacity == 3 and st_.n_patients == 2
    # round-trip: extract -> admit_state assigns a fresh pid, spill format
    pid_b, ph, dt = st_.extract("b")
    pid_b2 = st_.admit_state("b", ph, dt)
    assert pid_b2 not in (pid_a, pid_b)


def test_rebalance_moves_load_off_hot_shard():
    rng = np.random.default_rng(8)
    db = random_dbmart(rng, n_patients=12, max_events=20)
    router = ShardRouter(4, pinned={p: 0 for p in range(db.n_patients)})
    svc = ShardedStreamService(n_shards=4, router=router, tick_patients=4,
                               n_buckets_log2=H)
    replay(db, svc, rng)
    before = svc.shard_loads()
    assert max(before) == sum(before)   # everything on shard 0
    moves = svc.rebalance(imbalance_threshold=1.1)
    after = svc.shard_loads()
    assert moves and max(after) < max(before)
    assert sum(after) == sum(before)    # load moved, not created/lost
    assert_matches_batch(svc, db, rng)


# --- checkpoint / resume under chaos ---------------------------------------
# The schedule is generated up front as a deterministic op list, then split
# at a random cut: prefix -> checkpoint -> restore into a fresh session ->
# suffix.  The reference replays the *identical* prefix+suffix uninterrupted
# (the flat corpus order depends on the wave schedule, so byte-identical
# comparison requires byte-identical schedules), and both are checked
# against the batch oracle.

def _checkpoint_ops(db, rng, n_shards):
    """Deterministic chaos schedule: submits that drain the cohort, with
    ticks/runs/migrations/rebalances interleaved; ends fully drained."""
    ops = []
    cursors = np.zeros(db.n_patients, np.int64)
    alive = [p for p in range(db.n_patients) if db.nevents[p] > 0]
    submitted: list = []
    while alive:
        p = alive[int(rng.integers(len(alive)))]
        lo = int(cursors[p])
        hi = min(lo + int(rng.integers(1, 4)), int(db.nevents[p]))
        ops.append(("submit", p, lo, hi))
        if p not in submitted:
            submitted.append(p)
        cursors[p] = hi
        if hi == int(db.nevents[p]):
            alive.remove(p)
        r = rng.random()
        if r < 0.2:
            ops.append(("tick",))
        elif r < 0.35:
            ops.append(("run",))
        if n_shards > 1 and rng.random() < 0.2:
            key = submitted[int(rng.integers(len(submitted)))]
            ops.append(("migrate", key, int(rng.integers(n_shards))))
        if n_shards > 1 and rng.random() < 0.1:
            ops.append(("rebalance", 1.0 + float(rng.random())))
    ops.append(("run",))
    return ops


def _apply_ops(session, db, ops):
    for op in ops:
        if op[0] == "submit":
            _, p, lo, hi = op
            session.submit(p, db.date[p, lo:hi], db.phenx[p, lo:hi])
        elif op[0] == "tick":
            session.service.tick()
        elif op[0] == "run":
            session.service.run()
        elif op[0] == "migrate":
            session.service.migrate(op[1], op[2])
        elif op[0] == "rebalance":
            session.service.rebalance(imbalance_threshold=op[1])


def _assert_sessions_identical(a, b):
    """Every observable of two sharded services matches byte-for-byte."""
    sa, sb = a.service, b.service
    snap_a, keys_a = sharded_triples(sa)
    snap_b, keys_b = sharded_triples(sb)
    assert keys_a.tolist() == keys_b.tolist()
    assert (snap_a.seq == snap_b.seq).all()
    assert (snap_a.dur == snap_b.dur).all()
    assert (snap_a.counts == snap_b.counts).all()
    assert sa.pids == sb.pids
    assert sa.router.pinned == sb.router.pinned
    for va, vb in zip(sa.shards, sb.shards):
        assert va.store.rows.keys() == vb.store.rows.keys()
        assert {k: va.store.tier_of(k) for k in va.store.pids} \
            == {k: vb.store.tier_of(k) for k in vb.store.pids}


@pytest.mark.parametrize("n_shards,telemetry",
                         [(1, False), (2, False), (2, True)])
def test_checkpoint_restore_continues_byte_identical(n_shards, telemetry,
                                                     tmp_path):
    """Checkpoint at a random point mid-chaos, restore into a fresh
    session, continue — final corpus/sketch/router state byte-identical
    to an uninterrupted run of the same schedule, and batch-exact."""
    rng = np.random.default_rng(7_700 + 10 * n_shards + telemetry)
    db = random_dbmart(rng, n_patients=10, max_events=18)
    config = MiningConfig(engine="sharded", n_shards=n_shards,
                          tick_patients=2, n_buckets_log2=H, screen="hash",
                          budget_bytes=20_000, disk_bytes=5_000,
                          telemetry=telemetry)
    ops = _checkpoint_ops(db, rng, n_shards)
    cut = int(rng.integers(1, len(ops)))

    interrupted = MiningSession(config)
    _apply_ops(interrupted, db, ops[:cut])
    path = interrupted.checkpoint(str(tmp_path), extra={"cut": cut})
    resumed = MiningSession.restore(path)
    assert resumed.restore_extra == {"cut": cut}
    assert resumed.config == config
    _apply_ops(resumed, db, ops[cut:])

    reference = MiningSession(config)
    _apply_ops(reference, db, ops)

    _assert_sessions_identical(resumed, reference)
    assert_matches_batch(resumed.service, db, rng)


def test_checkpoint_restore_stream_engine(tmp_path):
    """The single-shard stream engine resumes byte-identically too (its
    state tree has no router/migration planes)."""
    rng = np.random.default_rng(91)
    db = random_dbmart(rng, n_patients=8, max_events=14)
    config = MiningConfig(tick_patients=2, n_buckets_log2=H, screen="hash",
                          budget_bytes=20_000, disk_bytes=5_000)
    ops = _checkpoint_ops(db, rng, n_shards=1)
    cut = int(rng.integers(1, len(ops)))

    interrupted = MiningSession(config)
    _apply_ops(interrupted, db, ops[:cut])
    resumed = MiningSession.restore(
        interrupted.checkpoint(str(tmp_path)))
    assert isinstance(resumed.service, StreamService)
    _apply_ops(resumed, db, ops[cut:])

    reference = MiningSession(config)
    _apply_ops(reference, db, ops)

    a, b = resumed.service.snapshot(), reference.service.snapshot()
    assert (a.seq == b.seq).all() and (a.dur == b.dur).all()
    assert (a.patient == b.patient).all()
    assert (a.counts == b.counts).all()
    assert resumed.service.store.pids == reference.service.store.pids
    assert resumed.service.n_ticks == reference.service.n_ticks


def test_checkpoint_is_a_snapshot_not_a_barrier(tmp_path):
    """Checkpointing must not advance the schedule: queued deltas and
    pending migration admits are captured, not flushed, so checkpointing
    after every op still yields the uninterrupted run's bytes."""
    rng = np.random.default_rng(17)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    config = MiningConfig(engine="sharded", n_shards=2, tick_patients=2,
                          n_buckets_log2=H, screen="hash")
    ops = _checkpoint_ops(db, rng, 2)

    chatty = MiningSession(config)
    for i, op in enumerate(ops):
        _apply_ops(chatty, db, [op])
        chatty.checkpoint(str(tmp_path), step=i)

    reference = MiningSession(config)
    _apply_ops(reference, db, ops)
    _assert_sessions_identical(chatty, reference)

    # and the *last* checkpoint restores to the same final state
    final = MiningSession.restore(str(tmp_path))
    _assert_sessions_identical(final, reference)

"""Multi-device semantics on 8 fake CPU devices (subprocess: device count
locks at first jax init, so each scenario runs in its own interpreter)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_script(body: str) -> str:
    script = (
        'import os\n'
        'os.environ["XLA_FLAGS"] = '
        '"--xla_force_host_platform_device_count=8"\n'
        + body
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_data_parallel_grads_match_single_device():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import model as model_lib
from repro.training import train_loop
from repro.distributed.sharding import axis_rules, param_shardings

cfg = get_config("tspm-mlho", reduced=True)
mdl = model_lib.build(cfg)
params, pspecs = mdl.init(jax.random.PRNGKey(0))
loss_fn = train_loop.make_loss_fn(mdl)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(4, 64, (8, 16)), jnp.int32)}
batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
batch["loss_mask"] = jnp.ones((8, 16), bool)

ref_loss, ref_grads = jax.value_and_grad(
    lambda p, b: loss_fn(p, b)[0])(params, batch)

mesh = jax.make_mesh((4, 2), ("data", "model"))
with axis_rules(mesh):
    shardings = param_shardings(mesh, pspecs)
    p_sh = jax.device_put(params, shardings)
    b_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(p, b)[0]))(p_sh, b_sh)
assert abs(float(loss) - float(ref_loss)) < 1e-4, (loss, ref_loss)
for a, b in zip(jax.tree.leaves(ref_grads), jax.tree.leaves(grads)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("DP-OK")
""")


def test_sharded_hash_screen_matches_global():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from functools import partial
from repro.compat import shard_map
from repro.core import mining, sparsity
from repro.data import synthea, dbmart

pats, dates, phx, _ = synthea.generate_cohort(n_patients=64, avg_events=16,
                                              seed=4)
db = dbmart.from_rows(pats, dates, phx)
mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
ref = np.asarray(sparsity.screen_hash(mined.seq, mined.mask, 3,
                                      n_buckets_log2=18))

mesh = jax.make_mesh((8,), ("data",))
spec = P("data")
@partial(shard_map, mesh=mesh, in_specs=(spec, spec),
         out_specs=spec)
def sharded_screen(seq, mask):
    return sparsity.screen_hash(seq, mask, 3, n_buckets_log2=18,
                                axis_names=("data",))

seq_sh = jax.device_put(mined.seq, NamedSharding(mesh, spec))
msk_sh = jax.device_put(mined.mask, NamedSharding(mesh, spec))
got = np.asarray(sharded_screen(seq_sh, msk_sh))
assert (got == ref).all(), "patient-sharded screen != global screen"
print("SCREEN-OK", int(got.sum()))
""")


def test_compressed_psum_convergence():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.distributed.compression import compressed_psum_mean

mesh = jax.make_mesh((8,), ("pod",))

# distributed linear regression with int8-compressed gradient allreduce
rng = np.random.default_rng(0)
X = rng.standard_normal((64, 16)).astype(np.float32)
w_true = rng.standard_normal(16).astype(np.float32)
y = X @ w_true

@partial(shard_map, mesh=mesh,
         in_specs=(P(), P("pod"), P("pod"), P("pod")),
         out_specs=(P(), P("pod")))
def step(w, Xs, ys, err):
    pred = Xs @ w
    g = 2 * Xs.T @ (pred - ys) / ys.size
    g_mean, new_err = compressed_psum_mean(g, "pod", err[0])
    return g_mean, new_err[None]  # error feedback stays shard-local

# jit the shard_map'd step: eager shard_map re-traces every call on
# jax 0.4.x, which turns 300 iterations into minutes
step = jax.jit(step)
w = jnp.zeros(16)
err = jax.device_put(jnp.zeros((8, 16)), NamedSharding(mesh, P("pod")))
Xd = jax.device_put(X, NamedSharding(mesh, P("pod")))
yd = jax.device_put(y, NamedSharding(mesh, P("pod")))
for i in range(300):
    g, err = step(w, Xd, yd, err)
    w = w - 0.1 * g
final = float(jnp.mean((X @ w - y) ** 2))
assert final < 1e-3, final
print("COMPRESS-OK", final)
""")


def test_elastic_reshard_across_meshes():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import model as model_lib
from repro.training import train_loop, checkpoint, elastic
import tempfile

cfg = get_config("tspm-mlho", reduced=True)
mdl = model_lib.build(cfg)
state, pspecs = train_loop.init_state(mdl, jax.random.PRNGKey(0))
sp = train_loop.state_pspecs(pspecs)

big = jax.make_mesh((4, 2), ("data", "model"))
small = jax.make_mesh((2, 2), ("data", "model"))  # "lost" half the fleet

st_big = elastic.reshard(state, big, sp)
with tempfile.TemporaryDirectory() as d:
    checkpoint.save(d, 0, st_big)
    restored, _ = checkpoint.restore(checkpoint.latest(d), state)
    st_small = elastic.reshard(restored, small, sp)
for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(st_small)):
    assert (np.asarray(a) == np.asarray(b)).all()
devs = {d for x in jax.tree.leaves(st_small)
        for d in x.sharding.device_set}
assert len(devs) == 4, devs
print("ELASTIC-OK")
""")


def test_tp_sharded_forward_matches_replicated():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import model as model_lib
from repro.distributed.sharding import axis_rules, param_shardings

cfg = get_config("gemma2-2b", reduced=True).replace(fsdp=True)
mdl = model_lib.build(cfg)
params, pspecs = mdl.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(1)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (4, 32)), jnp.int32)}
ref, _ = mdl.apply(params, batch, mode="train")

mesh = jax.make_mesh((2, 4), ("data", "model"))
with axis_rules(mesh):
    p_sh = jax.device_put(params, param_shardings(mesh, pspecs))
    b_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    got, _ = jax.jit(lambda p, b: mdl.apply(p, b, mode="train"))(p_sh, b_sh)
np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=2e-4,
                           rtol=2e-4)
print("TP-OK")
""")


def test_shard_map_ep_matches_dense_moe():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.distributed.sharding import axis_rules, param_shardings
from repro.models import model as model_lib
from repro.launch.mesh import make_test_mesh

cfg = get_config("deepseek-moe-16b", reduced=True).replace(
    capacity_factor=16.0, moe_dispatch="gspmd")
mdl = model_lib.build(cfg)
params, pspecs = mdl.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (4, 16)), jnp.int32)}
ref, aux_ref = mdl.apply(params, batch, mode="train")

mesh = make_test_mesh((2, 4), ("data", "model"))
mdl2 = model_lib.build(cfg.replace(moe_dispatch="shard_map_ep", fsdp=True))
with axis_rules(mesh):
    p_sh = jax.device_put(params, param_shardings(mesh, pspecs, params))
    b_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    got, aux = jax.jit(lambda p, b: mdl2.apply(p, b, mode="train"))(p_sh, b_sh)
np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=3e-4,
                           rtol=3e-4)
assert abs(float(aux_ref) - float(aux)) < 1e-6
print("EP-OK")
""")


def test_slstm_shard_map_grads_match():
    """The shard_map'd sLSTM (per-step dR psum fix) is gradient-exact."""
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.distributed.sharding import axis_rules, param_shardings
from repro.models import model as model_lib
from repro.launch.mesh import make_test_mesh

cfg = get_config("xlstm-125m", reduced=True)
mdl = model_lib.build(cfg)
params, pspecs = mdl.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(1)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (4, 16)), jnp.int32)}

def loss(p, b):
    logits, _ = mdl.apply(p, b, mode="train")
    return (logits.astype(jnp.float32) ** 2).mean()

ref_l, ref_g = jax.value_and_grad(loss)(params, batch)

mesh = make_test_mesh((4, 2), ("data", "model"))
with axis_rules(mesh):  # activates the shard_map path
    p_sh = jax.device_put(params, param_shardings(mesh, pspecs, params))
    b_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    l, g = jax.jit(jax.value_and_grad(loss))(p_sh, b_sh)
assert abs(float(l) - float(ref_l)) < 1e-4 * max(abs(float(ref_l)), 1)
for a, b in zip(jax.tree.leaves(ref_g), jax.tree.leaves(g)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4,
                               rtol=3e-3)
print("SLSTM-SMAP-OK")
""")

"""Verifiable tick journal: chaos replay exactness + the fraud-proof matrix.

The headline guarantee has two halves:

  * **completeness** — journaling any chaos interleaving (eviction,
    migration, rebalance, telemetry on/off, 1 or 2 shards) is invisible
    to the run itself, and ``MiningSession.replay`` of the journal
    reconstructs a session whose corpus, sketch table, router pins and
    pid table are byte-identical to the uninterrupted run *and* to the
    batch mine+screen oracle;
  * **soundness** — every tamper (a single flipped byte in any entry, a
    torn segment, and the re-chained forgeries an adversary who knows
    the format would write: truncation, reorder, payload edits, forged
    commitments) yields a typed :class:`FraudProof` naming the first
    divergent tick, and a clean journal never produces a false positive.

The typed session-event API the journal rides on (``subscribe(fn,
kinds=...)``, ``session.events()``, subscriber isolation) is pinned at
the bottom.
"""
import shutil

import numpy as np
import pytest

from repro import obs as obs_lib
from repro.api import MiningConfig, MiningSession
from repro.journal import (ChainBreak, CommitmentMismatch, Divergence,
                           FraudProof, TornSegment, Truncated, read_journal,
                           write_journal)
from repro.journal.entries import decode_entry, encode_entry, entry_kind
from repro.journal.journal import build_segment
from repro.storage.blockstore import CompressedBlockStore
from repro.stream.events import (DeltaSubmitted, Migrated, TickCompleted)
from repro.stream.service import StreamService
from tests.conftest import random_dbmart
from tests.test_stream import H
from tests.test_stream_migration import (_apply_ops,
                                         _assert_sessions_identical,
                                         _checkpoint_ops, assert_matches_batch)


def _chaos_session(tmp_path, rng, n_shards=2, telemetry=False,
                   commit_every=3):
    """One journaled chaos run (shared by the exactness and tamper
    tests): returns (session, db, ops, config)."""
    db = random_dbmart(rng, n_patients=9, max_events=16)
    config = MiningConfig(engine="sharded", n_shards=n_shards,
                          tick_patients=2, n_buckets_log2=H, screen="hash",
                          budget_bytes=20_000, disk_bytes=5_000,
                          telemetry=telemetry,
                          journal_dir=str(tmp_path / "journal"),
                          journal_commit_every=commit_every)
    ops = _checkpoint_ops(db, rng, n_shards)
    session = MiningSession(config)
    _apply_ops(session, db, ops)
    return session, db, ops, config


# --- completeness: chaos replay is byte-identical ---------------------------

@pytest.mark.parametrize("n_shards,telemetry",
                         [(1, False), (2, False), (2, True)])
def test_journal_chaos_replay_byte_identical(n_shards, telemetry, tmp_path):
    """Journal a random interleaving of submit/tick/evict/migrate/
    rebalance, replay it into a fresh session: corpus, sketch, router
    pins and pids match the live run byte-for-byte, the live run matches
    an unjournaled run of the same schedule (journaling is invisible),
    and both match the batch oracle."""
    rng = np.random.default_rng(8_800 + 10 * n_shards + telemetry)
    session, db, ops, config = _chaos_session(
        tmp_path, rng, n_shards=n_shards, telemetry=telemetry)

    bare = MiningSession(config.replace(journal_dir=None, telemetry=False))
    _apply_ops(bare, db, ops)
    _assert_sessions_identical(session, bare)

    res = session.verify()
    assert res.ok and res.proof is None and bool(res)
    assert res.n_ticks == session.service.n_ticks
    assert res.n_commits >= 1

    replayed = MiningSession.replay(config.journal_dir)
    _assert_sessions_identical(replayed, session)
    assert_matches_batch(replayed.service, db, rng)


def test_journal_stream_engine_replay(tmp_path):
    """The single-shard stream engine journals and replays exactly too
    (no router/migration planes in its event stream)."""
    rng = np.random.default_rng(97)
    db = random_dbmart(rng, n_patients=8, max_events=14)
    config = MiningConfig(tick_patients=2, n_buckets_log2=H, screen="hash",
                          budget_bytes=20_000, disk_bytes=5_000,
                          journal_dir=str(tmp_path / "j"),
                          journal_commit_every=2)
    ops = _checkpoint_ops(db, rng, n_shards=1)
    session = MiningSession(config)
    _apply_ops(session, db, ops)
    assert isinstance(session.service, StreamService)
    assert session.verify().ok

    replayed = MiningSession.replay(config.journal_dir)
    a, b = session.service.snapshot(), replayed.service.snapshot()
    for name in ("seq", "dur", "patient", "counts"):
        assert np.asarray(getattr(a, name)).tobytes() \
            == np.asarray(getattr(b, name)).tobytes()
    assert session.service.store.pids == replayed.service.store.pids
    assert session.service.n_ticks == replayed.service.n_ticks


def test_replay_upto_tick_stops_at_the_named_tick(tmp_path):
    """``replay(upto_tick=k)`` reconstructs the state as of tick k: the
    tick clock stops there and the corpus grows monotonically with k."""
    rng = np.random.default_rng(5)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    config = MiningConfig(tick_patients=2, n_buckets_log2=H, screen="hash",
                          journal_dir=str(tmp_path / "j"),
                          journal_commit_every=2)
    session = MiningSession(config)
    for p in range(db.n_patients):       # one productive tick per patient
        n = int(db.nevents[p])
        if n:
            session.submit(p, db.date[p, :n], db.phenx[p, :n])
            session.service.tick()
    total = session.service.n_ticks
    assert total >= 3
    session.journal().flush()

    prev_rows = -1
    for k in (1, total // 2, total):
        part = MiningSession.replay(config.journal_dir, upto_tick=k)
        assert part.service.n_ticks == k
        rows = len(np.asarray(part.service.snapshot().seq))
        assert rows >= prev_rows
        prev_rows = rows
    full = MiningSession.replay(config.journal_dir)
    assert np.asarray(full.service.snapshot().seq).tobytes() \
        == np.asarray(session.service.snapshot().seq).tobytes()


def test_journal_survives_checkpoint_restore(tmp_path):
    """A checkpoint-restored session keeps journaling into the same
    genesis-rooted log: the combined journal verifies, and replay from
    genesis equals the resumed session's final state."""
    rng = np.random.default_rng(41)
    db = random_dbmart(rng, n_patients=8, max_events=12)
    config = MiningConfig(engine="sharded", n_shards=2, tick_patients=2,
                          n_buckets_log2=H, screen="hash",
                          journal_dir=str(tmp_path / "j"),
                          journal_commit_every=2)
    ops = _checkpoint_ops(db, rng, 2)
    cut = int(rng.integers(1, len(ops)))

    interrupted = MiningSession(config)
    _apply_ops(interrupted, db, ops[:cut])
    path = interrupted.checkpoint(str(tmp_path / "ckpt"))
    interrupted.journal().close()

    resumed = MiningSession.restore(path)
    _apply_ops(resumed, db, ops[cut:])
    res = resumed.verify()
    assert res.ok, str(res)
    kinds = [entry_kind(e) for e, _ in read_journal(config.journal_dir)]
    assert kinds.count("open") == 1 and "checkpoint" in kinds

    replayed = MiningSession.replay(config.journal_dir)
    _assert_sessions_identical(replayed, resumed)


# --- soundness: the tamper matrix -------------------------------------------

def _rewrite(root, pairs):
    """Replace a journal's segments with exactly ``pairs`` — *preserving*
    the stored hashes (unlike write_journal, which re-chains)."""
    store = CompressedBlockStore(root)
    try:
        for key in list(store.keys()):
            if isinstance(key, str) and key.startswith("jseg"):
                store.discard(key)
        store.put_bytes("jseg00000000", build_segment(pairs))
    finally:
        store.close()


def _fork(tmp_path, src, i):
    dst = str(tmp_path / f"fork{i}")
    shutil.copytree(src, dst)
    return dst


def test_every_single_byte_flip_names_the_divergent_tick(tmp_path):
    """Flip one byte in *every* entry of a chaos journal (stored hash
    untouched): each copy fails verification with a ChainBreak at
    exactly that entry, carrying the 1-based first divergent tick —
    and the untouched journal still verifies after the whole sweep."""
    session, *_ = _chaos_session(tmp_path, np.random.default_rng(63))
    jdir = session.config.journal_dir
    session.journal().flush()
    clean = read_journal(jdir)
    kinds = [entry_kind(e) for e, _ in clean]
    assert len(clean) > 10 and kinds[0] == "open"

    for i, (e, h) in enumerate(clean):
        flipped = bytearray(e)
        flipped[len(e) // 2] ^= 0x01
        forged = clean[:i] + [(bytes(flipped), h)] + clean[i + 1:]
        t = str(tmp_path / f"flip{i}")
        shutil.copytree(jdir, t)
        _rewrite(t, forged)
        res = session.verify(t)
        assert not res.ok and isinstance(res.proof, ChainBreak), str(res)
        assert res.proof.index == i
        assert res.proof.tick == kinds[:i].count("tick") + 1

    assert session.verify().ok        # no false positive on the original


def test_torn_segment_is_a_fraud_proof(tmp_path):
    """A segment that fails framing (storage damage rather than a
    forgery) still produces a typed proof, not an exception."""
    session, *_ = _chaos_session(tmp_path, np.random.default_rng(29),
                                 n_shards=1)
    jdir = session.config.journal_dir
    session.journal().flush()
    t = _fork(tmp_path, jdir, "torn")
    store = CompressedBlockStore(t)
    key = sorted(k for k in store.keys()
                 if isinstance(k, str) and k.startswith("jseg"))[-1]
    store.put_bytes(key, b"\xff\xfe\xfd not a segment")
    store.close()
    res = session.verify(t)
    assert not res.ok and isinstance(res.proof, TornSegment), str(res)
    assert res.proof.tick >= 1


def test_rechained_forgeries_are_caught_by_replay(tmp_path):
    """An adversary who re-derives the chain writes an *internally
    consistent* journal — layer 1 passes; replay (shadow stream +
    commitments) and the against-live fork check must catch it."""
    session, *_ = _chaos_session(tmp_path, np.random.default_rng(77))
    jdir = session.config.journal_dir
    session.journal().flush()
    clean = read_journal(jdir)
    raw = [e for e, _ in clean]
    kinds = [entry_kind(e) for e in raw]
    n_case = 0

    def forge(entries):
        nonlocal n_case
        t = str(tmp_path / f"forge{n_case}")
        n_case += 1
        shutil.copytree(jdir, t)
        write_journal(t, entries)       # the adversary re-chains
        return session.verify(t)

    # (a) rollback: drop the tail
    res = forge(raw[:-3])
    assert not res.ok and isinstance(res.proof, (Truncated, Divergence)), \
        str(res)

    # (b) reorder two deltas of different patients
    deltas = [i for i, k in enumerate(kinds) if k == "delta"]
    swap = next((i, j) for i in deltas for j in deltas if j > i
                and decode_entry(raw[i])[1]["key"]
                != decode_entry(raw[j])[1]["key"])
    i, j = swap
    reordered = list(raw)
    reordered[i], reordered[j] = reordered[j], reordered[i]
    res = forge(reordered)
    assert not res.ok and isinstance(res.proof, FraudProof), str(res)
    assert res.proof.tick <= kinds[:j].count("tick") + 1

    # (c) forged merkle commitment (claim a different pid table)
    ci = kinds.index("commit")
    kind, fields, arrays, blobs = decode_entry(raw[ci])
    fields = dict(fields, pids="00" * 32)
    forged_commit = list(raw)
    forged_commit[ci] = encode_entry(kind, fields, arrays, blobs)
    res = forge(forged_commit)
    assert not res.ok and isinstance(res.proof, CommitmentMismatch), str(res)
    assert res.proof.tick == kinds[:ci].count("tick") + 1

    # (d) edited delta payload (a different clinical history)
    target = next(i for i in deltas
                  if len(decode_entry(raw[i])[2]["phenx"]) >= 2)
    kind, fields, arrays, blobs = decode_entry(raw[target])
    arrays = dict(arrays, phenx=arrays["phenx"] + 1000)
    edited = list(raw)
    edited[target] = encode_entry(kind, fields, arrays, blobs)
    res = forge(edited)
    assert not res.ok and isinstance(res.proof, FraudProof), str(res)

    # the real journal still verifies after the whole matrix
    assert session.verify().ok


def test_verify_requires_a_journal():
    session = MiningSession(MiningConfig(tick_patients=2, n_buckets_log2=H))
    session.submit(0, [1, 2], [3, 4])
    session.run()
    assert session.journal() is None
    with pytest.raises(RuntimeError):
        session.verify()


# --- the typed session-event API --------------------------------------------

def test_typed_subscription_and_legacy_shims_agree():
    """One subscribe(fn, kinds=...) API: typed subscribers, the deprecated
    subscribe_tick/subscribe_delta shims, and the pull-side
    session.events() tap all observe the same tick."""
    session = MiningSession(MiningConfig(tick_patients=4, n_buckets_log2=H))
    svc = session._ensure_service()
    tap = session.events(kinds=(DeltaSubmitted, TickCompleted))
    typed, shim_delta, shim_tick = [], [], []
    svc.subscribe(typed.append, kinds=TickCompleted)
    svc.subscribe_delta(
        lambda keys, slot, seq, dur: shim_delta.append(np.asarray(seq)))
    svc.subscribe_tick(shim_tick.append)

    session.submit(0, [1, 5, 9], [3, 4, 7])
    session.run()

    assert len(typed) == 1 and typed[0].tick == 1
    assert shim_tick == [svc]
    assert np.array_equal(shim_delta[0], typed[0].seq)
    drained = list(tap)
    assert [type(ev) for ev in drained] == [DeltaSubmitted, TickCompleted]
    assert len(tap) == 0              # drained
    # kinds filtering is enforced at subscribe time
    with pytest.raises(TypeError):
        svc.subscribe(lambda ev: None, kinds=(int,))


def test_subscriber_errors_are_isolated_and_counted():
    """A raising subscriber inside tick_finish must not corrupt the tick:
    the error is dropped, counted on events.subscriber_errors, and later
    subscribers still run (satellite fix for the PR 9 sync callbacks)."""
    tel = obs_lib.Telemetry()
    svc = StreamService(tick_patients=2, n_buckets_log2=H, telemetry=tel)
    seen = []

    def bad(ev):
        raise RuntimeError("subscriber boom")

    svc.subscribe(bad, kinds=TickCompleted)                  # isolate=True
    svc.subscribe(seen.append, kinds=TickCompleted)
    svc.submit(0, [1, 2], [3, 4])
    svc.tick()                                               # must not raise
    assert len(seen) == 1
    assert len(np.asarray(svc.snapshot().seq)) > 0           # tick landed
    assert tel.metrics.value("events.subscriber_errors") == 1

    # isolate=False (the journal's mode) propagates instead
    svc2 = StreamService(tick_patients=2, n_buckets_log2=H)
    svc2.subscribe(bad, kinds=TickCompleted, isolate=False)
    svc2.submit(0, [1, 2], [3, 4])
    with pytest.raises(RuntimeError, match="subscriber boom"):
        svc2.tick()


def test_external_admit_emits_migrated_with_state():
    """Cross-service handoff surfaces as Migrated(src=None) carrying the
    admitted PatientState — the event the feature store and journal key
    off (PR 9's admitted-rows gap)."""
    donor = StreamService(tick_patients=2, n_buckets_log2=H)
    donor.submit(7, [1, 2, 9], [3, 4, 6])
    donor.run()
    state = donor.extract_patient(7)

    svc = StreamService(tick_patients=2, n_buckets_log2=H)
    got = []
    svc.subscribe(got.append, kinds=Migrated)
    svc.admit_patient(state)
    assert len(got) == 1
    ev = got[0]
    assert ev.key == 7 and ev.src is None and ev.state is state

"""Streaming mining == batch mining: the subsystem's headline invariant.

Replays random dbmarts as per-patient deltas (random chunk sizes, patients
interleaved) through stream.StreamService and checks the final screened
corpus, support counts, and query masks against core.mining + core.sparsity
on the same dbmart.  Seeded-loop property tests so they run in offline
environments without hypothesis.
"""
import numpy as np
import pytest

from repro.core import mining, queries, sparsity
from repro.stream.service import StreamService
from tests.conftest import random_dbmart

H = 10  # small table so collisions actually happen in the one-sided test


def replay(db, svc, rng):
    """Submit each patient's history as random chronological chunks, with
    patients interleaved round-robin (arbitrary arrival order)."""
    cursors = np.zeros(db.n_patients, np.int64)
    alive = [p for p in range(db.n_patients) if db.nevents[p] > 0]
    while alive:
        p = alive[int(rng.integers(len(alive)))]
        lo = int(cursors[p])
        hi = min(lo + int(rng.integers(1, 4)), int(db.nevents[p]))
        svc.submit(p, db.date[p, lo:hi], db.phenx[p, lo:hi])
        cursors[p] = hi
        if hi == int(db.nevents[p]):
            alive.remove(p)
        if rng.random() < 0.3:
            svc.run()
    svc.run()


def batch_reference(db, n_buckets_log2=H):
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    cnt = np.asarray(sparsity.local_bucket_counts(
        np.asarray(mined.seq), np.asarray(mined.mask), n_buckets_log2))
    return seq, dur, pat, msk, cnt


def stream_triples(svc):
    """Corpus as (original patient key, seq, dur) triples."""
    snap = svc.snapshot()
    pid_to_key = {pid: k for k, pid in svc.store.pids.items()}
    keys = np.asarray([pid_to_key[int(p)] for p in snap.patient]
                      if len(snap.patient) else [], np.int64)
    return snap, keys


@pytest.mark.parametrize("case", range(6))
def test_streaming_equals_batch(case):
    rng = np.random.default_rng(1000 + case)
    db = random_dbmart(rng)
    svc = StreamService(tick_patients=int(rng.integers(1, 5)),
                        n_buckets_log2=H)
    replay(db, svc, rng)
    seq, dur, pat, msk, cnt = batch_reference(db)
    snap, keys = stream_triples(svc)

    # 1. corpus multiset
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    # 2. support sketch counts are *exactly* the batch bucket counts
    assert (snap.counts == cnt).all()
    # 3. screened corpus
    thr = int(rng.integers(1, 4))
    bkeep = np.asarray(sparsity.screen_hash_from_counts(seq, msk, cnt, thr, H))
    skeep = svc.screened_keep(thr)
    assert sorted(zip(keys[skeep], snap.seq[skeep], snap.dur[skeep])) \
        == sorted(zip(pat[bkeep], seq[bkeep], dur[bkeep]))
    # 4. query masks over the live corpus
    x = int(rng.integers(0, 30))
    for smask, bmask in [
        (svc.query_starts_with(x),
         np.asarray(queries.starts_with(seq, x)) & msk),
        (svc.query_ends_with(x, threshold=thr),
         np.asarray(queries.ends_with(seq, x)) & bkeep),
        (svc.query_min_duration(30),
         np.asarray(queries.min_duration(dur, 30)) & msk),
    ]:
        assert sorted(zip(keys[smask], snap.seq[smask], snap.dur[smask])) \
            == sorted(zip(pat[bmask], seq[bmask], dur[bmask]))


@pytest.mark.parametrize("case", range(3))
def test_streaming_fused_duration_equals_batch(case):
    """fuse_duration=True: streaming and batch agree on the fused codec
    (duration bucket packed into the id's low bits), for corpus, support
    counts, screen, and the duration query (dur stays carried separately)."""
    rng = np.random.default_rng(2000 + case)
    db = random_dbmart(rng)
    svc = StreamService(tick_patients=int(rng.integers(1, 5)),
                        n_buckets_log2=H, fuse_duration=True)
    replay(db, svc, rng)

    mined = mining.mine_triangular(db.phenx, db.date, db.nevents,
                                   fuse_duration=True)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    cnt = np.asarray(sparsity.local_bucket_counts(
        np.asarray(mined.seq), np.asarray(mined.mask), H))
    snap, keys = stream_triples(svc)

    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()
    thr = int(rng.integers(1, 4))
    bkeep = np.asarray(sparsity.screen_hash_from_counts(seq, msk, cnt, thr, H))
    skeep = svc.screened_keep(thr)
    assert sorted(zip(keys[skeep], snap.seq[skeep], snap.dur[skeep])) \
        == sorted(zip(pat[bkeep], seq[bkeep], dur[bkeep]))
    smask = svc.query_min_duration(30)
    bmask = np.asarray(queries.min_duration(dur, 30)) & msk
    assert sorted(zip(keys[smask], snap.seq[smask])) \
        == sorted(zip(pat[bmask], seq[bmask]))


def test_streaming_fused_duration_kernel_backend():
    """The Pallas delta kernel path agrees on the fused codec too."""
    rng = np.random.default_rng(11)
    db = random_dbmart(rng, n_patients=5, max_events=10)
    svc = StreamService(tick_patients=2, n_buckets_log2=H, fuse_duration=True,
                        backend="kernel", interpret=True)
    replay(db, svc, rng)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents,
                                   fuse_duration=True)
    seq, dur, pat, msk = (np.asarray(x) for x in mining.flatten(mined))
    cnt = np.asarray(sparsity.local_bucket_counts(
        np.asarray(mined.seq), np.asarray(mined.mask), H))
    snap, keys = stream_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()


def test_streaming_equals_batch_under_eviction():
    """A tiny byte budget forces spill/restore churn; results are exact."""
    rng = np.random.default_rng(42)
    db = random_dbmart(rng, n_patients=10, max_events=16)
    svc = StreamService(tick_patients=3, n_buckets_log2=H,
                        budget_bytes=40_000)
    replay(db, svc, rng)
    assert svc.store.spilled_count or len(svc.store.rows) < 10  # budget did bite
    seq, dur, pat, msk, cnt = batch_reference(db)
    snap, keys = stream_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()


def test_streaming_kernel_backend_equals_batch():
    rng = np.random.default_rng(7)
    db = random_dbmart(rng, n_patients=6, max_events=12)
    svc = StreamService(tick_patients=2, n_buckets_log2=H,
                        backend="kernel", interpret=True)
    replay(db, svc, rng)
    seq, dur, pat, msk, cnt = batch_reference(db)
    snap, keys = stream_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()


def test_sketch_merges_with_batch_screen_counts():
    """Half the cohort batch-mined, half streamed: merged tables equal the
    all-batch table (cold + hot cohorts screen together)."""
    rng = np.random.default_rng(3)
    db = random_dbmart(rng, n_patients=8, max_events=14)
    half = db.n_patients // 2
    cold = db.slice_patients(0, half)
    mined = mining.mine_triangular(cold.phenx, cold.date, cold.nevents)
    cold_cnt = np.asarray(sparsity.local_bucket_counts(
        np.asarray(mined.seq), np.asarray(mined.mask), H))

    svc = StreamService(tick_patients=2, n_buckets_log2=H)
    hot = db.slice_patients(half, db.n_patients)
    replay(hot, svc, rng)
    merged = svc.merged_counts(cold_cnt)

    _, _, _, _, full_cnt = batch_reference(db)
    assert (merged == full_cnt).all()


def test_sketch_error_is_one_sided():
    """Collisions may false-keep, but a non-sparse sequence NEVER drops."""
    rng = np.random.default_rng(5)
    db = random_dbmart(rng, n_patients=12, max_events=10, n_codes=4)
    svc = StreamService(tick_patients=4, n_buckets_log2=4)  # heavy collisions
    replay(db, svc, rng)
    snap, keys = stream_triples(svc)
    thr = 3
    keep = svc.screened_keep(thr)
    support = {}
    for k, s in set(zip(keys, snap.seq)):
        support[s] = support.get(s, 0) + 1
    for i, s in enumerate(snap.seq):
        if support[s] >= thr:
            assert keep[i]


def test_service_coalesces_second_delta_into_patient_slot():
    """Slot-level admission: a repeat delta joins its patient's slot in the
    same tick (chronological concat) instead of deferring a wave."""
    svc = StreamService(tick_patients=4)
    svc.submit(0, [1, 2], [3, 4])
    svc.submit(0, [5], [6])
    svc.submit(1, [1], [2])
    st = svc.tick()
    assert st.n_patients == 2 and len(svc.queue) == 0
    ph, dt = svc.store.history(0)
    assert ph.tolist() == [3, 4, 6] and dt.tolist() == [1, 2, 5]


def test_flooding_patient_drains_in_one_tick_and_stays_exact():
    """Regression for wave deferral: one patient flooding the queue used to
    admit one delta per tick (O(queue) ticks + O(queue^2) re-scans); slot
    admission drains the flood in a single tick, other patients still get
    their slots, and the mined corpus equals batch."""
    rng = np.random.default_rng(21)
    db = random_dbmart(rng, n_patients=3, max_events=24)
    svc = StreamService(tick_patients=2, n_buckets_log2=H)
    # patient 0 floods event-by-event; 1 and 2 queue behind it
    for i in range(int(db.nevents[0])):
        svc.submit(0, db.date[0, i : i + 1], db.phenx[0, i : i + 1])
    for p in (1, 2):
        n = int(db.nevents[p])
        svc.submit(p, db.date[p, :n], db.phenx[p, :n])
    st = svc.tick()
    assert st.n_patients == 2                  # flood slot + patient 1
    assert st.n_events == int(db.nevents[0]) + int(db.nevents[1])
    assert len(svc.queue) == 1                 # only patient 2 deferred
    svc.run()
    seq, dur, pat, msk, cnt = batch_reference(db)
    snap, keys = stream_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()


def test_slot_coalescing_caps_wave_width():
    """max_slot_events bounds a slot (the wave's slab pads to its widest
    slot, so one flood must not inflate every other patient's row); the
    overflow defers in per-patient order and the result stays exact."""
    rng = np.random.default_rng(6)
    db = random_dbmart(rng, n_patients=2, max_events=24)
    n0 = int(db.nevents[0])
    assert n0 > 8
    svc = StreamService(tick_patients=4, n_buckets_log2=H,
                        max_slot_events=8)
    for i in range(n0):    # flood patient 0 event-by-event
        svc.submit(0, db.date[0, i : i + 1], db.phenx[0, i : i + 1])
    st = svc.tick()
    assert st.n_events == 8            # slot closed at the cap
    assert len(svc.queue) == n0 - 8    # overflow deferred, order kept
    svc.run()
    seq, dur, pat, msk, cnt = batch_reference(db.slice_patients(0, 1))
    snap, keys = stream_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()


def test_store_regrowth_keeps_history():
    from repro.stream.store import PatientStore

    st = PatientStore(init_patients=2, init_events=8)
    rng = np.random.default_rng(0)
    want = {k: ([], []) for k in range(7)}
    for step in range(30):
        k = int(rng.integers(7))
        d = int(rng.integers(1, 6))
        ph = rng.integers(0, 50, d).astype(np.int32)
        dt = np.full(d, step, np.int32)
        rows, _ = st.admit([k])
        st.append(rows, ph[None], dt[None], np.asarray([d], np.int32))
        want[k][0].extend(ph.tolist())
        want[k][1].extend(dt.tolist())
    for k, (ph, dt) in want.items():
        if not ph:
            continue
        gp, gd = st.history(k)
        assert gp.tolist() == ph and gd.tolist() == dt

"""Flash-attention kernel vs naive oracle: shapes, dtypes, mask modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash, ref


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _run(B, Hq, Hkv, Sq, Skv, D, dtype=jnp.float32, **kw):
    q = _rand((B, Hq, Sq, D), dtype, 0)
    k = _rand((B, Hkv, Skv, D), dtype, 1)
    v = _rand((B, Hkv, Skv, D), dtype, 2)
    got = flash.flash_attention(q, k, v, interpret=True, bq=min(128, Sq),
                                bk=min(128, Skv), **kw)
    want = ref.attention_ref(q, k, v, **kw)
    return np.asarray(got), np.asarray(want)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 2, 2, 128, 64), (2, 4, 2, 256, 64), (1, 8, 1, 128, 128),
    (1, 2, 2, 384, 32),
])
def test_flash_causal(B, Hq, Hkv, S, D):
    got, want = _run(B, Hq, Hkv, S, S, D, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, tol):
    got, want = _run(1, 4, 2, 256, 256, 64, dtype=dtype, causal=True)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), atol=tol, rtol=tol)


def test_flash_sliding_window():
    got, want = _run(1, 2, 2, 384, 384, 64, causal=True, window=128)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_softcap():
    got, want = _run(1, 2, 2, 256, 256, 64, causal=True, softcap=50.0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_non_causal_cross():
    got, want = _run(1, 2, 2, 128, 256, 64, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_gqa_groups_match_ref():
    got, want = _run(2, 8, 2, 128, 128, 64, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

"""Device-pinned shards == host-serial shards == batch, byte for byte.

``placement='devices'`` changes *where* shard state lives and *when* work
is dispatched and migrations admitted — never what is computed.  These
tests replay random dbmarts through both placements (n_shards 1/2/4, with
eviction, with migration mid-stream, with the Pallas delta kernel, through
the façade's fit and submit/tick surfaces) and require identical corpus,
support counts, and screen masks, against each other and against batch
mine+screen.  A subprocess case forces 4 host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=4`` must be set before
jax initializes) so one-shard-per-device placement and the device-resident
psum stack are exercised for real, not just on a single shared device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import MiningConfig, MiningSession
from repro.launch.mesh import make_data_mesh
from repro.stream.shard import ShardedStreamService
from tests.conftest import random_dbmart
from tests.test_stream import H, batch_reference, replay
from tests.test_stream_sharded import sharded_triples


def corpus_triples(svc):
    snap, keys = sharded_triples(svc)
    return sorted(zip(keys, snap.seq, snap.dur)), np.asarray(snap.counts)


def assert_conformant(db, make_svc, seed, threshold=2):
    """host replay == devices replay == batch, on corpus/counts/screen."""
    per_placement = {}
    for placement in ("host", "devices"):
        svc = make_svc(placement)
        replay(db, svc, np.random.default_rng(seed))
        triples, cnt = corpus_triples(svc)
        keep = np.asarray(svc.screened_keep(threshold))
        per_placement[placement] = (triples, cnt, int(keep.sum()))
    seq, dur, pat, msk, bcnt = batch_reference(db)
    batch = sorted(zip(pat[msk], seq[msk], dur[msk]))
    for placement, (triples, cnt, _) in per_placement.items():
        assert triples == batch, f"{placement} corpus != batch"
        assert (cnt == bcnt).all(), f"{placement} counts != batch"
    assert per_placement["host"][0] == per_placement["devices"][0]
    assert (per_placement["host"][1] == per_placement["devices"][1]).all()
    assert per_placement["host"][2] == per_placement["devices"][2]


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_devices_placement_equals_host_and_batch(n_shards):
    rng = np.random.default_rng(500 + n_shards)
    db = random_dbmart(rng, n_patients=int(rng.integers(4, 12)))
    seed = int(rng.integers(1 << 30))
    mesh = make_data_mesh()

    def make_svc(placement):
        return ShardedStreamService(
            n_shards=n_shards, placement=placement, mesh=mesh,
            tick_patients=3, n_buckets_log2=H)

    assert_conformant(db, make_svc, seed)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_devices_placement_under_eviction(n_shards):
    """Per-shard byte budgets spill/restore on the pinned planes too."""
    rng = np.random.default_rng(600 + n_shards)
    db = random_dbmart(rng, n_patients=12, max_events=16)

    def make_svc(placement):
        return ShardedStreamService(
            n_shards=n_shards, placement=placement, tick_patients=3,
            n_buckets_log2=H, budget_bytes=40_000)

    assert_conformant(db, make_svc, 77)


def test_devices_placement_kernel_backend():
    """The Pallas delta kernel runs against device-committed planes."""
    rng = np.random.default_rng(71)
    db = random_dbmart(rng, n_patients=8, max_events=12)

    def make_svc(placement):
        return ShardedStreamService(
            n_shards=2, placement=placement, tick_patients=3,
            n_buckets_log2=H, backend="kernel", interpret=True)

    assert_conformant(db, make_svc, 13)


@pytest.mark.parametrize("placement", ["host", "devices"])
def test_async_migration_midstream(placement):
    """Random migrations between ticks, two-phase admission: pending
    states land at tick boundaries (or on any whole-cohort read) and the
    final state equals batch regardless of the interleaving."""
    rng = np.random.default_rng(81)
    db = random_dbmart(rng, n_patients=10, max_events=14)
    seq, dur, pat, msk, cnt = batch_reference(db)
    svc = ShardedStreamService(
        n_shards=4, placement=placement, async_migration=True,
        tick_patients=3, n_buckets_log2=H)
    cursors = np.zeros(db.n_patients, np.int64)
    for step in range(60):
        p = int(rng.integers(db.n_patients))
        lo = int(cursors[p])
        hi = min(lo + int(rng.integers(1, 3)), int(db.nevents[p]))
        if hi > lo:
            svc.submit(p, db.date[p, lo:hi], db.phenx[p, lo:hi])
            cursors[p] = hi
        if rng.random() < 0.3:
            svc.tick()
        if rng.random() < 0.25 and p in svc.pids:
            svc.migrate(p, int(rng.integers(4)))
    for p in range(db.n_patients):
        lo, hi = int(cursors[p]), int(db.nevents[p])
        if hi > lo:
            svc.submit(p, db.date[p, lo:hi], db.phenx[p, lo:hi])
    svc.run()
    triples, scnt = corpus_triples(svc)
    assert triples == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (scnt == cnt).all()
    assert not svc._pending_keys      # everything landed


def test_pending_admit_visible_to_reads():
    """A snapshot taken between migrate() and the next tick must already
    see the patient on its new home (reads flush the admit queue), and a
    second migrate of an in-flight patient lands it first."""
    svc = ShardedStreamService(n_shards=3, async_migration=True,
                               tick_patients=4, n_buckets_log2=H)
    svc.submit(0, np.arange(6, dtype=np.int32), np.zeros(6, np.int32))
    svc.submit(1, np.arange(4, dtype=np.int32), np.ones(4, np.int32))
    svc.run()
    before, cnt_before = corpus_triples(svc)

    src = svc.router.route(0)
    dst = (src + 1) % 3
    svc.migrate(0, dst)
    assert 0 in svc._pending_keys
    after, cnt_after = corpus_triples(svc)          # flushes
    assert 0 not in svc._pending_keys
    assert after == before and (cnt_after == cnt_before).all()
    assert 0 in svc.shards[dst].store.pids

    # re-migrate while a fresh handoff is parked: flush-then-move
    svc.migrate(0, src)
    assert 0 in svc._pending_keys
    svc.migrate(0, dst)
    assert svc.router.route(0) == dst
    final, cnt_final = corpus_triples(svc)
    assert final == before and (cnt_final == cnt_before).all()

    # a submit to an in-flight patient mines only after its state lands
    svc.migrate(0, src)
    svc.submit(0, np.arange(6, 9, dtype=np.int32), np.zeros(3, np.int32))
    svc.run()
    assert not svc._pending_keys
    hist = svc.shards[src].store.history(0)
    assert len(hist[0]) == 9           # full history on the new home

    # run() with empty queues still lands parked admits: a migrate with
    # nothing left to mine must not strand the patient off-shard
    svc.migrate(0, dst)
    assert 0 in svc._pending_keys
    assert svc.run() == []
    assert not svc._pending_keys
    assert 0 in svc.shards[dst].store.pids


@pytest.mark.parametrize("arrival", ["fit", "submit_tick"])
def test_facade_placement_conformance(arrival):
    """fit/submit/tick byte-identical between placement='host' and
    placement='devices' through MiningSession, and vs the batch engine."""
    from repro.data import dbmart, synthea

    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=24, avg_events=10, seed=2)
    db = dbmart.from_rows(pats, dates, phx)
    mesh = make_data_mesh()
    frames = {}
    for placement in ("host", "devices"):
        session = MiningSession(MiningConfig(
            engine="sharded", n_shards=2, placement=placement,
            screen="hash", n_buckets_log2=H, threshold=2,
            tick_patients=4), mesh=mesh)
        if arrival == "fit":
            frame = session.fit(db)
        else:
            for p in range(db.n_patients):
                n = int(db.nevents[p])
                half = n // 2
                if half:
                    session.submit(p, db.date[p, :half], db.phenx[p, :half])
            session.tick()
            for p in range(db.n_patients):
                n, half = int(db.nevents[p]), int(db.nevents[p]) // 2
                if n > half:
                    session.submit(p, db.date[p, half:n], db.phenx[p, half:n])
            frame = session.run()
        frames[placement] = frame
    batch = MiningSession(MiningConfig(
        engine="batch", screen="hash", n_buckets_log2=H, threshold=2)).fit(db)
    h, d = frames["host"], frames["devices"]
    # frames canonicalize (mask + lexsort) on access, so equal multisets
    # mean elementwise-equal arrays across all three engines
    for ha, da, ba in zip(h.arrays(), d.arrays(), batch.arrays()):
        assert (np.asarray(ha) == np.asarray(da)).all()
        assert (np.asarray(ha) == np.asarray(ba)).all()
    assert (h._corpus.counts() == d._corpus.counts()).all()
    assert (h._corpus.counts() == batch._corpus.counts()).all()
    assert h.screen().n_kept == d.screen().n_kept == batch.screen().n_kept


def test_multi_device_placement_conformance():
    """Real one-shard-per-device pinning: a fresh interpreter with 4
    forced host devices replays host vs devices (with a mid-stream async
    migration) and requires byte-identical corpus + counts + screen."""
    script = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 4, jax.devices()
        from tests.conftest import random_dbmart
        from tests.test_stream import H, batch_reference, replay
        from tests.test_stream_sharded import sharded_triples
        from repro.launch.mesh import make_data_mesh
        from repro.stream.shard import ShardedStreamService

        rng = np.random.default_rng(11)
        db = random_dbmart(rng, n_patients=10, max_events=14)
        mesh = make_data_mesh()
        out = {}
        for placement in ("host", "devices"):
            svc = ShardedStreamService(n_shards=4, placement=placement,
                                       mesh=mesh, tick_patients=3,
                                       n_buckets_log2=H)
            replay(db, svc, np.random.default_rng(3))
            svc.migrate(next(iter(svc.pids)), 2)
            snap, keys = sharded_triples(svc)
            out[placement] = (sorted(zip(keys, snap.seq, snap.dur)),
                              np.asarray(snap.counts),
                              int(np.asarray(svc.screened_keep(2)).sum()))
        if out["devices"][0] != out["host"][0]:
            raise SystemExit("corpus mismatch across placements")
        if not (out["devices"][1] == out["host"][1]).all():
            raise SystemExit("counts mismatch across placements")
        if out["devices"][2] != out["host"][2]:
            raise SystemExit("screen mismatch across placements")
        seq, dur, pat, msk, cnt = batch_reference(db)
        if out["devices"][0] != sorted(zip(pat[msk], seq[msk], dur[msk])):
            raise SystemExit("corpus mismatch vs batch")
        if not (out["devices"][1] == cnt).all():
            raise SystemExit("counts mismatch vs batch")
        print("placement-4dev-ok")
    """)
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(repo, "src"), repo,
                    env.get("PYTHONPATH", "")] if p)
    proc = subprocess.run([sys.executable, "-c", script], cwd=repo, env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "placement-4dev-ok" in proc.stdout

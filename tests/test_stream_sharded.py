"""Sharded streaming == single-shard streaming == batch mine+screen.

Replays random dbmarts through ShardedStreamService (n_shards 1/2/4, hash
and balanced routers, with and without a ('data',) mesh for the psum table
merge) and checks corpus, support counts, and query masks against both a
single-shard StreamService replay and core.mining + core.sparsity on the
same dbmart — including under per-shard eviction.
"""
import numpy as np
import pytest

from repro.core import queries, sparsity
from repro.data import pipeline
from repro.launch.mesh import make_data_mesh
from repro.stream.service import StreamService
from repro.stream.shard import ShardedStreamService, ShardRouter, \
    stable_shard_hash
from tests.test_stream import H, batch_reference, replay, stream_triples


def sharded_triples(svc: ShardedStreamService):
    snap = svc.snapshot()
    p2k = svc.pid_to_key()
    keys = np.asarray([p2k[int(p)] for p in snap.patient]
                      if len(snap.patient) else [], np.int64)
    return snap, keys


def run_replay(db, svc, seed):
    replay(db, svc, np.random.default_rng(seed))
    return svc


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("with_mesh", [False, True])
def test_sharded_equals_single_shard_and_batch(n_shards, with_mesh):
    rng = np.random.default_rng(300 + n_shards)
    from tests.conftest import random_dbmart

    db = random_dbmart(rng, n_patients=int(rng.integers(4, 12)))
    seed = int(rng.integers(1 << 30))
    kw = dict(tick_patients=int(rng.integers(1, 5)), n_buckets_log2=H)
    sh = run_replay(db, ShardedStreamService(
        n_shards=n_shards, mesh=make_data_mesh() if with_mesh else None,
        **kw), seed)
    single = run_replay(db, StreamService(**kw), seed)

    seq, dur, pat, msk, cnt = batch_reference(db)
    snap, keys = sharded_triples(sh)
    ssnap, skeys = stream_triples(single)

    batch_corpus = sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert sorted(zip(keys, snap.seq, snap.dur)) == batch_corpus
    assert sorted(zip(skeys, ssnap.seq, ssnap.dur)) == batch_corpus
    # merged table == single-shard table == batch bucket counts, exactly
    assert (snap.counts == cnt).all()
    assert (ssnap.counts == cnt).all()

    thr = int(rng.integers(1, 4))
    bkeep = np.asarray(sparsity.screen_hash_from_counts(seq, msk, cnt, thr, H))
    keep = sh.screened_keep(thr)
    skeep = single.screened_keep(thr)
    screened = sorted(zip(pat[bkeep], seq[bkeep], dur[bkeep]))
    assert sorted(zip(keys[keep], snap.seq[keep], snap.dur[keep])) == screened
    assert sorted(zip(skeys[skeep], ssnap.seq[skeep],
                      ssnap.dur[skeep])) == screened

    x = int(rng.integers(0, 30))
    for smask, bmask in [
        (sh.query_starts_with(x),
         np.asarray(queries.starts_with(seq, x)) & msk),
        (sh.query_ends_with(x, threshold=thr),
         np.asarray(queries.ends_with(seq, x)) & bkeep),
        (sh.query_min_duration(30),
         np.asarray(queries.min_duration(dur, 30)) & msk),
    ]:
        assert sorted(zip(keys[smask], snap.seq[smask], snap.dur[smask])) \
            == sorted(zip(pat[bmask], seq[bmask], dur[bmask]))


@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_equals_batch_under_eviction(n_shards):
    """Per-shard byte budgets force spill/restore churn; results are exact."""
    from tests.conftest import random_dbmart

    rng = np.random.default_rng(43)
    db = random_dbmart(rng, n_patients=12, max_events=16)
    svc = ShardedStreamService(n_shards=n_shards, tick_patients=3,
                               n_buckets_log2=H, budget_bytes=40_000)
    replay(db, svc, rng)
    assert any(len(s.store.host) or len(s.store.rows) < db.n_patients
               for s in svc.shards)   # at least one budget did bite
    seq, dur, pat, msk, cnt = batch_reference(db)
    snap, keys = sharded_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (snap.counts == cnt).all()


def test_balanced_router_pins_by_lpt_buckets():
    nevents = np.asarray([2, 30, 4, 30, 6, 8], np.int64)
    keys = list("abcdef")
    router = ShardRouter.balanced(keys, nevents, 2)
    buckets = pipeline.balance_buckets(nevents, 2)
    for s, b in enumerate(buckets):
        for p in b:
            assert router.route(keys[p]) == s
    # unknown keys still route (hash fallback), inside range
    assert 0 <= router.route("zz") < 2


def test_hash_router_is_stable_and_sticky():
    r = ShardRouter(4)
    for key in [0, 1, 17, "patient-3", ("site", 9)]:
        assert r.route(key) == r.route(key)
        assert 0 <= r.route(key) < 4
    # int hashing avalanche: dense ids spread over shards
    shards = {r.route(i) for i in range(64)}
    assert len(shards) == 4
    assert stable_shard_hash("x") == stable_shard_hash("x")


def _submit_patient(svc, key, n_events):
    svc.submit(key, np.arange(n_events, dtype=np.int32),
               np.zeros(n_events, np.int32))


def test_rebalance_min_gain_hysteresis():
    """A borderline patient whose move barely dents the imbalance stays
    put (handoff costs host copies + a retrace); with the guard off the
    same move happens.  Near-balanced cohorts must produce zero moves."""
    from repro.core import chunking as chk

    def build():
        svc = ShardedStreamService(
            n_shards=2, router=ShardRouter(2, pinned={0: 0, 1: 0, 2: 1}),
            tick_patients=4, n_buckets_log2=H)
        # shard0: costs 4^2, 20^2; shard1: 19^2 (x BYTES_PER_PAIR).
        # moving patient 0 (cost 416) is legal for the old LPT guard but
        # its gain (416) is under min_gain * mean (~505 at 0.05)
        for key, n in ((0, 4), (1, 20), (2, 19)):
            _submit_patient(svc, key, n)
        svc.run()
        return svc

    svc = build()
    loads = svc.shard_loads()
    mean = sum(loads) / 2
    gain = loads[0] - max(loads[0] - 4 * 4 * chk.BYTES_PER_PAIR,
                          loads[1] + 4 * 4 * chk.BYTES_PER_PAIR)
    assert 0 < gain < 0.05 * mean     # the scenario is actually borderline

    assert svc.rebalance(imbalance_threshold=1.0) == []      # guard holds
    assert svc.migrations == []

    svc = build()
    moves = svc.rebalance(imbalance_threshold=1.0, min_gain=0.0)
    assert moves == [(0, 0, 1)]       # guard off: the borderline move runs

    # a near-balanced cohort (equal costs) never migrates, guard or not
    svc = ShardedStreamService(
        n_shards=2, router=ShardRouter(2, pinned={0: 0, 1: 1}),
        tick_patients=4, n_buckets_log2=H)
    for key in (0, 1):
        _submit_patient(svc, key, 12)
    svc.run()
    assert svc.rebalance(imbalance_threshold=1.0, min_gain=0.0) == []


def test_sharded_merges_with_batch_screen_counts():
    """Half the cohort batch-mined, half stream-sharded: merged tables
    equal the all-batch table (cold + hot cohorts screen together)."""
    from repro.core import mining
    from tests.conftest import random_dbmart

    rng = np.random.default_rng(9)
    db = random_dbmart(rng, n_patients=8, max_events=14)
    half = db.n_patients // 2
    cold = db.slice_patients(0, half)
    mined = mining.mine_triangular(cold.phenx, cold.date, cold.nevents)
    cold_cnt = np.asarray(sparsity.local_bucket_counts(
        np.asarray(mined.seq), np.asarray(mined.mask), H))

    svc = ShardedStreamService(n_shards=2, tick_patients=2, n_buckets_log2=H)
    replay(db.slice_patients(half, db.n_patients), svc, rng)
    merged = svc.merged_counts(cold_cnt)

    _, _, _, _, full_cnt = batch_reference(db)
    assert (merged == full_cnt).all()

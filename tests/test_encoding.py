"""Property tests for the 64-bit sequence codecs (paper §Methods, Fig 2)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core import encoding


@given(st.integers(0, encoding.MAX_BIT_VOCAB - 1),
       st.integers(0, encoding.MAX_BIT_VOCAB - 1))
def test_bit_roundtrip(start, end):
    s, e = encoding.unpack(encoding.pack(start, end, "bit"), "bit")
    assert (int(s), int(e)) == (start, end)


@given(st.integers(0, encoding.MAX_PAPER_VOCAB - 1),
       st.integers(0, encoding.MAX_PAPER_VOCAB - 1))
def test_paper_roundtrip(start, end):
    s, e = encoding.unpack(encoding.pack(start, end, "paper"), "paper")
    assert (int(s), int(e)) == (start, end)


@given(st.integers(0, 2**23 - 1), st.integers(0, 2**23 - 1),
       st.integers(0, encoding.DUR_MASK))
def test_fused_duration_roundtrip(start, end, bucket):
    seq = encoding.pack(start, end, "bit")
    fused = encoding.fuse_duration(seq, bucket)
    seq2, b2 = encoding.split_duration(fused)
    assert int(seq2) == int(seq) and int(b2) == bucket


def test_pack_is_injective_bulk():
    rng = np.random.default_rng(0)
    s = rng.integers(0, 10000, 5000)
    e = rng.integers(0, 10000, 5000)
    for codec in encoding.CODECS:
        packed = np.asarray(encoding.pack(s, e, codec))
        uniq = len({(a, b) for a, b in zip(s, e)})
        assert len(np.unique(packed)) == uniq


def test_pack_monotone_in_start():
    # sorted packed ids group by start phenX — the property the paper's
    # sort-then-scan screening relies on
    a = encoding.pack(5, 99, "bit")
    b = encoding.pack(6, 0, "bit")
    assert int(a) < int(b)


def test_bucket_duration():
    d = jnp.asarray([0, 29, 30, 59, 60, 365])
    assert np.asarray(encoding.bucket_duration(d, 30)).tolist() == [0, 0, 1, 1, 2, 12]


def test_vocab_roundtrip():
    v = encoding.build_vocab(["p1", "p2", "p1"], ["Cough", "Fever", "Cough"])
    assert v.n_phenx == 2 and v.n_patients == 2
    seq = encoding.pack(v.phenx_index["Cough"], v.phenx_index["Fever"], "bit")
    assert v.decode_sequence(int(seq)) == "Cough -> Fever"


def test_bad_codec_raises():
    with pytest.raises(ValueError):
        encoding.pack(1, 2, "nope")

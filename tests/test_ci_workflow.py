"""CI pipeline invariants, enforced from inside tier-1.

The workflow is data; these tests are the lint that keeps its guarantees
from rotting: the bench-smoke matrix must stay generated from the suite
registry (so a new ``benchmarks/run.py`` suite can never be silently
missing from the smoke list), every suite must write the artifact the
smoke job uploads, the scheduled slow job must exist and actually select
the ``slow`` marker, and every job must carry a timeout under the shared
cancel-in-progress concurrency group.
"""
import os
import re

import pytest

yaml = pytest.importorskip("yaml")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKFLOW = os.path.join(REPO, ".github", "workflows", "ci.yml")


@pytest.fixture(scope="module")
def workflow():
    with open(WORKFLOW) as f:
        return yaml.safe_load(f)


@pytest.fixture(scope="module")
def suites():
    from benchmarks.run import SUITES

    return SUITES


def _triggers(workflow):
    # YAML 1.1 parses a bare `on:` key as boolean True
    return workflow.get("on", workflow.get(True))


def test_workflow_parses_and_has_all_jobs(workflow):
    assert {"tier1", "bench-registry", "bench-smoke",
            "slow-nightly"} <= set(workflow["jobs"])


def test_scheduled_slow_job(workflow):
    crons = _triggers(workflow)["schedule"]
    assert crons and all(len(c["cron"].split()) == 5 for c in crons)
    slow = workflow["jobs"]["slow-nightly"]
    assert "schedule" in slow["if"]
    run_steps = " ".join(s.get("run", "") for s in slow["steps"])
    assert "-m slow" in run_steps
    assert "hypothesis" in " ".join(s.get("run", "") for s in slow["steps"])


def test_concurrency_and_timeouts(workflow):
    conc = workflow["concurrency"]
    # cancel-in-progress is scoped to PR updates: superseded pushes to
    # main must still get a completed verdict
    assert "pull_request" in str(conc["cancel-in-progress"])
    assert "github.ref" in conc["group"]
    for name, job in workflow["jobs"].items():
        assert "timeout-minutes" in job, f"job {name} has no timeout"


def test_pip_cache_keyed_on_requirements(workflow):
    req = os.path.join(REPO, ".github", "requirements-ci.txt")
    assert os.path.exists(req)
    for name in ("tier1", "bench-smoke", "slow-nightly"):
        setup = [s for s in workflow["jobs"][name]["steps"]
                 if "setup-python" in s.get("uses", "")]
        assert setup, f"job {name} has no setup-python step"
        with_ = setup[0]["with"]
        assert with_.get("cache") == "pip"
        assert with_.get("cache-dependency-path") == \
            ".github/requirements-ci.txt"


def test_jax_version_matrix_covers_both_sides(workflow):
    """The tier-1 matrix must pin an oldest 0.4.x leg (compat.py's
    fallback spellings) alongside whatever pip resolves today."""
    legs = workflow["jobs"]["tier1"]["strategy"]["matrix"]["include"]
    jaxes = {leg["jax"] for leg in legs}
    assert {"oldest", "latest"} <= jaxes
    assert re.search(r"jax\[cpu\]==0\.4\.\d+", str(workflow["env"]))


def test_bench_smoke_matrix_is_the_registry(workflow, suites):
    """The smoke matrix is *generated from* benchmarks.run.SUITES via the
    bench-registry job, so no registered suite can be missing from the
    smoke list; this pins the wiring on both ends."""
    smoke = workflow["jobs"]["bench-smoke"]
    assert smoke["needs"] == "bench-registry" \
        or smoke["needs"] == ["bench-registry"]
    matrix = smoke["strategy"]["matrix"]["suite"]
    assert "fromJSON(needs.bench-registry.outputs.suites)" in matrix
    listing = " ".join(s.get("run", "")
                       for s in workflow["jobs"]["bench-registry"]["steps"])
    assert "from benchmarks.run import SUITES" in listing
    # and the registry itself is intact / importable with entries
    assert len(suites) >= 5
    assert "streaming_placement" in suites


def test_every_suite_writes_its_smoke_artifact(workflow, suites):
    """The smoke job uploads BENCH_<suite>.json with if-no-files-found:
    error — every registered suite's runner must default to exactly that
    path or the upload (and so the job) fails."""
    upload = [s for s in workflow["jobs"]["bench-smoke"]["steps"]
              if "upload-artifact" in s.get("uses", "")]
    assert upload and upload[0]["with"]["if-no-files-found"] == "error"
    assert upload[0]["with"]["path"] == "BENCH_${{ matrix.suite }}.json"
    with open(os.path.join(REPO, "benchmarks", "run.py")) as f:
        src = f.read()
    for name in suites:
        assert f'"BENCH_{name}.json"' in src, \
            f"suite {name} does not write BENCH_{name}.json"


def test_overhead_regression_gate_present(workflow):
    """The checked-in BENCH_api_overhead.json is a regression baseline:
    the gate must compare against it (2x) besides the 5% ceiling."""
    runs = " ".join(s.get("run", "")
                    for s in workflow["jobs"]["tier1"]["steps"])
    assert "BENCH_api_overhead.json" in runs
    assert "2 * stored" in runs
    assert "0.05" in runs


def test_observability_gate_present(workflow, suites):
    """Telemetry must stay < 3% on the ingest hot path: tier-1 carries a
    gate running the observability suite against the checked-in
    BENCH_observability_overhead.json, and the suite is registered (so
    bench-smoke regenerates the artifact on every PR)."""
    assert "observability_overhead" in suites
    runs = " ".join(s.get("run", "")
                    for s in workflow["jobs"]["tier1"]["steps"])
    assert "BENCH_observability_overhead.json" in runs
    assert "observability_overhead" in runs
    assert "0.03" in runs


def test_fused_screen_gate_present(workflow, suites):
    """The corpus-free screen must stay byte-invisible: tier-1 carries a
    gate fitting a live screen="fused" session against the materializing
    path and re-validating the checked-in BENCH_mining_fused.json
    (exactness + peak-bytes ratio under the BYTES_PER_PAIR cost model),
    and the mining_fused suite is registered so bench-smoke regenerates
    the artifact on every PR."""
    assert "mining_fused" in suites
    runs = " ".join(s.get("run", "")
                    for s in workflow["jobs"]["tier1"]["steps"])
    assert "BENCH_mining_fused.json" in runs
    assert "mining_fused" in runs
    assert 'screen="fused"' in runs


def test_nightly_checkpoint_resume_drill(workflow, suites):
    """The nightly must kill a checkpointing replay mid-run and resume it
    across a real process boundary, diffing query results against an
    uninterrupted run — and the storage_tiering suite must be registered
    (so bench-smoke regenerates BENCH_storage_tiering.json per PR)."""
    assert "storage_tiering" in suites
    slow = workflow["jobs"]["slow-nightly"]
    runs = " ".join(s.get("run", "") for s in slow["steps"])
    assert "--checkpoint-dir" in runs and "--resume" in runs
    assert "--stop-after-wave" in runs
    assert "--disk-bytes" in runs, \
        "the resume drill must exercise the compressed disk tier"
    assert "diff " in runs, "resumed output is never compared"


def test_nightly_uploads_trace_artifact(workflow):
    """The nightly chaos leg must produce an inspectable Chrome trace: a
    sharded telemetry-on replay with --trace-out on forced host devices,
    uploaded with if-no-files-found: error so a silently-empty trace
    fails the job."""
    slow = workflow["jobs"]["slow-nightly"]
    runs = " ".join(s.get("run", "") for s in slow["steps"])
    assert "--trace-out" in runs and "--metrics-json" in runs
    assert "--shards" in runs and "repro.launch.stream" in runs
    envs = [s.get("env", {}) for s in slow["steps"] if s.get("run")]
    assert any("xla_force_host_platform_device_count"
               in str(e.get("XLA_FLAGS", "")) for e in envs)
    upload = [s for s in slow["steps"]
              if "upload-artifact" in s.get("uses", "")]
    assert upload, "slow-nightly has no artifact upload step"
    assert upload[0]["with"]["if-no-files-found"] == "error"
    assert "chaos_trace.json" in upload[0]["with"]["path"]


def test_journal_conformance_gate_present(workflow, suites):
    """The audit log must acquit honest runs and convict forgeries:
    tier-1 carries a gate that verifies + replays a live journaled
    session, forges a re-chained delta edit (which must yield a typed
    fraud proof), and re-validates the checked-in
    BENCH_journal_overhead.json (< 5% ceiling with replay asserted
    exact); the journal_overhead suite is registered so bench-smoke
    regenerates the artifact on every PR."""
    assert "journal_overhead" in suites
    runs = " ".join(s.get("run", "")
                    for s in workflow["jobs"]["tier1"]["steps"])
    assert "BENCH_journal_overhead.json" in runs
    assert "journal_dir" in runs
    assert "write_journal" in runs, \
        "the gate never forges a re-chained journal"
    assert "MiningSession.replay" in runs
    assert "overhead_ceiling" in runs and "replay_exact" in runs


def test_nightly_journal_replay_drill(workflow):
    """The nightly must journal a sharded chaos run (eviction + live
    rebalancing, commitments exercised) and replay it in a separate
    process, diffing the printed state digests — the byte-exact audit
    contract across real process boundaries."""
    slow = workflow["jobs"]["slow-nightly"]
    runs = " ".join(s.get("run", "") for s in slow["steps"])
    assert "--journal-dir" in runs and "--replay-journal" in runs
    assert "--journal-commit-every" in runs, \
        "the drill must exercise merkle commitments, not just the chain"
    assert "--rebalance-every" in runs.split("--journal-dir")[0] \
        or "--rebalance-every" in runs
    assert "state_digest" in runs
    assert "diff " in runs, "the replayed digest is never compared"


def test_serving_conformance_gate_present(workflow, suites):
    """The batched read path must stay byte-invisible: tier-1 carries a
    gate driving a live session.serve() against frame-chain evaluation
    and re-validating the checked-in BENCH_serving_latency.json (exact
    masks + the >= 2x p99 speedup floor at >= 32 clients), and the
    serving_latency suite is registered so bench-smoke regenerates the
    artifact on every PR."""
    assert "serving_latency" in suites
    runs = " ".join(s.get("run", "")
                    for s in workflow["jobs"]["tier1"]["steps"])
    assert "BENCH_serving_latency.json" in runs
    assert "session.serve" in runs
    assert "p99_speedup" in runs
    assert "min_p99_speedup" in runs

"""Per-assigned-architecture smoke tests: reduced config, one forward +
one gradient step on CPU; output shapes + finiteness; prefill/decode
consistency for every family (the full configs are exercised only by the
dry-run, per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import SMOKE, ShapeConfig
from repro.launch import specs
from repro.models import model as model_lib


def _loss_fn(mdl, cfg):
    def loss(params, batch):
        logits, aux = mdl.apply(params, batch, mode="train")
        labels = batch["labels"]
        mask = batch["loss_mask"]
        lse = jax.nn.log_softmax(logits.astype(jnp.float32))
        ll = jnp.take_along_axis(lse, labels[..., None], -1)[..., 0]
        return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1) + aux

    return loss


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad_step(arch):
    cfg = get_config(arch, reduced=True)
    mdl = model_lib.build(cfg)
    params, pspecs = mdl.init(jax.random.PRNGKey(0))
    # spec tree mirrors param tree
    assert jax.tree.structure(params) == jax.tree.structure(
        pspecs, is_leaf=lambda v: isinstance(v, jax.sharding.PartitionSpec))

    batch = specs.train_batch(cfg, SMOKE, concrete=True)
    logits, aux = mdl.apply(params, batch, mode="train")
    assert logits.shape[:2] == batch["labels"].shape
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, grads = jax.value_and_grad(_loss_fn(mdl, cfg))(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss2 = _loss_fn(mdl, cfg)(params2, batch)
    assert bool(jnp.isfinite(loss2))


DECODE_ARCHS = [a for a in ARCHS]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode logits == full-forward logits (unbounded MoE capacity
    so token-choice dropping cannot differ between the two paths)."""
    cfg = get_config(arch, reduced=True)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=16.0)
    mdl = model_lib.build(cfg)
    params, _ = mdl.init(jax.random.PRNGKey(1))
    shape = ShapeConfig("t", 24, 2, "train")
    batch = specs.train_batch(cfg, shape, concrete=True, seed=3)

    full, _ = mdl.apply(params, batch, mode="train")

    # vlm text tokens and encdec decoder tokens are shorter than seq_len
    n_pre = 8 if cfg.family in ("vlm", "encdec") else 16
    if cfg.family == "encdec":
        caches = mdl.init_caches(2, 24, src_len=batch["src_embeds"].shape[1])
        pre = {"src_embeds": batch["src_embeds"],
               "tokens": batch["tokens"][:, :n_pre]}
        step = {"tokens": batch["tokens"][:, n_pre:n_pre + 1]}
    else:
        caches = mdl.init_caches(2, 24)
        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :n_pre]
        if cfg.family == "vlm":
            # keep patch prefix in the prefill
            pass
        step = {"tokens": batch["tokens"][:, n_pre:n_pre + 1]}

    lg_pre, caches = mdl.apply(params, pre, mode="prefill", caches=caches)
    lg_dec, caches = mdl.apply(params, step, mode="decode", caches=caches)

    off = cfg.n_patches if cfg.family == "vlm" else 0
    tol = 2e-3
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, -1]), np.asarray(full[:, off + n_pre - 1]),
        atol=tol, rtol=tol)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0]), np.asarray(full[:, off + n_pre]),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("arch", ["xlstm-125m", "zamba2-2.7b"])
def test_subquadratic_flag(arch):
    assert get_config(arch).subquadratic


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (the spec table)."""
    expect = {
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, v), arch
    c = get_config("seamless-m4t-large-v2")
    assert (c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (1024, 16, 8192, 256206)
    assert c.n_enc_layers == 24 and c.n_dec_layers == 24
    z = get_config("zamba2-2.7b")
    assert z.ssm_state == 64
    m = get_config("deepseek-moe-16b")
    assert (m.n_experts, m.experts_per_token, m.n_shared_experts) == (64, 6, 2)
    l4 = get_config("llama4-maverick-400b-a17b")
    assert (l4.n_experts, l4.experts_per_token) == (128, 1)

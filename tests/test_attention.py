"""Attention substrate: blocked (flash-style) == direct softmax, RoPE
properties, decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import attention, layers


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


def _direct(q, k, v, causal=True, window=None, softcap=None):
    from repro.kernels.flash_attention.ref import attention_ref

    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = attention_ref(qt, kt, vt, causal=causal, window=window,
                      softcap=softcap)
    return jnp.swapaxes(o, 1, 2)


@pytest.mark.parametrize("sq,chunk", [(64, 16), (64, 64), (96, 32)])
@pytest.mark.parametrize("window", [None, 24])
def test_blocked_sdpa_matches_direct(sq, chunk, window):
    q = _rand((2, sq, 4, 16), 0)
    k = _rand((2, sq, 2, 16), 1)
    v = _rand((2, sq, 2, 16), 2)
    got = attention.blocked_sdpa(q, k, v, causal=True, window=window,
                                 q_chunk=chunk)
    want = _direct(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_blocked_sdpa_chunk_invariance():
    """Chunk size must not change the result (flash invariant)."""
    q = _rand((1, 128, 4, 16), 3)
    k = _rand((1, 128, 4, 16), 4)
    v = _rand((1, 128, 4, 16), 5)
    outs = [attention.blocked_sdpa(q, k, v, q_chunk=c)
            for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_full():
    """One-position decode over a cache == the last row of full attention."""
    B, S, H, HK, D = 2, 32, 4, 2, 16
    q_all = _rand((B, S, H, D), 6)
    k = _rand((B, S, HK, D), 7)
    v = _rand((B, S, HK, D), 8)

    class Cfg:
        attn_softcap = None
        n_kv_heads = HK
        hd = D

    full = _direct(q_all, k, v, causal=True)
    # cache padded beyond pos with garbage — mask must hide it
    pad = 8
    kc = jnp.concatenate([k, _rand((B, pad, HK, D), 9) * 100], axis=1)
    vc = jnp.concatenate([v, _rand((B, pad, HK, D), 10) * 100], axis=1)
    got = attention.decode_attention(q_all[:, -1:], kc, vc, Cfg(),
                                     pos=jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


@given(st.integers(0, 1000), st.integers(1, 64))
@settings(max_examples=10)
def test_rope_preserves_norm(seed, pos):
    x = _rand((1, 1, 2, 32), seed)
    cos, sin = layers.rope_angles(jnp.asarray([[pos]]), 32)
    y = layers.apply_rope(x, cos, sin)
    np.testing.assert_allclose(float(jnp.linalg.norm(x)),
                               float(jnp.linalg.norm(y)), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    q = _rand((1, 1, 1, 16), 0)
    k = _rand((1, 1, 1, 16), 1)

    def dot_at(i, j):
        ci, si = layers.rope_angles(jnp.asarray([[i]]), 16)
        cj, sj = layers.rope_angles(jnp.asarray([[j]]), 16)
        qi = layers.apply_rope(q, ci, si)
        kj = layers.apply_rope(k, cj, sj)
        return float(jnp.sum(qi * kj))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), rel=1e-4)


def test_rope_partial_fraction_leaves_tail():
    x = _rand((1, 1, 1, 32), 2)
    cos, sin = layers.rope_angles(jnp.asarray([[9]]), 32, fraction=0.5)
    y = layers.apply_rope(x, cos, sin, fraction=0.5)
    assert (np.asarray(y)[..., 16:] == np.asarray(x)[..., 16:]).all()
    assert not (np.asarray(y)[..., :16] == np.asarray(x)[..., :16]).all()

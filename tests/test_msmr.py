"""MSMR-lite: feature matrices + mutual-information ranking."""
import numpy as np

from repro.core import mining, msmr, sparsity
from tests.conftest import random_dbmart


def test_feature_matrix_presence():
    db = random_dbmart(np.random.default_rng(2), n_patients=20, max_events=12)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = mining.flatten(mined)
    _, _, _, u_key, u_sup, n_u = sparsity.support_counts(seq, pat, msk)
    feats = msmr.top_sequences(u_key, u_sup, k=16)
    fm = msmr.feature_matrix(seq, pat, msk, feats, n_patients=20)
    x = np.asarray(fm.x)
    assert x.shape == (20, 16)
    assert set(np.unique(x)) <= {0.0, 1.0}
    # presence agrees with a direct check for one feature
    fid = int(np.asarray(feats)[0])
    seq_np, pat_np, msk_np = (np.asarray(v) for v in (seq, pat, msk))
    for p in range(20):
        has = bool(((seq_np == fid) & msk_np & (pat_np == p)).any())
        assert bool(x[p, 0] == 1.0) == has


def test_mi_ranks_informative_feature():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 2, 400)
    x = rng.integers(0, 2, (400, 8)).astype(np.float32)
    x[:, 3] = y  # perfectly informative
    x[:, 5] = np.where(rng.random(400) < 0.8, y, 1 - y)  # partially
    scores = np.asarray(msmr.mi_scores(x, y))
    assert int(np.argmax(scores)) == 3
    assert scores[5] > np.delete(scores, [3, 5]).max()


def test_jmi_greedy_selection():
    rng = np.random.default_rng(1)
    y = rng.integers(0, 2, 300)
    x = rng.integers(0, 2, (300, 10)).astype(np.float32)
    x[:, 0] = y
    x[:, 1] = y  # redundant duplicate
    x[:, 2] = np.where(rng.random(300) < 0.75, y, 1 - y)
    sel = msmr.select_jmi(x, y, k=3)
    assert sel[0] == 0 or sel[0] == 1
    assert len(set(sel.tolist())) == 3

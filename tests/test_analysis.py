"""Roofline machinery: HLO collective parser (synthetic text), terms math,
tokenizer roundtrips, sampling properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline as rl
from repro.data import tokenize
from repro.data.dbmart import from_rows
from repro.serving.sampling import sample

SYNTH_HLO = """
HloModule test

%body.1 (p: (s32[], f32[128]{0})) -> (s32[], f32[128]{0}) {
  %ar.1 = f32[128]{0} all-reduce(%x), replica_groups=[4,2]<=[8]
  ROOT %t = (s32[], f32[128]{0}) tuple(%i, %ar.1)
}

ENTRY %main (a: f32[256]) -> f32[256] {
  %w = (s32[], f32[128]{0}) while(%init), condition=%c, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[256]{0} all-gather(%shard), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%big), replica_groups=[2,4]<=[8]
  %cp = f32[256]{0} collective-permute(%a), source_target_pairs={{0,1}}
}
"""


def test_collective_parser_trip_scaling():
    got = rl.collective_bytes(SYNTH_HLO)
    assert got["all-reduce"] == 128 * 4 * 10          # x trip count
    assert got["all-gather"] == 256 * 4 // 4          # operand = out/group
    assert got["reduce-scatter"] == 64 * 4 * 4        # operand = out*group
    assert got["collective-permute"] == 256 * 4


def test_collective_parser_nested_loops():
    nested = """
%inner (p: f32[8]) -> f32[8] {
  %ar = f32[8]{0} all-reduce(%x), replica_groups=[1,8]<=[8]
}
%outer (p: f32[8]) -> f32[8] {
  %w2 = f32[8]{0} while(%i), condition=%c2, body=%inner, backend_config={"known_trip_count":{"n":"5"}}
}
ENTRY %main () -> f32[8] {
  %w1 = f32[8]{0} while(%i), condition=%c1, body=%outer, backend_config={"known_trip_count":{"n":"3"}}
}
"""
    got = rl.collective_bytes(nested)
    assert got["all-reduce"] == 8 * 4 * 5 * 3         # product up the chain


def test_shape_bytes():
    assert rl.shape_bytes("bf16", "2,3,4") == 48
    assert rl.shape_bytes("f32", "") == 4
    assert rl.shape_bytes("pred", "128") == 128


def test_roofline_terms_and_dominant():
    r = rl.Roofline(arch="a", shape="s", chips=256, hlo_flops=1e18,
                    hlo_bytes=1e12, coll_bytes=1e15, coll_breakdown={},
                    model_flops=5e17)
    assert r.t_compute == pytest.approx(1e18 / (256 * rl.PEAK_FLOPS))
    assert r.t_memory == pytest.approx(1e12 / (256 * rl.HBM_BW))
    assert r.t_collective == pytest.approx(1e15 / (256 * rl.ICI_BW))
    assert r.dominant == "collective"
    assert 0 < r.roofline_fraction < 1
    assert r.useful_ratio == pytest.approx(0.5)


def test_tokenizer_roundtrip_and_gaps():
    db = from_rows([0, 0, 0], [10, 10, 74], ["A", "B", "A"])
    docs = tokenize.patient_documents(db)
    assert len(docs) == 1
    d = docs[0]
    assert d[0] == tokenize.BOS and d[-1] == tokenize.EOS
    # A, gap(0), B, gap(64), A
    xs = [t - tokenize.PHENX_OFFSET for t in d[1::2]]
    assert xs == [db.vocab.phenx_index["A"], db.vocab.phenx_index["B"],
                  db.vocab.phenx_index["A"]]
    gaps = [int(t) - 4 for t in d[2::2][:2]]
    assert gaps == [int(tokenize.gap_bucket(0)), int(tokenize.gap_bucket(64))]


def test_pack_corpus_shapes_and_mask():
    db = from_rows([0, 1, 1], [1, 2, 3], ["X", "Y", "Z"])
    c = tokenize.pack_corpus(db, seq_len=8)
    assert c.tokens.shape[1] == 8
    assert (c.loss_mask == (c.tokens != tokenize.PAD)).all()


def test_sampling_greedy_and_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(logits)[0]) == 1
    rng = jax.random.PRNGKey(0)
    draws = {int(sample(logits, jax.random.fold_in(rng, i),
                        temperature=1.0, top_k=2)[0]) for i in range(50)}
    assert draws <= {1, 2}
    assert 1 in draws


def test_count_params_moe_active():
    from repro.configs import get_config

    cfg = get_config("deepseek-moe-16b")
    total, active = rl.count_params(cfg)
    assert 15e9 < total < 20e9          # ~16B as published
    assert 2e9 < active < 4e9           # ~2.8B active as published

"""Input-pipeline balancing: remainder-shard regression + invariants."""
import numpy as np

from repro.data.pipeline import balance_buckets, balance_patients


def test_balance_patients_remainder_not_piled_on_shard0():
    """P % n_shards != 0: every bucket used to gate at floor(P/S), so the
    remainder patients all silently landed in shard 0."""
    nevents = np.full(10, 20, np.int64)   # uniform cost, P=10, S=4
    buckets = balance_buckets(nevents, 4)
    sizes = sorted(len(b) for b in buckets)
    assert max(sizes) <= -(-10 // 4)      # ceil capacity respected
    assert sizes == [2, 2, 3, 3]          # not [2, 2, 2, 4]


def test_balance_patients_remainder_is_permutation_and_balanced():
    rng = np.random.default_rng(7)
    for P, S in [(10, 4), (13, 8), (257, 8), (5, 7)]:
        nevents = rng.integers(1, 200, P)
        perm = balance_patients(nevents, S)
        assert sorted(perm.tolist()) == list(range(P))
        buckets = balance_buckets(nevents, S)
        assert max(len(b) for b in buckets) <= -(-P // S)


def test_balance_patients_cost_balance_with_remainder():
    rng = np.random.default_rng(11)
    nevents = rng.integers(1, 300, 250)   # 250 % 8 != 0
    buckets = balance_buckets(nevents, 8)
    cost = nevents.astype(np.int64) * (nevents.astype(np.int64) - 1) // 2
    loads = np.asarray([cost[b].sum() for b in buckets])
    assert loads.max() <= 1.35 * max(loads.mean(), 1)

"""Adaptive partitioning + file-based mode == in-memory single shot."""
import numpy as np

from repro.core import chunking, mining, sparsity
from repro.data import synthea
from repro.data.dbmart import from_rows
from tests.conftest import random_dbmart


def _flat_set(seq, dur, pat, mask):
    seq, dur, pat, mask = (np.asarray(x) for x in (seq, dur, pat, mask))
    return set(zip(seq[mask].tolist(), dur[mask].tolist(), pat[mask].tolist()))


def test_plan_chunks_budget_and_cover():
    nevents = np.random.default_rng(0).integers(1, 200, 500).astype(np.int32)
    budget = 4 << 20
    chunks = chunking.plan_chunks(nevents, budget)
    assert chunks[0].start == 0 and chunks[-1].stop == 500
    for a, b in zip(chunks, chunks[1:]):
        assert a.stop == b.start
    for c in chunks:
        cost = c.n_patients * c.max_events ** 2 * chunking.BYTES_PER_PAIR * 0.5
        assert cost <= budget or c.n_patients == 1
        assert c.max_events >= int(nevents[c.start:c.stop].max())


def test_chunked_equals_unchunked():
    db = random_dbmart(np.random.default_rng(5), n_patients=40, max_events=24)
    whole = mining.mine_triangular(db.phenx, db.date, db.nevents)
    seq, dur, pat, msk = mining.flatten(whole)
    expect = _flat_set(seq, dur, pat, msk)
    out = chunking.mine_chunked(db, budget_bytes=64 << 10)
    got = _flat_set(out["seq"], out["dur"], out["patient"], out["mask"])
    assert got == expect


def test_chunked_screen_matches_global(tmp_path):
    pats, dates, phx, _ = synthea.generate_cohort(n_patients=64, avg_events=16, seed=2)
    db = from_rows(pats, dates, phx)
    threshold = 4
    whole = mining.mine_triangular(db.phenx, db.date, db.nevents)
    keep_ref = np.asarray(sparsity.screen_hash(whole.seq, whole.mask, threshold,
                                               n_buckets_log2=22))
    n_ref = int(keep_ref.sum())

    out = chunking.mine_chunked(db, budget_bytes=128 << 10, threshold=threshold)
    assert int(out["keep"].sum()) == n_ref

    # file-based mode agrees too
    paths = chunking.mine_to_files(db, str(tmp_path / "spill"),
                                   budget_bytes=128 << 10)
    assert len(paths) > 1
    n_file = sum(len(part["seq"]) for part in
                 chunking.screen_files(str(tmp_path / "spill"), threshold))
    assert n_file == n_ref


def test_hash_screen_threshold_edge(tmp_path):
    """Support exactly == threshold must survive in BOTH file-based and
    in-memory modes (the screen is `>= threshold`), and == threshold-1
    must be dropped — with per-patient chunks, so the count only reaches
    the threshold after the cross-chunk table merge."""
    n_support = 5
    pats = [p for p in range(n_support) for _ in range(2)]
    dates = [d for _ in range(n_support) for d in (0, 10)]
    phx = [x for _ in range(n_support) for x in ("A", "B")]
    db = from_rows(pats, dates, phx)
    budget = 900            # one patient per chunk: 8*8*26*0.5 = 832 bytes
    assert len(chunking.plan_chunks(np.asarray(db.nevents), budget)) \
        == n_support

    for threshold, survives in ((n_support, True), (n_support + 1, False)):
        out = chunking.mine_chunked(db, budget_bytes=budget,
                                    threshold=threshold)
        assert int(out["keep"].sum()) == (n_support if survives else 0)

        chunking.mine_to_files(db, str(tmp_path / f"spill{threshold}"),
                               budget_bytes=budget)
        n_file = sum(len(part["seq"]) for part in chunking.screen_files(
            str(tmp_path / f"spill{threshold}"), threshold))
        assert n_file == (n_support if survives else 0)

    # load_files round-trips the unscreened corpus + merged table
    out = chunking.load_files(str(tmp_path / f"spill{n_support}"))
    assert len(out["seq"]) == n_support
    # one distinct id, deduped per patient: n_support contributions total
    assert int(out["counts"].sum()) == n_support
    ref = chunking.mine_chunked(db, budget_bytes=budget, with_counts=True)
    assert (out["counts"] == ref["counts"]).all()


def test_scheduler_work_stealing():
    from repro.data.pipeline import ChunkScheduler

    db = random_dbmart(np.random.default_rng(1), n_patients=64, max_events=16)
    sched = ChunkScheduler(db, budget_bytes=32 << 10)
    assert len(sched.chunks) > 2
    results = sched.run(lambda c: c.n_patients, n_workers=3)
    assert sum(results) == 64
    assert len(sched.completed) == len(sched.chunks)


def test_balance_patients_lpt():
    from repro.data.pipeline import balance_patients

    nevents = np.random.default_rng(3).integers(1, 300, 256)
    perm = balance_patients(nevents, 8)
    assert sorted(perm.tolist()) == list(range(256))
    cost = nevents[perm].astype(np.int64)
    cost = cost * (cost - 1) // 2
    shard = cost.reshape(8, 32).sum(1)
    assert shard.max() <= 1.35 * max(shard.mean(), 1)

"""Pairgen Pallas kernel vs jnp oracle: shape sweeps + properties."""
import numpy as np
import pytest

from repro.core import mining
from repro.kernels.tspm_pairgen import ops, pairgen, ref
from tests.conftest import random_dbmart


@pytest.mark.parametrize("P,E", [(1, 8), (3, 16), (8, 48), (16, 130), (7, 129)])
def test_pairgen_shapes(P, E):
    db = random_dbmart(np.random.default_rng(P * 1000 + E),
                       n_patients=P, max_events=E)
    got = ops.pairgen(db.phenx, db.date, db.nevents, interpret=True)
    want = mining.mine_dense(db.phenx, db.date, db.nevents)
    m = np.asarray(want.mask)
    assert (np.asarray(got.mask) == m).all()
    assert (np.asarray(got.seq)[m] == np.asarray(want.seq)[m]).all()
    assert (np.asarray(got.dur)[m] == np.asarray(want.dur)[m]).all()


@pytest.mark.parametrize("codec", ["bit", "paper"])
@pytest.mark.parametrize("fuse", [False, True])
def test_pairgen_codecs_and_fusion(codec, fuse):
    db = random_dbmart(np.random.default_rng(5), n_patients=6, max_events=20)
    got = ops.pairgen(db.phenx, db.date, db.nevents, codec=codec,
                      fuse_duration=fuse, interpret=True)
    want = mining.mine_dense(db.phenx, db.date, db.nevents, codec=codec,
                             fuse_duration=fuse)
    m = np.asarray(want.mask)
    assert (np.asarray(got.seq)[m] == np.asarray(want.seq)[m]).all()


@pytest.mark.parametrize("pb,tile", [(1, 128), (2, 128), (8, 128), (8, 256)])
def test_pairgen_block_shapes(pb, tile):
    db = random_dbmart(np.random.default_rng(9), n_patients=8, max_events=64)
    got = ops.pairgen(db.phenx, db.date, db.nevents, pb=pb, tile=tile,
                      interpret=True)
    want = mining.mine_dense(db.phenx, db.date, db.nevents)
    m = np.asarray(want.mask)
    assert (np.asarray(got.seq)[m] == np.asarray(want.seq)[m]).all()


def test_planes_ref_matches_planes_kernel():
    db = random_dbmart(np.random.default_rng(2), n_patients=8, max_events=32)
    E = 128
    ph = np.zeros((8, E), np.int32)
    dt = np.zeros((8, E), np.int32)
    ph[:, :32] = db.phenx[:, :32]
    dt[:, :32] = db.date[:, :32]
    s, e, d, m = pairgen.pairgen_planes(ph, dt, db.nevents, pb=8, ti=128,
                                        tj=128, interpret=True)
    sr, er, dr, mr = ref.pairgen_planes_ref(ph, dt, db.nevents)
    assert (np.asarray(m) == np.asarray(mr)).all()
    assert (np.asarray(s) == np.asarray(sr)).all()
    assert (np.asarray(e) == np.asarray(er)).all()
    assert (np.asarray(d) == np.asarray(dr)).all()


def test_pairgen_is_lowerable_for_tpu_style_blocks():
    """The kernel traces + lowers with MXU-aligned blocks (no interpret)."""
    import jax

    db = random_dbmart(np.random.default_rng(4), n_patients=8, max_events=100)
    fn = lambda p, d, n: ops.pairgen(p, d, n, interpret=True)
    jax.jit(fn).lower(db.phenx, np.asarray(db.date), db.nevents)

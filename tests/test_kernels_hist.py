"""Histogram kernel vs scatter oracle + integration with the hash screen."""
import numpy as np
import pytest

from repro.core import mining, sparsity
from repro.kernels.seq_hist import ops, ref, seq_hist
from tests.conftest import random_dbmart


@pytest.mark.parametrize("R,T,B", [(8, 128, 512), (16, 256, 1024),
                                   (8, 512, 4096), (4, 64, 512)])
def test_hist_matches_ref(R, T, B):
    rng = np.random.default_rng(R * T)
    h = rng.integers(0, B, (R, T)).astype(np.int32)
    m = rng.random((R, T)) < 0.7
    got = np.asarray(seq_hist.hist(h, m, B, bt=min(512, B),
                                   rows=4 if R % 4 == 0 else 1, interpret=True))
    want = np.asarray(ref.hist_ref(h, m, B))
    assert (got == want).all()
    assert got.sum() == m.sum()


def test_bucket_counts_dedupes_per_patient():
    """Same sequence twice for one patient counts once (paper semantics)."""
    seq = np.asarray([[7, 7, 9], [7, 5, 5]], np.int64)
    mask = np.ones((2, 3), bool)
    c_kernel = np.asarray(ops.bucket_counts(seq, mask, 10, interpret=True,
                                            force_kernel=True))
    c_ref = np.asarray(sparsity.local_bucket_counts(seq, mask, 10))
    assert (c_kernel == c_ref).all()
    h7 = int(np.asarray(sparsity.hash_bucket(np.int64(7), 10)))
    assert c_kernel[h7] == 2  # two patients, once each


def test_bucket_counts_matches_sparsity_module():
    db = random_dbmart(np.random.default_rng(3), n_patients=8, max_events=16)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    for H in (10, 12, 14):
        a = np.asarray(ops.bucket_counts(mined.seq, mined.mask, H,
                                         interpret=True, force_kernel=True))
        b = np.asarray(sparsity.local_bucket_counts(mined.seq, mined.mask, H))
        assert (a == b).all()


def test_large_table_falls_back_to_scatter():
    db = random_dbmart(np.random.default_rng(1), n_patients=4, max_events=12)
    mined = mining.mine_triangular(db.phenx, db.date, db.nevents)
    a = np.asarray(ops.bucket_counts(mined.seq, mined.mask, 20))
    b = np.asarray(sparsity.local_bucket_counts(mined.seq, mined.mask, 20))
    assert (a == b).all()

"""Post-COVID WHO-definition pipeline (paper vignette 2) vs ground truth."""
import numpy as np
import pytest

from repro.core import mining, postcovid
from repro.data import dbmart, synthea


def _run(seed, n=200):
    pats, dates, phx, truth = synthea.generate_cohort(
        n_patients=n, avg_events=40, seed=seed)
    db = dbmart.from_rows(pats, dates, phx)
    mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
    seq, dur, pat, msk = mining.flatten(mined)
    cfg = postcovid.PostCovidConfig(covid_id=db.vocab.phenx_index[synthea.COVID])
    pcc, cand = postcovid.identify(seq, dur, pat, msk, db.phenx, db.nevents,
                                   cfg, db.n_patients, db.vocab.n_phenx)
    return db, truth, np.asarray(pcc), np.asarray(cand)


@pytest.mark.parametrize("seed", [3, 7, 11])
def test_symptom_f1(seed):
    db, truth, pcc, _ = _run(seed)
    pred = postcovid.decode_symptoms(pcc, db.vocab)
    tp = fp = fn = 0
    for p in range(db.n_patients):
        t, pr = truth.symptom_sets[p], pred[p]
        tp += len(t & pr)
        fp += len(pr - t)
        fn += len(t - pr)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    assert rec >= 0.95, f"recall {rec}"
    assert f1 >= 0.85, f"f1 {f1}"


def test_patient_level_accuracy():
    db, truth, pcc, _ = _run(13)
    acc = (pcc.any(1) == truth.long_covid).mean()
    assert acc >= 0.85


def test_crafted_fixture():
    """Hand-built cohort: one clean PCC case, one competing-cause control,
    one transient-acute control, one covid-free patient."""
    rows = []

    def add(p, d, x):
        rows.append((p, d, x))

    # enough covid-free "background" patients to make run-rate stats work
    for p in range(4, 14):
        for k in range(6):
            add(p, 50 + 37 * k, "Lab")
    # patient 0: textbook PCC (fatigue run, new onset, no competitor)
    add(0, 100, "COVID-19")
    for k in range(4):
        add(0, 160 + 30 * k, "Fatigue")
    for k in range(5):
        add(0, 20 + 50 * k, "Lab")
    # patient 1: fatigue run anchored by influenza -> must be excluded
    add(1, 100, "COVID-19")
    add(1, 300, "Influenza")
    for k in range(4):
        add(1, 303 + 30 * k, "Fatigue")
    # patient 2: transient acute fatigue only (short spread)
    add(2, 100, "COVID-19")
    add(2, 105, "Fatigue")
    add(2, 112, "Fatigue")
    # patient 3: no covid, has fatigue-like lab runs
    for k in range(5):
        add(3, 80 + 40 * k, "Lab")
    # a couple more flu-anchored patients so the anchor rate is significant
    for p in (14, 15):
        add(p, 90, "COVID-19")
        add(p, 280, "Influenza")
        for k in range(4):
            add(p, 283 + 30 * k, "Fatigue")

    pats = [r[0] for r in rows]
    dates = [r[1] for r in rows]
    phx = [r[2] for r in rows]
    db = dbmart.from_rows(pats, dates, phx)
    mined = mining.mine(db.phenx, db.date, db.nevents, backend="jnp")
    seq, dur, pat, msk = mining.flatten(mined)
    cfg = postcovid.PostCovidConfig(covid_id=db.vocab.phenx_index["COVID-19"])
    pcc, cand = postcovid.identify(seq, dur, pat, msk, db.phenx, db.nevents,
                                   cfg, db.n_patients, db.vocab.n_phenx)
    pred = postcovid.decode_symptoms(np.asarray(pcc), db.vocab)
    # patient ids are renumbered by first appearance (paper's running
    # numbers) — map original ids through the lookup table
    row = db.vocab.patient_index
    assert pred[row[0]] == {"Fatigue"}     # clean PCC detected
    assert pred[row[1]] == set()           # explained by influenza
    assert pred[row[2]] == set()           # transient, spread < 2 months
    assert pred[row[3]] == set()           # no covid at all
    # candidates included patient 1's fatigue before exclusion
    fat = db.vocab.phenx_index["Fatigue"]
    assert bool(np.asarray(cand)[row[1], fat])

"""Telemetry subsystem: registry semantics, span trees, exactness, retraces.

The observability layer's contract has three legs, all tested here:

  * **recording** — counters/gauges/histograms resolve once and mutate in
    place, labels key distinct series, spans nest per track with legal
    out-of-order finishes, and both exports (nested JSON, Chrome trace)
    round-trip;
  * **absence** — disabled telemetry is the shared no-op singletons:
    identical object every call, zero allocations on the hot path;
  * **exactness** — telemetry never changes a mined byte, across all five
    planner engines, and the jitted ingest still recompiles O(log) times
    over a 200-tick growing stream (the retrace counter measures the
    invariant the geometric-growth policy promises).

A subprocess case forces 2 host devices and requires the per-shard
``tick.device`` spans to *overlap* in time under device placement while
``shard_load()`` reports consumable busy fractions — the async dispatch
win, measured rather than asserted from code structure.
"""
import json
import os
import subprocess
import sys
import textwrap
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.api import MiningConfig, MiningSession
from repro.stream.shard import ShardedStreamService, ShardRouter
from tests.conftest import random_dbmart
from tests.test_stream import H


# --- metrics registry -------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    reg = obs.MetricsRegistry()
    c = reg.counter("ticks")
    c.inc()
    c.inc(4)
    assert c.value == 5 and reg.value("ticks") == 5

    g = reg.gauge("depth")
    g.set(7)
    g.set(3)
    assert g.value == 3

    h = reg.histogram("lat")
    for v in (2e-6, 3e-6, 1e-3, 5.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4
    assert s["min"] == 2e-6 and s["max"] == 5.0
    assert abs(s["sum"] - (2e-6 + 3e-6 + 1e-3 + 5.0)) < 1e-12
    assert sum(s["buckets"].values()) == 4
    # 2us and 3us land in different exponential buckets (bounds are 2^i us)
    assert len(s["buckets"]) >= 3


def test_registry_labels_and_same_object():
    reg = obs.MetricsRegistry()
    a0 = reg.counter("evts", shard=0)
    a1 = reg.counter("evts", shard=1)
    assert a0 is not a1
    a0.inc(3)
    assert reg.value("evts", shard=0) == 3
    assert reg.value("evts", shard=1) == 0
    # same key resolves to the same object, from any layer
    assert reg.counter("evts", shard=0) is a0
    with pytest.raises(TypeError):
        reg.gauge("evts", shard=0)      # kind change is an error
    snap = reg.snapshot()
    assert snap["evts{shard=0}"] == 3 and snap["evts{shard=1}"] == 0


def test_registry_reset_keeps_cached_references():
    reg = obs.MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("t")
    c.inc(9)
    h.observe(1.0)
    reg.reset()
    assert c.value == 0 and h.count == 0 and h.summary()["buckets"] == {}
    c.inc()                             # cached reference still records
    assert reg.value("n") == 1


def test_histogram_rejects_bad_config():
    with pytest.raises(ValueError):
        obs.Histogram(base=1.0)
    with pytest.raises(ValueError):
        obs.Histogram(scale=0.0)


# --- span tracer ------------------------------------------------------------

def test_span_nesting_and_json_forest():
    tr = obs.SpanTracer()
    with tr.span("outer", track="main"):
        with tr.span("inner", track="main", n=3):
            pass
        with tr.span("inner2", track="main"):
            pass
    other = tr.begin("solo", track="side")
    tr.finish(other)
    forest = tr.to_json()
    roots = {n["name"] for n in forest}
    assert roots == {"outer", "solo"}
    outer = next(n for n in forest if n["name"] == "outer")
    assert [c["name"] for c in outer["children"]] == ["inner", "inner2"]
    assert outer["children"][0]["args"] == {"n": 3}
    assert all(n["t1"] >= n["t0"] for n in forest)


def test_out_of_order_finish_is_legal():
    """Async regions close in any order: the device span opened at
    dispatch outlives the collect span opened after it."""
    tr = obs.SpanTracer()
    d0 = tr.begin("device", track="shard0")
    d1 = tr.begin("device", track="shard1")
    tr.finish(d1)                       # shard1 collected first
    c0 = tr.begin("collect", track="shard0")
    tr.finish(c0)
    tr.finish(d0)
    # collect began while device was open on the same track -> nested
    forest = tr.to_json()
    by_track = {n["track"]: n for n in forest}
    assert by_track["shard0"]["name"] == "device"
    assert [c["name"] for c in by_track["shard0"]["children"]] == ["collect"]
    assert tr.find("device", track="shard1")[0]["t1"] is not None \
        if isinstance(tr.find("device", track="shard1")[0], dict) \
        else tr.find("device", track="shard1")[0].t1 is not None


def test_chrome_trace_roundtrip(tmp_path):
    tr = obs.SpanTracer()
    with tr.span("tick", track="shard0", cat="host", pairs=12):
        pass
    with tr.span("tick", track="shard1", cat="device"):
        pass
    path = tmp_path / "trace.json"
    tr.dump_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert {m["args"]["name"] for m in meta} == {"shard0", "shard1"}
    assert all(m["name"] == "thread_name" for m in meta)
    assert len(spans) == 2
    assert {s["tid"] for s in spans} == {m["tid"] for m in meta}
    tick0 = next(s for s in spans if s["cat"] == "host")
    assert tick0["args"] == {"pairs": 12}
    assert all(s["dur"] >= 0 and s["ts"] >= 0 for s in spans)


# --- disabled telemetry: no-ops, no allocations -----------------------------

def test_noop_singletons_are_shared():
    assert obs.NOOP.metrics is obs.NOOP_REGISTRY
    assert obs.NOOP.tracer is obs.NOOP_TRACER
    assert not obs.NOOP.enabled
    r = obs.NOOP_REGISTRY
    assert r.counter("a") is r.gauge("b") is r.histogram("c", shard=1)
    assert r.counter("a") is obs.NOOP_METRIC
    assert obs.NOOP_TRACER.begin("x") is obs.NOOP_TRACER.begin("y")
    assert obs.NOOP.snapshot() == {}
    assert obs.NOOP_TRACER.to_chrome_trace()["traceEvents"] == []


def test_noop_hot_path_allocates_nothing():
    m = obs.NOOP_METRIC
    sp_tracer = obs.NOOP_TRACER
    # warm any lazy interning
    m.inc()
    m.set(1.0)
    m.observe(0.5)
    sp = sp_tracer.begin("t")
    sp_tracer.finish(sp)
    tracemalloc.start()
    base = tracemalloc.take_snapshot()
    for _ in range(1000):
        m.inc()
        m.inc(2)
        m.set(3.5)
        m.observe(1e-3)
        s = sp_tracer.begin("tick", track="shard0", pairs=1)
        sp_tracer.finish(s)
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    grown = sum(d.size_diff for d in after.compare_to(base, "lineno")
                if d.size_diff > 0)
    # a few hundred bytes of slack for tracemalloc's own bookkeeping;
    # a real per-call allocation over 5000 calls would be tens of KiB
    assert grown < 4096, f"no-op hot path grew {grown} bytes"


# --- exactness: telemetry never changes mined bytes -------------------------

@pytest.mark.parametrize("engine", ["batch", "chunked", "files", "stream",
                                    "sharded"])
def test_byte_identical_on_off(engine):
    rng = np.random.default_rng(hash(engine) % (1 << 30))
    db = random_dbmart(rng, n_patients=10, max_events=12)
    frames = {}
    for tel in (False, True):
        cfg = MiningConfig(engine=engine, screen="hash", n_buckets_log2=H,
                           threshold=2, tick_patients=3,
                           n_shards=2 if engine == "sharded" else 1,
                           telemetry=tel)
        frames[tel] = MiningSession(cfg).fit(db)
    for a, b in zip(frames[False].arrays(), frames[True].arrays()):
        assert np.array_equal(np.asarray(a), np.asarray(b)), engine
    assert (frames[False]._corpus.counts()
            == frames[True]._corpus.counts()).all()
    assert frames[False].screen().n_kept == frames[True].screen().n_kept


def test_session_accessors_require_telemetry():
    s = MiningSession(MiningConfig())
    with pytest.raises(RuntimeError):
        s.metrics()
    with pytest.raises(RuntimeError):
        s.trace()
    s_on = MiningSession(MiningConfig(telemetry=True))
    assert s_on.metrics() == {}          # empty but live
    assert s_on.trace() is s_on.telemetry.tracer


def test_session_metrics_record_mining():
    rng = np.random.default_rng(5)
    db = random_dbmart(rng, n_patients=8, max_events=10)
    s = MiningSession(MiningConfig(engine="stream", telemetry=True,
                                   tick_patients=3, screen="hash",
                                   n_buckets_log2=H))
    s.fit(db)
    snap = s.metrics()
    assert snap["stream.ticks"] > 0
    assert snap["stream.events"] == int(db.nevents.sum())
    assert snap["stream.tick.dispatch_s"]["count"] == snap["stream.ticks"]
    # only patients with events are ever submitted/admitted
    assert snap["store.admits"] == int((np.asarray(db.nevents) > 0).sum())
    assert "sketch.bucket_load_factor" in snap
    fit_spans = s.trace().find("session.fit")
    assert len(fit_spans) == 1 and fit_spans[0].args["engine"] == "stream"
    # tick spans: dispatch/device/collect per tick, on the stream track
    n_ticks = snap["stream.ticks"]
    assert len(s.trace().find("tick.dispatch")) == n_ticks
    assert len(s.trace().find("tick.device")) == n_ticks
    assert len(s.trace().find("tick.collect")) == n_ticks


# --- TickStats split (the overlapping-wall fix) -----------------------------

def test_tick_stats_split_populated_without_telemetry():
    """dispatch/collect/device splits are plain perf_counter reads, so
    they are populated even with telemetry off (benchmarks rely on it)."""
    from repro.stream.service import StreamService

    svc = StreamService(tick_patients=4, n_buckets_log2=H)
    rng = np.random.default_rng(2)
    db = random_dbmart(rng, n_patients=6, max_events=8)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        if n:
            svc.submit(p, db.date[p, :n], db.phenx[p, :n])
    stats = svc.run()
    assert stats
    for st in stats:
        assert st.dispatch_s > 0 and st.collect_s > 0 and st.device_s >= 0
        # the split partitions the begin->finish wall: components can
        # never exceed it (small float slack for the two clock reads)
        assert st.dispatch_s + st.device_s + st.collect_s \
            <= st.wall_s + 1e-6


# --- retrace budget: O(log) recompiles over a growing stream ----------------

def test_retrace_budget_over_growing_stream():
    """200 ticks of ever-growing histories: the geometric capacity policy
    must keep jitted-ingest recompiles O(log total work), measured by the
    jit.retraces counter (satellite of the tick-latency histogram — a
    per-tick retrace would show up as ~200 here)."""
    from repro.stream.service import StreamService

    tel = obs.Telemetry()
    svc = StreamService(tick_patients=4, n_buckets_log2=H, telemetry=tel)
    rng = np.random.default_rng(9)
    n_ticks = 200
    total_events = 0
    for t in range(n_ticks):
        for p in range(int(rng.integers(1, 4))):
            k = int(rng.integers(6))
            n = int(rng.integers(1, 4))
            dates = np.arange(total_events, total_events + n, dtype=np.int32)
            svc.submit(k, dates, rng.integers(0, 5, n).astype(np.int32))
            total_events += n
        svc.run()
    snap = tel.metrics.snapshot()
    assert snap["stream.ticks"] >= n_ticks
    retraces = snap["jit.retraces"]
    budget = 6 * int(np.ceil(np.log2(total_events + 2))) + 12
    assert retraces <= budget, \
        f"{retraces} recompiles over {total_events} events " \
        f"(budget {budget}): ingest is retracing per tick, not O(log)"


# --- device-timed busy signal + busy-weighted rebalance ---------------------

def test_shard_load_fractions():
    svc = ShardedStreamService(n_shards=2, tick_patients=3,
                               n_buckets_log2=H)
    rng = np.random.default_rng(4)
    db = random_dbmart(rng, n_patients=8, max_events=10)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        if n:
            svc.submit(p, db.date[p, :n], db.phenx[p, :n])
    svc.run()
    fracs = svc.shard_load()
    assert len(fracs) == 2
    assert all(0.0 <= f <= 1.0 for f in fracs)
    assert any(f > 0.0 for f in fracs)   # something ran on some shard
    # the window reset: an immediate re-poll has accumulated ~nothing
    again = svc.shard_load()
    assert all(f < 0.5 for f in again)


def test_busy_weighted_rebalance_exact_and_converges():
    """Weights skew the LPT toward idle shards without changing mined
    results; degenerate weights (all-zero, mismatched length) are
    handled; the safety cap stops any weighted ping-pong."""
    rng = np.random.default_rng(6)
    db = random_dbmart(rng, n_patients=12, max_events=12)
    from tests.test_stream import batch_reference
    from tests.test_stream_sharded import sharded_triples

    seq, dur, pat, msk, cnt = batch_reference(db)
    svc = ShardedStreamService(
        n_shards=3, tick_patients=3, n_buckets_log2=H,
        router=ShardRouter(3, pinned={p: 0 for p in range(db.n_patients)}))
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        if n:
            svc.submit(p, db.date[p, :n], db.phenx[p, :n])
    svc.run()
    # shard 0 holds everything; pretend it is also the busiest device
    moves = svc.rebalance(imbalance_threshold=1.1,
                          busy_weights=[0.9, 0.1, 0.1])
    assert moves                         # the hot shard drained
    assert all(src == 0 for _, src, _ in moves)
    # all-zero weights (nothing polled) fall back to unweighted
    svc.rebalance(busy_weights=[0.0, 0.0, 0.0])
    with pytest.raises(ValueError):
        svc.rebalance(busy_weights=[1.0, 1.0])
    snap, keys = sharded_triples(svc)
    assert sorted(zip(keys, snap.seq, snap.dur)) \
        == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (np.asarray(snap.counts) == cnt).all()


def test_busy_weighted_auto_rebalance_exactness():
    """config-driven: busy_weighted_rebalance + rebalance_every feeds
    shard_load() into the periodic LPT pass; results stay batch-exact."""
    rng = np.random.default_rng(13)
    db = random_dbmart(rng, n_patients=10, max_events=12)
    from tests.test_stream import batch_reference

    seq, dur, pat, msk, cnt = batch_reference(db)
    session = MiningSession(MiningConfig(
        engine="sharded", n_shards=3, tick_patients=2, screen="hash",
        n_buckets_log2=H, rebalance_every=2, imbalance_threshold=1.1,
        busy_weighted_rebalance=True, telemetry=True))
    frame = session.fit(db)
    got = sorted(zip(*(np.asarray(a) for a in
                       (frame.arrays()[2], frame.arrays()[0],
                        frame.arrays()[1]))))
    assert got == sorted(zip(pat[msk], seq[msk], dur[msk]))
    assert (frame._corpus.counts() == cnt).all()


def test_overlapping_device_spans_on_forced_devices():
    """2 forced host devices, device placement, telemetry on: per-shard
    ``tick.device`` spans must overlap in wall time (the dispatched waves
    really run concurrently) and shard_load() must return busy fractions
    the rebalancer can consume."""
    script = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 2, jax.devices()
        from repro import obs
        from repro.stream.shard import ShardedStreamService
        from tests.conftest import random_dbmart
        from tests.test_stream import H

        tel = obs.Telemetry()
        svc = ShardedStreamService(n_shards=2, placement="devices",
                                   tick_patients=4, n_buckets_log2=H,
                                   telemetry=tel)
        rng = np.random.default_rng(21)
        db = random_dbmart(rng, n_patients=12, max_events=14)
        for p in range(db.n_patients):
            n = int(db.nevents[p])
            if n:
                svc.submit(p, db.date[p, :n], db.phenx[p, :n])
        svc.run()

        d0 = tel.tracer.find("tick.device", track="shard0")
        d1 = tel.tracer.find("tick.device", track="shard1")
        assert d0 and d1, (len(d0), len(d1))
        overlaps = [
            (a, b) for a in d0 for b in d1
            if max(a.t0, b.t0) < min(a.t1, b.t1)]
        if not overlaps:
            raise SystemExit("no overlapping device spans across shards")
        fracs = svc.shard_load()
        assert len(fracs) == 2 and all(0.0 <= f <= 1.0 for f in fracs)
        assert any(f > 0.0 for f in fracs), fracs
        # the busy signal is consumable by the weighted rebalancer
        svc.rebalance(busy_weights=fracs)
        doc = tel.tracer.to_chrome_trace()
        tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
        assert len(tids) >= 2, tids
        print("obs-overlap-ok", len(overlaps))
    """)
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(repo, "src"), repo,
                    env.get("PYTHONPATH", "")] if p)
    proc = subprocess.run([sys.executable, "-c", script], cwd=repo, env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "obs-overlap-ok" in proc.stdout


# --- serving metrics: recorded when on, the shared no-ops when off ----------

def _served_session(telemetry: bool):
    rng = np.random.default_rng(59)
    db = random_dbmart(rng, n_patients=6, max_events=10)
    session = MiningSession(MiningConfig(threshold=2, screen="hash",
                                         n_buckets_log2=H,
                                         telemetry=telemetry))
    session.fit(db)
    return session, int(np.unique(db.phenx[db.phenx >= 0])[0])


def test_serve_metrics_disabled_are_noop_singletons():
    """With telemetry off the server resolves every serve.* instrument to
    the shared no-op objects — the query hot path records nothing,
    allocates no metric state, and ``stats()`` still reports plain
    numbers from its own counters."""
    from repro.serving.tspm import plan

    session, code = _served_session(telemetry=False)
    server = session.serve()
    for m in (server._m_queries, server._m_waves, server._m_occupancy,
              server._m_hits, server._m_misses, server._m_evictions,
              server._m_hit_ratio, server._m_staleness, server._m_wait,
              server._m_eval):
        assert m is obs.NOOP_METRIC
    assert server._tracer is obs.NOOP_TRACER
    server.query(plan().screen(2).starts_with(code))
    server.query(plan().screen(2).starts_with(code))
    st = server.stats()
    assert st["queries"] == 2 and st["cache_hits"] == 1
    assert session.telemetry.metrics.snapshot() == {}


def test_serve_metrics_and_spans_recorded():
    from repro.serving.tspm import plan

    session, code = _served_session(telemetry=True)
    with session.serve() as server:
        p = plan().screen(2).starts_with(code)
        server.submit(p).result(timeout=60)
        server.query(p)
    snap = session.telemetry.metrics.snapshot()
    assert snap["serve.queries"] == 2
    assert snap["serve.waves"] == 1            # the second query was a hit
    assert snap["serve.cache.hits"] == 1
    assert snap["serve.cache.misses"] == 1
    assert snap["serve.cache.hit_ratio"] == 0.5
    assert snap["serve.batch_occupancy"]["count"] == 1
    assert snap["serve.eval_s"]["count"] == 2
    assert snap["serve.wait_s"]["count"] == 1  # only the submitted query
    evs = session.telemetry.tracer.to_chrome_trace()["traceEvents"]
    names = {e["name"] for e in evs if e.get("ph") == "X"}
    assert {"serve.eval", "serve.wait"} <= names
    serve_meta = [e for e in evs if e.get("ph") == "M"
                  and e["args"].get("name") == "serve"]
    assert serve_meta, "serve spans are not on their own track"

"""Naive full-softmax attention oracle (f32) with the same mask options."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float | None = None, scale: float | None = None):
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    o = jnp.einsum("bhqk,bhkd->bhqd",
                   p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30), vq)
    return o.astype(q.dtype)

"""Blocked online-softmax attention (flash) Pallas kernel.

The LM-side compute hot spot for train_4k / prefill_32k shapes.  Supports
causal masking, sliding windows (gemma2 local layers) and attention-logit
softcapping (gemma2), and GQA via head-index mapping in the k/v BlockSpecs.

Grid: (batch * q_heads, Sq/bq, Skv/bk), kv innermost; the (acc, m, l)
online-softmax state lives in VMEM scratch and the output tile is written
once on the final kv step.  Block sizes default to 128 x 128 (MXU-aligned);
the q/k/v tiles + f32 accumulator stay well under VMEM at D <= 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, bq: int, bk: int, n_kv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[:] = jnp.zeros_like(acc_ref)
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # [bq, D]
    k = k_ref[0].astype(jnp.float32)          # [bk, D]
    v = v_ref[0].astype(jnp.float32)          # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qi = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0][:, None]                       # [bq, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=1)[:, None])
    p = jnp.exp(s - m_cur)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_ref[:, 0][:, None] + p.sum(axis=1)[:, None]
    acc_ref[:] = acc_ref[:] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[:] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(j == n_kv - 1)
    def _finish():
        l = l_ref[:, 0][:, None]
        o_ref[0] = (acc_ref[:] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q [B, Hq, Sq, D], k/v [B, Hkv, Skv, D] -> o [B, Hq, Sq, D].

    GQA: Hq must be a multiple of Hkv; kv blocks are indexed by h // group.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    if scale is None:
        scale = D ** -0.5
    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)
    n_kv = Skv // bk

    def kv_index(bh, i, j):
        return (bh // group, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)

"""Dispatching attention wrapper used by the model zoo.

impl: 'flash' (Pallas kernel), 'xla' (reference einsum), 'auto' (flash on
TPU, xla elsewhere — interpret-mode flash is numerically exact but slow on
CPU, so models default to xla in tests while kernel tests pin interpret).
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash as _k
from repro.kernels.flash_attention import ref as _ref


def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
              impl: str = "auto", interpret: bool | None = None):
    if impl == "auto":
        impl = "flash" if jax.default_backend() == "tpu" else "xla"
    if impl == "flash":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _k.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  interpret=interpret)
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)

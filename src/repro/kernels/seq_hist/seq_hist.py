"""Pallas TPU histogram kernel for the hash-based sparsity screen.

The distributed screen (core/sparsity.screen_hash) needs bucket counts of
hashed sequence ids.  TPU has no native vector scatter: XLA lowers
scatter-add to a serialized loop, so for moderate table sizes the
TPU-idiomatic histogram is *compare-and-reduce*: for each bucket tile,
count matches of the id tile against the bucket iota — dense VPU work that
vectorizes perfectly and keeps the accumulator tile VMEM-resident.

Work is O(N * B): the right regime is B <= ~2^14 (on-device screening
tables).  ops.py picks scatter-add for larger tables; the tradeoff is
recorded in DESIGN.md.  Grid = (bucket-tiles, row-tiles) with rows
innermost so each accumulator tile sees consecutive writes (the Pallas
revisiting rule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(h_ref, m_ref, out_ref, *, bt: int, rows: int):
    b = pl.program_id(0)
    buckets = b * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
    h = h_ref[:]                                      # [rows, T]
    m = m_ref[:]
    eq = (h[:, :, None] == buckets[None, :, :]) & m[:, :, None]
    # dtype= pins the accumulator: with x64 enabled jnp.sum promotes int32
    # to int64, which the int32 out_ref swap rejects
    partial = jnp.sum(eq.astype(jnp.int32), axis=(0, 1), dtype=jnp.int32)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[:] = jnp.zeros_like(out_ref)

    out_ref[:] += partial[None, :]


@functools.partial(jax.jit, static_argnames=("n_buckets", "bt", "rows", "interpret"))
def hist(h, mask, n_buckets: int, bt: int = 512, rows: int = 8,
         interpret: bool = False):
    """Bucket counts [n_buckets] of ids h [R, T] under mask (int32)."""
    R, T = h.shape
    assert R % rows == 0 and n_buckets % bt == 0, (R, rows, n_buckets, bt)
    grid = (n_buckets // bt, R // rows)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, bt=bt, rows=rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, T), lambda b, r: (r, 0)),
            pl.BlockSpec((rows, T), lambda b, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt), lambda b, r: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, n_buckets), jnp.int32),
        interpret=interpret,
    )(h.astype(jnp.int32), mask)
    return out[0]

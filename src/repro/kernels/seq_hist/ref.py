"""Pure-jnp oracle for the histogram kernel."""
import jax.numpy as jnp


def hist_ref(h, mask, n_buckets: int):
    h = jnp.asarray(h, jnp.int32).reshape(-1)
    m = jnp.asarray(mask, bool).reshape(-1)
    return jnp.zeros(n_buckets, jnp.int32).at[h].add(m.astype(jnp.int32))

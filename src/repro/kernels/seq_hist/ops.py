"""jit'd wrapper: distinct-(patient, sequence) bucket counts via the kernel.

Dispatch: compare-and-reduce Pallas kernel for tables <= 2^14 buckets
(VMEM-resident accumulators, no serialized scatter); XLA scatter-add above.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparsity
from repro.core.encoding import SENTINEL
from repro.kernels.seq_hist import ref as _ref
from repro.kernels.seq_hist import seq_hist as _k

KERNEL_MAX_LOG2 = 14


def _dedupe_rows(seq, mask):
    """Row-wise (patient) dedupe: sorted ids + first-occurrence flags."""
    seq = jnp.asarray(seq, jnp.int64)
    mask = jnp.asarray(mask, bool)
    P = seq.shape[0]
    flat = jnp.where(mask, seq, SENTINEL).reshape(P, -1)
    srt = jnp.sort(flat, axis=1)
    first = jnp.concatenate(
        [jnp.ones((P, 1), bool), srt[:, 1:] != srt[:, :-1]], axis=1)
    first &= srt != SENTINEL
    return srt, first


def bucket_counts(seq, mask, n_buckets_log2: int,
                  interpret: bool | None = None, force_kernel: bool = False):
    """Distinct-patient bucket counts for [P, T]-shaped mined ids."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    srt, first = _dedupe_rows(seq, mask)
    h = sparsity.hash_bucket(srt, n_buckets_log2)
    if n_buckets_log2 > KERNEL_MAX_LOG2 and not force_kernel:
        return _ref.hist_ref(h, first, 1 << n_buckets_log2)
    P, T = h.shape
    rows = 8 if P % 8 == 0 else (4 if P % 4 == 0 else (2 if P % 2 == 0 else 1))
    bt = min(512, 1 << n_buckets_log2)
    return _k.hist(h, first, 1 << n_buckets_log2, bt=bt, rows=rows,
                   interpret=interpret)

"""Shared helpers for the kernel op wrappers."""
from __future__ import annotations

import jax.numpy as jnp


def pad_to(x, m, axis, value=0):
    """Pad ``axis`` of ``x`` up to the next multiple of ``m``."""
    n = x.shape[axis]
    pad = (-n) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)

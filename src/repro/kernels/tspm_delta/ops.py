"""jit'd wrapper: pad -> Pallas delta kernel -> 64-bit packed Mined slab."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.core.mining import Mined
from repro.kernels.tspm_delta import delta as _k
from repro.kernels.util import pad_to as _pad_to


def delta_pairgen(phenx, date, n_old, n_new, new_phenx, new_date,
                  codec: str = "bit", fuse_duration: bool = False,
                  bucket_days: int = 30, pb: int = 8, tile: int = 128,
                  interpret: bool | None = None) -> Mined:
    """Kernel-backed delta mining to the [P, E, D] slab (== delta ref)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    phenx = jnp.asarray(phenx, jnp.int32)
    date = jnp.asarray(date, jnp.int32)
    n_old = jnp.asarray(n_old, jnp.int32)
    n_new = jnp.asarray(n_new, jnp.int32)
    new_phenx = jnp.asarray(new_phenx, jnp.int32)
    new_date = jnp.asarray(new_date, jnp.int32)
    P, E = phenx.shape
    D = new_phenx.shape[1]
    if P == 0 or E == 0 or D == 0:
        # zero-width slab: nothing to tile (Pallas block specs require a
        # nonempty grid), and no pair can be valid
        shape = (P, E, D)
        return Mined(jnp.full(shape, encoding.SENTINEL, jnp.int64),
                     jnp.zeros(shape, jnp.int32), jnp.zeros(shape, bool))
    ti = min(tile, max(128, 1 << int(np.ceil(np.log2(max(E, 1))))))
    tj = min(tile, max(128, 1 << int(np.ceil(np.log2(max(D, 1))))))
    phenx_p = _pad_to(phenx, ti, 1)
    date_p = _pad_to(date, ti, 1)
    new_phenx_p = _pad_to(new_phenx, tj, 1)
    new_date_p = _pad_to(new_date, tj, 1)
    pbb = min(pb, P)
    phenx_p = _pad_to(phenx_p, pbb, 0)
    date_p = _pad_to(date_p, pbb, 0)
    new_phenx_p = _pad_to(new_phenx_p, pbb, 0)
    new_date_p = _pad_to(new_date_p, pbb, 0)
    nold_p = _pad_to(n_old, pbb, 0)
    nnew_p = _pad_to(n_new, pbb, 0)

    s, e, dur, mask = _k.delta_planes(
        phenx_p, date_p, nold_p, nnew_p, new_phenx_p, new_date_p,
        pb=pbb, ti=ti, tj=tj, interpret=interpret)
    s = s[:P, :E, :D]
    e = e[:P, :E, :D]
    dur = dur[:P, :E, :D]
    mask = mask[:P, :E, :D]

    seq = encoding.pack(jnp.maximum(s, 0), jnp.maximum(e, 0), codec)
    if fuse_duration:
        seq = encoding.fuse_duration(
            seq, encoding.bucket_duration(dur, bucket_days))
    seq = jnp.where(mask, seq, encoding.SENTINEL)
    return Mined(seq, dur, mask)

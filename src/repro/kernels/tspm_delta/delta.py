"""Pallas TPU kernel for *delta* pair enumeration (streaming tSPM+).

Batch mining (kernels/tspm_pairgen) fills the full dense E x E pair matrix
per patient; when a patient's history grows by d new events, only the last
d columns of that matrix are new.  This kernel computes exactly that slab:

    output planes [P, E, D]   (i = any stored event, j = delta event)

with column ``j`` standing for global event position ``n_old[p] + j`` —
the i-axis spans the *updated* history planes (which already contain the
appended delta at positions ``n_old .. n_old + n_new``), so new-x-new pairs
fall out of the same mask ``i < n_old + j`` with no special casing.  The
union of these slabs over all ticks is the batch pair set (property-tested
in tests/test_stream.py).

Tiling mirrors tspm_pairgen (Pb x Ti x Tj tiles, lane dim 128), but the
j-grid covers only the delta window: a tick touching d events of an
n-event history costs O(n * d) pairs instead of the O(n^2) re-mine.

64-bit note (same as pairgen): the kernel emits int32 start/end planes;
the 64-bit packed key is formed by the XLA consumer in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _delta_kernel(nold_ref, nnew_ref, xi_ref, di_ref, xj_ref, dj_ref,
                  s_ref, e_ref, dur_ref, msk_ref, *, ti: int, tj: int):
    pi = pl.program_id(1)
    pj = pl.program_id(2)
    gi = pi * ti + jax.lax.broadcasted_iota(jnp.int32, (1, ti, 1), 1)
    gj = pj * tj + jax.lax.broadcasted_iota(jnp.int32, (1, 1, tj), 2)
    n_old = nold_ref[:][:, :, None]          # [Pb, 1, 1]
    n_new = nnew_ref[:][:, :, None]
    # i precedes the delta event's global position; j inside the delta window
    mask = (gi < n_old + gj) & (gj < n_new)
    xi = xi_ref[:][:, :, None]               # [Pb, Ti, 1] stored history
    xj = xj_ref[:][:, None, :]               # [Pb, 1, Tj] delta events
    di = di_ref[:][:, :, None]
    dj = dj_ref[:][:, None, :]
    s_ref[:] = jnp.where(mask, xi, -1)
    e_ref[:] = jnp.where(mask, xj, -1)
    dur_ref[:] = jnp.where(mask, dj - di, 0)
    msk_ref[:] = mask


@functools.partial(jax.jit, static_argnames=("pb", "ti", "tj", "interpret"))
def delta_planes(phenx, date, n_old, n_new, new_phenx, new_date,
                 pb: int = 8, ti: int = 128, tj: int = 128,
                 interpret: bool = False):
    """Delta pair planes: (start, end, duration, mask), each [P, E, D].

    ``phenx``/``date`` are the updated [P, E] history planes (delta already
    appended at the per-patient cursors); ``new_phenx``/``new_date`` are the
    [P, D] delta events aligned at column 0.  P must divide by pb, E by ti,
    D by tj (ops.py pads).
    """
    P, E = phenx.shape
    D = new_phenx.shape[1]
    assert P % pb == 0 and E % ti == 0 and D % tj == 0, (P, E, D, pb, ti, tj)
    grid = (P // pb, E // ti, D // tj)
    nold2 = n_old.reshape(P, 1).astype(jnp.int32)
    nnew2 = n_new.reshape(P, 1).astype(jnp.int32)
    kernel = functools.partial(_delta_kernel, ti=ti, tj=tj)
    out_shape = [
        jax.ShapeDtypeStruct((P, E, D), jnp.int32),   # start plane
        jax.ShapeDtypeStruct((P, E, D), jnp.int32),   # end plane
        jax.ShapeDtypeStruct((P, E, D), jnp.int32),   # duration (days)
        jax.ShapeDtypeStruct((P, E, D), jnp.bool_),   # validity
    ]
    scalar = pl.BlockSpec((pb, 1), lambda p, i, j: (p, 0))
    row_i = pl.BlockSpec((pb, ti), lambda p, i, j: (p, i))
    row_j = pl.BlockSpec((pb, tj), lambda p, i, j: (p, j))
    tile = pl.BlockSpec((pb, ti, tj), lambda p, i, j: (p, i, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[scalar, scalar, row_i, row_i, row_j, row_j],
        out_specs=[tile, tile, tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )(nold2, nnew2, phenx.astype(jnp.int32), date.astype(jnp.int32),
      new_phenx.astype(jnp.int32), new_date.astype(jnp.int32))

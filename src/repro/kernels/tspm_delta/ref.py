"""Pure-jnp oracle for the delta pair-generation kernel ([P, E, D] slab)."""
from __future__ import annotations

import jax.numpy as jnp


def delta_planes_ref(phenx, date, n_old, n_new, new_phenx, new_date):
    """Reference (start, end, duration, mask) planes, each [P, E, D]."""
    phenx = jnp.asarray(phenx, jnp.int32)
    date = jnp.asarray(date, jnp.int32)
    n_old = jnp.asarray(n_old, jnp.int32)
    n_new = jnp.asarray(n_new, jnp.int32)
    new_phenx = jnp.asarray(new_phenx, jnp.int32)
    new_date = jnp.asarray(new_date, jnp.int32)
    E = phenx.shape[-1]
    D = new_phenx.shape[-1]
    gi = jnp.arange(E, dtype=jnp.int32)[None, :, None]
    gj = jnp.arange(D, dtype=jnp.int32)[None, None, :]
    mask = (gi < n_old[:, None, None] + gj) & (gj < n_new[:, None, None])
    s = jnp.where(mask, phenx[:, :, None], -1)
    e = jnp.where(mask, new_phenx[:, None, :], -1)
    dur = jnp.where(mask, new_date[:, None, :] - date[:, :, None], 0)
    return s, e, dur, mask

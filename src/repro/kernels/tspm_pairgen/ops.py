"""jit'd wrapper: pad -> Pallas pairgen -> 64-bit packed Mined (dense)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.core.mining import Mined
from repro.kernels.tspm_pairgen import pairgen as _k
from repro.kernels.util import pad_to as _pad_to


def pairgen(phenx, date, nevents, codec: str = "bit",
            fuse_duration: bool = False, bucket_days: int = 30,
            pb: int = 8, tile: int = 128, interpret: bool | None = None) -> Mined:
    """Kernel-backed mining to the dense [P, E, E] layout (== mine_dense)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    phenx = jnp.asarray(phenx, jnp.int32)
    date = jnp.asarray(date, jnp.int32)
    nevents = jnp.asarray(nevents, jnp.int32)
    P, E = phenx.shape
    t = min(tile, max(128, 1 << int(np.ceil(np.log2(max(E, 1))))))
    t = min(t, tile)
    phenx_p = _pad_to(phenx, t, 1)
    date_p = _pad_to(date, t, 1)
    pbb = min(pb, P) if P % min(pb, P) == 0 else 1
    phenx_p = _pad_to(phenx_p, pbb, 0)
    date_p = _pad_to(date_p, pbb, 0)
    nev_p = _pad_to(nevents, pbb, 0)

    s, e, dur, mask = _k.pairgen_planes(
        phenx_p, date_p, nev_p, pb=pbb, ti=t, tj=t, interpret=interpret)
    s = s[:P, :E, :E]
    e = e[:P, :E, :E]
    dur = dur[:P, :E, :E]
    mask = mask[:P, :E, :E]

    seq = encoding.pack(jnp.maximum(s, 0), jnp.maximum(e, 0), codec)
    if fuse_duration:
        seq = encoding.fuse_duration(
            seq, encoding.bucket_duration(dur, bucket_days))
    seq = jnp.where(mask, seq, encoding.SENTINEL)
    return Mined(seq, dur, mask)

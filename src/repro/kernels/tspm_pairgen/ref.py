"""Pure-jnp oracle for the pair-generation kernel (dense layout)."""
from __future__ import annotations

import jax.numpy as jnp


def pairgen_planes_ref(phenx, date, nevents):
    """Reference (start, end, duration, mask) planes, each [P, E, E]."""
    phenx = jnp.asarray(phenx, jnp.int32)
    date = jnp.asarray(date, jnp.int32)
    nevents = jnp.asarray(nevents, jnp.int32)
    E = phenx.shape[-1]
    ar = jnp.arange(E, dtype=jnp.int32)
    mask = (ar[:, None] < ar[None, :])[None] & \
        (ar[None, None, :] < nevents[:, None, None])
    s = jnp.where(mask, phenx[:, :, None], -1)
    e = jnp.where(mask, phenx[:, None, :], -1)
    dur = jnp.where(mask, date[:, None, :] - date[:, :, None], 0)
    return s, e, dur, mask

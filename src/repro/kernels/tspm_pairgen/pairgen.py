"""Pallas TPU kernel for transitive pair enumeration (the tSPM+ hot loop).

The C++ algorithm is a thread-per-patient double loop appending to a
thread-local vector.  The TPU-native shape (DESIGN.md §2): a grid over
(patient-block, i-tile, j-tile) computing VMEM tiles of the dense E x E
pair matrix — start/end phenX planes, duration and validity mask — in one
fused pass, so no [P, E, E] intermediates ever round-trip through HBM.

64-bit note: Mosaic's vector int64 support is limited, so the kernel emits
two int32 planes (start, end); the 64-bit key `(start << 24) | end` is
formed by one fused elementwise op in the XLA consumer (ops.py).  The
paper's "numeric representation + cheap bitshifts" insight is preserved;
only the word size of the kernel's store changes.

Tiling: Pb x Ti x Tj output tiles (defaults 8 x 128 x 128) keep the working
set ~1.5 MB in VMEM and the lane dimension at the TPU-native 128.  Tiles
entirely below the diagonal still write (masked) — grid-level skipping of
the lower triangle is a layout change tracked in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairgen_kernel(nev_ref, xi_ref, di_ref, xj_ref, dj_ref,
                    s_ref, e_ref, dur_ref, msk_ref, *, ti: int, tj: int):
    pi = pl.program_id(1)
    pj = pl.program_id(2)
    gi = pi * ti + jax.lax.broadcasted_iota(jnp.int32, (1, ti, 1), 1)
    gj = pj * tj + jax.lax.broadcasted_iota(jnp.int32, (1, 1, tj), 2)
    nev = nev_ref[:]                     # [Pb, 1]
    mask = (gi < gj) & (gj < nev[:, :, None])   # i < j and j in-bounds
    xi = xi_ref[:][:, :, None]           # [Pb, Ti, 1]
    xj = xj_ref[:][:, None, :]           # [Pb, 1, Tj]
    di = di_ref[:][:, :, None]
    dj = dj_ref[:][:, None, :]
    s_ref[:] = jnp.where(mask, xi, -1)
    e_ref[:] = jnp.where(mask, xj, -1)
    dur_ref[:] = jnp.where(mask, dj - di, 0)
    msk_ref[:] = mask


@functools.partial(jax.jit, static_argnames=("pb", "ti", "tj", "interpret"))
def pairgen_planes(phenx, date, nevents, pb: int = 8, ti: int = 128,
                   tj: int = 128, interpret: bool = False):
    """Dense pair planes: (start, end, duration, mask), each [P, E, E].

    P must divide by pb and E by ti == tj (ops.py pads).
    """
    P, E = phenx.shape
    assert P % pb == 0 and E % ti == 0 and E % tj == 0, (P, E, pb, ti, tj)
    grid = (P // pb, E // ti, E // tj)
    nev2 = nevents.reshape(P, 1).astype(jnp.int32)
    kernel = functools.partial(_pairgen_kernel, ti=ti, tj=tj)
    out_shape = [
        jax.ShapeDtypeStruct((P, E, E), jnp.int32),   # start plane
        jax.ShapeDtypeStruct((P, E, E), jnp.int32),   # end plane
        jax.ShapeDtypeStruct((P, E, E), jnp.int32),   # duration (days)
        jax.ShapeDtypeStruct((P, E, E), jnp.bool_),   # validity
    ]
    row_i = pl.BlockSpec((pb, ti), lambda p, i, j: (p, i))
    row_j = pl.BlockSpec((pb, tj), lambda p, i, j: (p, j))
    tile = pl.BlockSpec((pb, ti, tj), lambda p, i, j: (p, i, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pb, 1), lambda p, i, j: (p, 0)),  # nevents
            row_i,  # phenx_i
            row_i,  # date_i
            row_j,  # phenx_j
            row_j,  # date_j
        ],
        out_specs=[tile, tile, tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )(nev2, phenx.astype(jnp.int32), date.astype(jnp.int32),
      phenx.astype(jnp.int32), date.astype(jnp.int32))

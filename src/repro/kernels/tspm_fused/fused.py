"""Pallas TPU kernel: fused mine+screen — corpus-free support counting.

The materializing path writes the dense [P, E, E] pair corpus, then sorts
each patient row to dedup and scatter-adds hashed ids into the [2^H]
screen table (``sparsity.local_bucket_counts``).  This kernel produces the
*same table* without ever writing a pair: each Pb x Ti x Tj tile (the
tiling shared with tspm_pairgen / tspm_delta) decides in-register which of
its pairs is the patient's first contribution of that (start, end) value
pair, hashes those, and compare-and-reduces them into a VMEM-resident
bucket-tile accumulator (the seq_hist histogram idiom — TPU has no vector
scatter).

Dedup without the row sort: pair (i, j) is its patient's first occurrence
of the value pair (x_i, x_j) iff

    i < j < nevents
    and no k < i has x_k == x_i          (i is the value's first start)
    and max{k < j : x_k == x_j} <= i     (no closer end occurrence)

which keeps exactly one (i, j) per distinct present (a, b) — including
a == b, where it keeps (first, second) occurrence — so the counts match
the sort-based dedup bucket for bucket.  The lookbacks need the patient's
*full* event row (not just the tile), which rides in as one extra
[Pb, E] operand; dates are not needed at all (unfused ids are
duration-free, and validity is positional).

64-bit note: ids are int64 but Mosaic's vector int64 support is limited
(see tspm_pairgen).  The kernel never forms the id: the multiply-shift
hash is *linear* in the packed fields mod 2^64 —

    hash(pack(s, e)) = top_H((s * K * codec_mult + e * K) mod 2^64)

— so it evaluates the hash directly from the int32 phenX planes with a
13-bit-limb modular multiply: fields split into two 13-bit limbs,
constants into five, partial products < 2^26 and column sums < 2^29 stay
int32-exact, one carry propagation, then the top H bits are stitched from
the limbs (H <= 24 keeps every stitch shift in-range).

Grid: (bucket-tiles, patient-blocks, i-tiles, j-tiles) with bucket tiles
OUTERMOST so each [1, bt] accumulator block sees all its writes
consecutively (the Pallas revisiting rule, as in seq_hist — there rows
are innermost for the same reason).  The cost is recomputing
mask/dedup/hash once per bucket tile; with bt = min(2^H, 512) that factor
is 2^H / 512, bounded by the compare-and-reduce regime this kernel is
dispatched in (ops.KERNEL_MAX_LOG2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import encoding, sparsity

LIMB_BITS = 13
LIMB_MASK = (1 << LIMB_BITS) - 1
N_LIMBS = 5                       # 4 * 13 + 12 = 64 bits
_M64 = (1 << 64) - 1
MAX_BUCKETS_LOG2 = 24             # stitch shifts stay < 13 bits for H <= 24


def _limbs(c: int) -> tuple[int, ...]:
    return tuple((c >> (LIMB_BITS * t)) & LIMB_MASK for t in range(N_LIMBS))


def hash_constants(codec: str = "bit", fused_ids: bool = False):
    """Per-field multiply-shift constants mod 2^64 (host-side ints).

    hash(id) depends linearly on (start, end[, bucket]) because pack /
    fuse_duration are sums of disjoint shifted fields:

        id = start * mult * 2^shift + end * 2^shift + bucket
    """
    mult = (1 << encoding.BIT_SHIFT) if codec == "bit" else encoding.PAPER_SHIFT
    shift = encoding.DUR_BITS if fused_ids else 0
    k = sparsity.HASH_MULT
    c_start = (k * mult << shift) & _M64
    c_end = (k << shift) & _M64
    c_bucket = k & _M64
    return c_start, c_end, c_bucket


def hash_parts(start, end, bucket=None, *, codec: str = "bit",
               n_buckets_log2: int = 20, fused_ids: bool = False):
    """``sparsity.hash_bucket(pack(start, end))`` without forming the id.

    int32-only 13-bit-limb evaluation of (start*C1 + end*C2 [+ bucket*K])
    mod 2^64, returning the top ``n_buckets_log2`` bits as int32.  Inputs
    broadcast (the kernel passes [Pb, Ti, 1] x [Pb, 1, Tj]); fields must
    be < 2^26 (vocab < 2^24, buckets < 2^15 — both hold by construction).
    """
    H = n_buckets_log2
    assert 1 <= H <= MAX_BUCKETS_LOG2, H
    c_start, c_end, c_bucket = hash_constants(codec, fused_ids)
    terms = [(start, _limbs(c_start)), (end, _limbs(c_end))]
    if fused_ids:
        assert bucket is not None
        terms.append((bucket, _limbs(c_bucket)))

    cols = [0] * N_LIMBS
    for x, cl in terms:
        x = jnp.asarray(x, jnp.int32)
        x0 = x & LIMB_MASK
        x1 = x >> LIMB_BITS
        for t in range(N_LIMBS):
            if not cl[t]:
                continue
            cols[t] = cols[t] + x0 * cl[t]
            if t + 1 < N_LIMBS:          # column 5 is bit >= 65: 0 mod 2^64
                cols[t + 1] = cols[t + 1] + x1 * cl[t]

    limbs = []
    carry = 0
    for t in range(N_LIMBS):
        tot = cols[t] + carry
        limbs.append(tot & LIMB_MASK)
        carry = tot >> LIMB_BITS
    limbs[-1] = limbs[-1] & 0xFFF        # top limb is 12 bits; drop bit 64+

    sh = 64 - H
    h = 0
    for t in range(N_LIMBS):
        lo = LIMB_BITS * t
        width = 12 if t == N_LIMBS - 1 else LIMB_BITS
        if lo + width <= sh:
            continue
        h = h | (limbs[t] << (lo - sh)) if lo >= sh \
            else h | (limbs[t] >> (sh - lo))
    return jnp.asarray(h & ((1 << H) - 1), jnp.int32)


def _fused_kernel(nev_ref, xi_ref, xj_ref, xr_ref, out_ref, *, ti: int,
                  tj: int, bt: int, chunk_i: int, codec: str,
                  n_buckets_log2: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    pj = pl.program_id(3)
    gi = pi * ti + jax.lax.broadcasted_iota(jnp.int32, (1, ti, 1), 1)
    gj = pj * tj + jax.lax.broadcasted_iota(jnp.int32, (1, 1, tj), 2)
    nev = nev_ref[:]                                    # [Pb, 1]
    valid = (gi < gj) & (gj < nev[:, :, None])

    xi = xi_ref[:]                                      # [Pb, Ti]
    xj = xj_ref[:]                                      # [Pb, Tj]
    xr = xr_ref[:]                                      # [Pb, E] full row
    E = xr.shape[1]
    k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, E), 2)

    # lookbacks stay on real events: k < gi < nevents for any valid pair,
    # so padded positions are never consulted
    eq_i = (xr[:, None, :] == xi[:, :, None]) & (k < gi)       # [Pb, Ti, E]
    first_start = ~jnp.any(eq_i, axis=2)                       # [Pb, Ti]
    gj_col = pj * tj + jax.lax.broadcasted_iota(jnp.int32, (1, tj, 1), 1)
    eq_j = (xr[:, None, :] == xj[:, :, None]) & (k < gj_col)   # [Pb, Tj, E]
    prev_end = jnp.max(jnp.where(eq_j, k, -1), axis=2)         # [Pb, Tj]

    first = valid & first_start[:, :, None] & (prev_end[:, None, :] <= gi)
    h = hash_parts(xi[:, :, None], xj[:, None, :], codec=codec,
                   n_buckets_log2=n_buckets_log2)
    h = jnp.where(first, h, -1)          # dead pairs match no bucket

    buckets = b * bt + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bt), 2)

    def body(c, acc):
        h_c = jax.lax.dynamic_slice_in_dim(h, c * chunk_i, chunk_i, axis=1)
        h_c = h_c.reshape(h.shape[0], chunk_i * tj)
        # dtype= pins the accumulator: with x64 enabled jnp.sum promotes
        # int32 to int64, which the int32 out_ref swap rejects (seq_hist)
        return acc + jnp.sum((h_c[:, :, None] == buckets).astype(jnp.int32),
                             axis=(0, 1), dtype=jnp.int32)

    partial = jax.lax.fori_loop(
        0, ti // chunk_i, body, jnp.zeros((bt,), jnp.int32))

    @pl.when((pl.program_id(1) == 0) & (pi == 0) & (pj == 0))
    def _init():
        out_ref[:] = jnp.zeros_like(out_ref)

    out_ref[:] += partial[None, :]


@functools.partial(jax.jit, static_argnames=(
    "n_buckets_log2", "codec", "pb", "ti", "tj", "bt", "chunk_i", "interpret"))
def fused_table(phenx, nevents, n_buckets_log2: int, codec: str = "bit",
                pb: int = 8, ti: int = 128, tj: int = 128, bt: int = 512,
                chunk_i: int = 4, interpret: bool = False):
    """[2^H] int32 bucket counts of a padded [P, E] cohort (== the table
    ``sparsity.local_bucket_counts`` builds from the materialized corpus).

    P must divide by pb, E by ti == tj, 2^H by bt, ti by chunk_i
    (ops.py pads and clamps).
    """
    P, E = phenx.shape
    B = 1 << n_buckets_log2
    assert P % pb == 0 and E % ti == 0 and E % tj == 0, (P, E, pb, ti, tj)
    assert B % bt == 0 and ti % chunk_i == 0, (B, bt, ti, chunk_i)
    grid = (B // bt, P // pb, E // ti, E // tj)
    nev2 = nevents.reshape(P, 1).astype(jnp.int32)
    x = phenx.astype(jnp.int32)
    kernel = functools.partial(
        _fused_kernel, ti=ti, tj=tj, bt=bt, chunk_i=chunk_i, codec=codec,
        n_buckets_log2=n_buckets_log2)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pb, 1), lambda b, p, i, j: (p, 0)),   # nevents
            pl.BlockSpec((pb, ti), lambda b, p, i, j: (p, i)),  # phenx_i
            pl.BlockSpec((pb, tj), lambda b, p, i, j: (p, j)),  # phenx_j
            pl.BlockSpec((pb, E), lambda b, p, i, j: (p, 0)),   # full row
        ],
        out_specs=pl.BlockSpec((1, bt), lambda b, p, i, j: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.int32),
        interpret=interpret,
    )(nev2, x, x, x)
    return out[0]

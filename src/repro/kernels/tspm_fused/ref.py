"""jnp reference for fused mine+screen bucket counting (the kernel oracle).

``block_bucket_counts`` is the semantic contract of ``kernels/tspm_fused``:
mine a patient block to the dense pair layout, then fold it straight into
the [2^H] hash-bucket table with first-contribution-per-patient dedup —
``sparsity.local_bucket_counts`` applied to ``mining.mine_dense``.  The
block never leaves the function, so the *cohort-level* peak is one dense
block, not the [P, E, E] corpus: this is also the production fallback for
the cases the Pallas kernel does not cover (fused-duration ids, whose
cross-row dedup does not decompose over (i, j) tiles, and bucket tables
past the compare-and-reduce regime).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import mining, sparsity


def block_bucket_counts(phenx, date, nevents, codec: str = "bit",
                        fuse_duration: bool = False, bucket_days: int = 30,
                        n_buckets_log2: int = 20):
    """[2^H] int32 distinct-patient bucket counts of one patient block."""
    m = mining.mine_dense(phenx, date, nevents, codec, fuse_duration,
                          bucket_days)
    P = m.seq.shape[0]
    return sparsity.local_bucket_counts(
        m.seq.reshape(P, -1), m.mask.reshape(P, -1), n_buckets_log2)


def fused_bucket_counts_ref(phenx, date, nevents, codec: str = "bit",
                            fuse_duration: bool = False, bucket_days: int = 30,
                            n_buckets_log2: int = 20,
                            block_patients: int = 256):
    """Whole-cohort oracle: block loop over :func:`block_bucket_counts`.

    Bucket counts are additive over disjoint patient blocks (each distinct
    (patient, id) contributes exactly once, to the same bucket, whichever
    block its patient lands in), so this equals the single-shot table.
    """
    P = phenx.shape[0] if getattr(phenx, "ndim", 0) == 2 else 0
    counts = jnp.zeros(1 << n_buckets_log2, jnp.int32)
    for s in range(0, P, block_patients):
        e = s + block_patients
        counts = counts + block_bucket_counts(
            phenx[s:e], date[s:e], nevents[s:e], codec, fuse_duration,
            bucket_days, n_buckets_log2)
    return counts

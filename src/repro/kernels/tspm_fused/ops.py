"""Dispatching wrapper: pad -> fused Pallas counting -> [2^H] bucket table.

Mirrors tspm_pairgen/ops.py (padding recipe, interpret default) and
seq_hist/ops.py (compare-and-reduce regime bound).  Tile sizes come from
``analysis.roofline.mining_tile_plan`` — analytic VMEM fit by default,
measured autotune rows when ``benchmarks/mining_fused.py`` hands them in.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline
from repro.kernels.tspm_fused import fused as _k
from repro.kernels.tspm_fused import ref as _ref
from repro.kernels.util import pad_to as _pad_to

# compare-and-reduce histogram work is O(pairs * 2^H): past ~2^14 buckets
# the recompute-per-bucket-tile factor loses to the jnp block fallback
# (same bound as seq_hist's scatter-add crossover)
KERNEL_MAX_LOG2 = 14


def _kernel_block(phenx, nevents, codec, n_buckets_log2, plan, pb, tile, bt,
                  interpret):
    P, E = phenx.shape
    tile = int(tile or plan.ti)
    pb = int(pb or plan.pb)
    bt_ = min(int(bt or plan.bt), 1 << n_buckets_log2)
    while (1 << n_buckets_log2) % bt_:
        bt_ //= 2
    t = min(tile, max(128, 1 << int(np.ceil(np.log2(max(E, 1))))))
    t = min(t, tile)
    x = _pad_to(phenx, t, 1)
    pbb = min(pb, P) if P % min(pb, P) == 0 else 1
    x = _pad_to(x, pbb, 0)
    nev = _pad_to(nevents, pbb, 0)     # padded patients: nevents == 0
    return _k.fused_table(
        x, nev, n_buckets_log2=n_buckets_log2, codec=codec, pb=pbb, ti=t,
        tj=t, bt=bt_, chunk_i=min(4, t), interpret=interpret)


def fused_bucket_counts(phenx, date, nevents, codec: str = "bit",
                        fuse_duration: bool = False, bucket_days: int = 30,
                        n_buckets_log2: int = 20, backend: str = "auto",
                        block_patients: int | None = None,
                        pb: int | None = None, tile: int | None = None,
                        bt: int | None = None,
                        interpret: bool | None = None):
    """Corpus-free [2^H] bucket counts == local_bucket_counts(mine(...)).

    backend: 'kernel' | 'jnp' | 'auto' ('auto' = kernel on TPU, jnp ref
    elsewhere, as mining.mine).  The Pallas kernel covers unfused ids with
    H <= KERNEL_MAX_LOG2; fused-duration ids (whose cross-row dedup does
    not decompose over tiles) and larger tables take the blocked jnp
    reference — still corpus-free at cohort level (peak is one
    [block, E, E] slab, never [P, E, E]).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "jnp"
    phenx = jnp.asarray(phenx, jnp.int32)
    date = jnp.asarray(date, jnp.int32)
    nevents = jnp.asarray(nevents, jnp.int32).reshape(-1)
    H = n_buckets_log2
    P, E = phenx.shape if phenx.ndim == 2 else (0, 0)
    if P == 0 or E == 0:
        # zero-width-slab guard (mirrors tspm_delta/ops.py): no events,
        # empty table
        return jnp.zeros(1 << H, jnp.int32)
    plan = roofline.mining_tile_plan(E, H)
    blk = int(block_patients or plan.block_patients)
    use_kernel = (backend == "kernel" and not fuse_duration
                  and H <= KERNEL_MAX_LOG2)
    counts = jnp.zeros(1 << H, jnp.int32)
    for s in range(0, P, blk):
        e = s + blk
        if use_kernel:
            part = _kernel_block(phenx[s:e], nevents[s:e], codec, H, plan,
                                 pb, tile, bt, interpret)
        else:
            part = _ref.block_bucket_counts(
                phenx[s:e], date[s:e], nevents[s:e], codec, fuse_duration,
                bucket_days, H)
        counts = counts + part
    return counts

"""AdamW + schedules, built from scratch (no optax in the container).

Optimizer state (mu, nu) is f32 and mirrors the parameter tree, so it
inherits the params' 2-D (fsdp x tp) sharding — ZeRO-style distributed
optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(zeros, jax.tree.map(jnp.copy, zeros),
                    jnp.zeros((), jnp.int32))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(cfg: OptConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * g32
        nu_n = b2 * nu + (1 - b2) * g32 * g32
        mu_hat = mu_n / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu_n / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu_n, nu_n

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda v: isinstance(v, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda v: isinstance(v, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda v: isinstance(v, tuple))
    return new_params, OptState(new_mu, new_nu, step), \
        {"lr": lr, "grad_norm": gnorm}

"""Train step assembly: loss, microbatch grad accumulation, optimizer.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function; the launcher jits it with NamedShardings (dry-run / production)
or plainly (CPU examples).  Microbatching scans over leading batch splits,
accumulating f32 gradients — grad accumulation == large-batch equivalence
is tested.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.training import optimizer as opt_lib


class TrainState(NamedTuple):
    params: dict
    opt: opt_lib.OptState


def lm_loss(logits, labels, mask, z_coef: float = 1e-4):
    """Masked CE + z-loss (keeps the softmax normalizer bounded at scale)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                             -1)[..., 0] - lse
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    z = (lse ** 2 * mask).sum() / denom
    return ce + z_coef * z, ce


def make_loss_fn(mdl, z_coef: float = 1e-4):
    def loss_fn(params, batch):
        logits, aux = mdl.apply(params, batch, mode="train")
        total, ce = lm_loss(logits, batch["labels"], batch["loss_mask"],
                            z_coef)
        return total + aux, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(mdl, opt_cfg: opt_lib.OptConfig, microbatches: int = 1,
                    z_coef: float = 1e-4):
    loss_fn = make_loss_fn(mdl, z_coef)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                (loss, metrics), grads = grad_fn(state.params, mb)
                g_acc, l_acc = carry
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    g_acc, grads)
                return (g_acc, l_acc + loss / microbatches), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), metrics = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)

        new_params, new_opt, opt_metrics = opt_lib.update(
            opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_state(mdl, rng) -> tuple[TrainState, dict]:
    params, pspecs = mdl.init(rng)
    return TrainState(params, opt_lib.init(params)), pspecs


def state_pspecs(pspecs):
    """Opt state mirrors params; step is replicated."""
    from jax.sharding import PartitionSpec as P

    return TrainState(
        pspecs,
        opt_lib.OptState(pspecs, pspecs, P()),
    )

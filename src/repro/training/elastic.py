"""Fault tolerance: preemption hook, elastic re-meshing, straggler notes.

* ``PreemptionGuard`` — SIGTERM/SIGINT flips a flag; the train loop
  checkpoints and exits cleanly at the next step boundary (tested by
  setting the flag directly).
* ``reshard`` — moves a (checkpointed or live) state tree onto a NEW mesh:
  the elastic-scaling path after losing/gaining pods.  Because checkpoints
  are mesh-agnostic (host numpy), restart onto any mesh whose axes divide
  the array dims is a restore + device_put with the new NamedShardings.
* Straggler mitigation lives in the data pipeline (work-stealing chunk
  scheduler + LPT patient balancing, data/pipeline.py) plus the step-time
  watchdog here: persistent outliers get reported for replacement — on a
  real fleet this feeds the pod manager; here it feeds logs/tests.
"""
from __future__ import annotations

import signal
import time

import jax

from repro.distributed.sharding import param_shardings


class PreemptionGuard:
    def __init__(self, install_handlers: bool = False):
        self.preempted = False
        if install_handlers:
            for sig in (signal.SIGTERM, signal.SIGINT):
                signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self.preempted = True

    def trigger(self):  # tests / external pod-manager hook
        self.preempted = True


def reshard(tree, new_mesh, spec_tree):
    """Place a host/device tree onto ``new_mesh`` with the given specs."""
    shardings = param_shardings(new_mesh, spec_tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda v: not isinstance(v, (dict, tuple, list)))


class StepWatchdog:
    """Flags steps slower than ``factor`` x trailing-median (stragglers)."""

    def __init__(self, factor: float = 2.0, window: int = 16):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.flagged: list[int] = []
        self._t0 = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        dt = time.perf_counter() - self._t0
        hist = self.times[-self.window:]
        slow = bool(hist) and dt > self.factor * sorted(hist)[len(hist) // 2]
        self.times.append(dt)
        if slow:
            self.flagged.append(step)
        return slow

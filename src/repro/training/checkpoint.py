"""Checkpointing: atomic, resumable, async-capable (no orbax in container).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir
and atomically renamed — a crashed writer never corrupts the latest
checkpoint, which is what restart-after-failure relies on.  ``save_async``
snapshots to host then writes on a background thread (training continues).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Blocking atomic save; returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    hosted = [np.asarray(x) for x in leaves]
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": a for i, a in enumerate(hosted)})
    manifest = {"step": step, "n_leaves": len(hosted),
                "treedef": treedef, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


_async_thread: threading.Thread | None = None


def save_async(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Snapshot to host now, write in the background."""
    global _async_thread
    wait()
    leaves, treedef = _flatten(tree)
    hosted = [np.asarray(x) for x in leaves]  # device->host happens here
    unflat = jax.tree_util.tree_structure(tree)

    def _write():
        save(ckpt_dir, step,
             jax.tree_util.tree_unflatten(unflat, hosted), extra)

    _async_thread = threading.Thread(target=_write, daemon=True)
    _async_thread.start()


def wait():
    global _async_thread
    if _async_thread is not None:
        _async_thread.join()
        _async_thread = None


def latest(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def restore(path: str, tree_like):
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    leaves = [z[f"a{i}"] for i in range(manifest["n_leaves"])]
    treedef = jax.tree_util.tree_structure(tree_like)
    ref_leaves = jax.tree_util.tree_leaves(tree_like)
    assert len(ref_leaves) == len(leaves), "checkpoint/model tree mismatch"
    cast = [np.asarray(a, dtype=r.dtype) for a, r in zip(leaves, ref_leaves)]
    return jax.tree_util.tree_unflatten(treedef, cast), manifest

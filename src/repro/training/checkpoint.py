"""Checkpointing: atomic, resumable, async-capable (no orbax in container).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir
and atomically renamed — a crashed writer never corrupts the latest
checkpoint, which is what restart-after-failure relies on.  ``save_async``
snapshots to host then writes on a background thread (training continues).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Blocking atomic save; returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    hosted = [np.asarray(x) for x in leaves]
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": a for i, a in enumerate(hosted)})
    manifest = {"step": step, "n_leaves": len(hosted),
                "treedef": treedef, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


class Saver:
    """Async checkpoint writer with *instance-scoped* pending state.

    The pre-refactor module held one global pending thread, so two
    concurrent savers (two sessions, or a trainer plus a streaming
    service) would join and forget *each other's* writes — ``wait()`` on
    one could drop the other's still-unstarted thread handle.  Each Saver
    owns its own pending thread and a lock, so independent savers never
    interfere; the module-level ``save_async``/``wait`` remain as shims
    over a default instance."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()

    def save_async(self, ckpt_dir: str, step: int, tree,
                   extra: dict | None = None) -> None:
        """Snapshot to host now, write in the background."""
        leaves, _ = _flatten(tree)
        hosted = [np.asarray(x) for x in leaves]  # device->host happens here
        unflat = jax.tree_util.tree_structure(tree)

        def _write():
            save(ckpt_dir, step,
                 jax.tree_util.tree_unflatten(unflat, hosted), extra)

        t = threading.Thread(target=_write, daemon=True)
        # join-then-start under the lock: writes through one Saver are
        # serialized, and a concurrent wait() can never observe (or join)
        # a not-yet-started thread
        with self._lock:
            if self._thread is not None:
                self._thread.join()
            t.start()
            self._thread = t

    def wait(self) -> None:
        with self._lock:
            if self._thread is not None:
                self._thread.join()
                self._thread = None


_DEFAULT_SAVER = Saver()


def save_async(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Module-level shim over a process-default :class:`Saver`."""
    _DEFAULT_SAVER.save_async(ckpt_dir, step, tree, extra)


def wait():
    _DEFAULT_SAVER.wait()


def latest(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def load(path: str) -> tuple[list, dict]:
    """Load a checkpoint's raw leaves + manifest without a reference tree
    (the session checkpoint format stores its structure in ``extra``)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    leaves = [z[f"a{i}"] for i in range(manifest["n_leaves"])]
    return leaves, manifest


def restore(path: str, tree_like):
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    leaves = [z[f"a{i}"] for i in range(manifest["n_leaves"])]
    treedef = jax.tree_util.tree_structure(tree_like)
    ref_leaves = jax.tree_util.tree_leaves(tree_like)
    assert len(ref_leaves) == len(leaves), "checkpoint/model tree mismatch"
    cast = [np.asarray(a, dtype=r.dtype) for a, r in zip(leaves, ref_leaves)]
    return jax.tree_util.tree_unflatten(treedef, cast), manifest

"""Logical-axis sharding: rules context + activation constraints.

Model code annotates activations with *logical* axis names
(``constrain(x, ("batch", "seq", None))``).  The launcher installs a rule
set mapping logical names to mesh axes; outside any rule context the
annotations are no-ops, so CPU unit tests never see a mesh.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

_RULES: contextvars.ContextVar = contextvars.ContextVar("axis_rules",
                                                        default=None)

# default logical -> mesh-axis mapping (single- and multi-pod meshes)
def default_rules(mesh) -> dict:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    return {
        "batch": batch if len(batch) > 1 else (batch[0] if batch else None),
        "model": "model" if "model" in axes else None,
        "fsdp": "data" if "data" in axes else None,
        "seq": None,            # flipped to ('data',) for long-context SP
        "seq_res": None,        # Megatron-SP residual (cfg.sp_residual)
        "expert": "model" if "model" in axes else None,
    }


@contextlib.contextmanager
def axis_rules(mesh, rules: dict | None = None):
    token = _RULES.set((mesh, rules or default_rules(mesh)))
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules():
    return _RULES.get()


def logical_to_pspec(names, rules) -> P:
    return P(*[rules.get(n) if isinstance(n, str) else n for n in names])


def constrain(x, names):
    ctx = _RULES.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, logical_to_pspec(names, rules)))


def sanitize_pspec(spec: P, shape, mesh) -> P:
    """Drop mesh axes a dim is not divisible by (small weights replicate).
    Mirrors the fallback rule every production sharder needs: a [768, 8]
    gate projection cannot shard 8 ways over a 16-wide 'model' axis."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def sanitize_tree(spec_tree, struct_tree, mesh):
    return jax.tree.map(
        lambda s, x: sanitize_pspec(s, x.shape, mesh), spec_tree, struct_tree,
        is_leaf=lambda v: isinstance(v, P))


def param_shardings(mesh, spec_tree, struct_tree=None):
    """PartitionSpec tree (from model init) -> NamedSharding tree,
    sanitized against the struct shapes when provided."""
    if struct_tree is not None:
        spec_tree = sanitize_tree(spec_tree, struct_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda v: isinstance(v, P))


def _device_resident_stack(tables, mesh, axis: str):
    """[S, B] global array assembled from per-shard tables *in place* when
    each table already lives on its mesh-position device (the
    device-pinned streaming layout): no host round-trip, no cross-device
    copy — the psum reads each device's table where it sits.  Returns
    None when the layout doesn't match (then the caller host-gathers)."""
    mesh_devs = list(mesh.devices.flat)
    if len(tables) != len(mesh_devs) or mesh.shape[axis] != len(mesh_devs):
        return None
    parts = []
    for t, d in zip(tables, mesh_devs):
        if not isinstance(t, jax.Array) or t.devices() != {d}:
            return None
        parts.append(t[None])
    return jax.make_array_from_single_device_arrays(
        (len(tables),) + tables[0].shape,
        NamedSharding(mesh, P(axis)), parts)


def merge_sharded_counts(tables, mesh=None, axis: str = "data"):
    """Global screen table from per-shard bucket-count tables: one psum.

    Per-shard sketch tables count distinct (patient, sequence) pairs over
    *disjoint* patient sets, so the global table is their elementwise sum —
    the same merge the batch screen does per chunk
    (``sparsity.merge_bucket_counts``).  With a mesh, the [S, B] stack is
    sharded over ``axis`` and reduced with a single shard_map'd psum (each
    device folds its local shard rows first), the collective pattern of
    ``sparsity.screen_hash``; without one, the sum runs locally.  Tables
    pinned one-per-mesh-device (``ShardedStreamService`` with
    ``placement='devices'``) are stacked in place; any other committed
    layout gathers through the host first — ``jnp.stack`` cannot mix
    device commitments.
    """
    tables = [jnp.asarray(t) for t in tables]
    if mesh is not None:
        resident = _device_resident_stack(tables, mesh, axis)
        if resident is not None:
            return _jitted_merge(mesh, axis)(resident)
    if len({d for t in tables for d in t.devices()}) > 1:
        tables = [np.asarray(t) for t in tables]
    stacked = jnp.stack(tables)
    if mesh is None:
        return stacked.sum(axis=0)
    n = mesh.shape[axis]
    if stacked.shape[0] % n:   # pad with zero tables to a shardable count
        pad = n - stacked.shape[0] % n
        stacked = jnp.concatenate(
            [stacked, jnp.zeros((pad,) + stacked.shape[1:], stacked.dtype)])
    merge = _jitted_merge(mesh, axis)
    return merge(jax.device_put(stacked, NamedSharding(mesh, P(axis))))


@functools.lru_cache(maxsize=8)
def _jitted_merge(mesh, axis: str):
    # jit'd once per (mesh, axis): eager shard_map re-traces every call on
    # jax 0.4.x, and the merge runs on every snapshot rebuild
    return jax.jit(compat.shard_map(
        lambda c: jax.lax.psum(c.sum(axis=0), axis), mesh=mesh,
        in_specs=P(axis), out_specs=P()))


def fsdp_axis_for(cfg):
    if not cfg.fsdp:
        return None
    # with TP disabled the 'model' axis would idle — fold it into FSDP so
    # block weights shard 256-way (grad sync shrinks accordingly)
    return "data" if cfg.tp_internals else ("data", "model")

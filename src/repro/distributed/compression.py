"""Gradient compression for the slow (cross-pod) axis: int8 all-reduce
with error feedback.

Inside a shard_map'd train step, replace ``psum(g, 'pod')`` with
``compressed_psum_mean(g, 'pod', err)``: values are quantized to int8
against a shared scale (one scalar psum), summed as int32 (4x fewer bytes
on the wire than f32 — the paper's pack-to-integers trick applied to
gradients), and the local quantization residual is carried to the next
step (error feedback keeps SGD unbiased in the long run; convergence is
tested in tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, scale):
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q


def compressed_psum_mean(g, axis_name, err=None):
    """Mean-allreduce of g over ``axis_name`` via int8.  Returns
    (mean_g f32, new_err).  err is the local error-feedback buffer."""
    g = g.astype(jnp.float32)
    if err is not None:
        g = g + err
    amax = jnp.max(jnp.abs(g))
    gmax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = quantize(g, scale)
    deq_local = q.astype(jnp.float32) * scale
    new_err = g - deq_local
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / n.astype(jnp.float32), new_err


def tree_compressed_psum_mean(grads, axis_name, err_tree=None):
    if err_tree is None:
        err_tree = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(
        lambda g, e: compressed_psum_mean(g, axis_name, e), grads, err_tree)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda v: isinstance(v, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda v: isinstance(v, tuple))
    return mean, err

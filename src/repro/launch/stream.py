"""Streaming mining launcher: replay a synthetic cohort as deltas.

  PYTHONPATH=src python -m repro.launch.stream --patients 200 --waves 8

Generates a Synthea-style cohort, replays it wave-by-wave through the
unified session API (``repro.api.MiningSession`` — the planner picks the
stream or sharded engine from the config), and prints ingest throughput
plus sample chainable-frame queries.

``--journal-dir DIR`` journals every session event into a hash-chained
tick journal (repro.journal) and verifies it after the run;
``--replay-journal DIR`` skips ingest entirely and reconstructs the
session from a journal instead.  Both modes print a ``state_digest=``
line over the final corpus/sketch/pid state, so a replay drill can diff
a journaled run against its replay across processes (ci.yml nightly).
"""
from __future__ import annotations

import argparse
import hashlib
import time

import numpy as np

from repro.api import MiningConfig, MiningSession
from repro.data import dbmart, synthea
from repro.stream.shard import ShardedStreamService, ShardRouter


def replay_waves(db, svc, n_waves: int, seed: int = 0, start_wave: int = 0):
    """Split each patient's history into ~n_waves chronological deltas and
    interleave them (wave-major), mimicking encounter-by-encounter arrival.
    ``svc`` is anything with ``submit`` (a service or a MiningSession).
    ``start_wave`` skips earlier waves without submitting them (the wave
    cuts are seed-deterministic, so a resumed replay continues the exact
    delta schedule a checkpointed run left off at)."""
    rng = np.random.default_rng(seed)
    cuts = []
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        k = min(n_waves, max(n, 1))
        edges = np.sort(rng.choice(np.arange(1, n), size=k - 1, replace=False)) \
            if n > 1 and k > 1 else np.zeros(0, np.int64)
        cuts.append(np.concatenate([[0], edges, [n]]).astype(np.int64))
    for w in range(n_waves):
        if w < start_wave:
            continue
        for p in range(db.n_patients):
            c = cuts[p]
            if w + 1 < len(c) and c[w] < c[w + 1]:
                lo, hi = int(c[w]), int(c[w + 1])
                svc.submit(p, db.date[p, lo:hi], db.phenx[p, lo:hi])
        yield w


def state_digest(svc) -> str:
    """One hex digest over everything the journal replay must reproduce
    (corpus, sketch table, pid table) — the cross-process comparison key
    for the replay drill."""
    snap = svc.snapshot()
    h = hashlib.sha256()
    for name in ("seq", "dur", "patient", "counts"):
        h.update(np.ascontiguousarray(
            np.asarray(getattr(snap, name))).tobytes())
    pids = svc.pids if hasattr(svc, "shards") else svc.store.pids
    h.update(repr(sorted((str(k), int(v))
                         for k, v in dict(pids).items())).encode())
    return h.hexdigest()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--patients", type=int, default=200)
    ap.add_argument("--avg-events", type=int, default=32)
    ap.add_argument("--waves", type=int, default=8)
    ap.add_argument("--tick-patients", type=int, default=16)
    ap.add_argument("--threshold", type=int, default=4)
    ap.add_argument("--buckets-log2", type=int, default=20)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "kernel", "auto"])
    ap.add_argument("--budget-mb", type=int, default=0,
                    help="store byte budget in MiB (0 = unbounded)")
    ap.add_argument("--disk-bytes", type=int, default=0,
                    help="host-spill byte budget: evicted histories past "
                         "it demote into the compressed disk tier "
                         "(0 = host tier unbounded, no disk tier)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="checkpoint the session here after every wave "
                         "(atomic step_<wave> dirs; see --resume)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in "
                         "--checkpoint-dir and continue the replay from "
                         "the next wave (config comes from the "
                         "checkpoint; continuation is byte-identical to "
                         "an uninterrupted run)")
    ap.add_argument("--stop-after-wave", type=int, default=None,
                    metavar="W", help="exit after checkpointing wave W "
                    "(simulates a killed service; pair with --resume)")
    ap.add_argument("--shards", type=int, default=1,
                    help="patient shards over the ('data',) mesh")
    ap.add_argument("--placement", default="auto",
                    choices=["auto", "host", "devices"],
                    help="shard state placement: 'devices' pins one shard "
                         "per device (overlapped ticks, async migration "
                         "admits), 'host' keeps shards serial on the "
                         "default device, 'auto' picks 'devices' when the "
                         "host has >= 1 device per shard")
    ap.add_argument("--router", default="balance",
                    choices=["hash", "balance"],
                    help="patient->shard routing (balance pins by LPT "
                         "pair cost, hash needs no prior knowledge)")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="migrate patients off hot shards every N ticks "
                         "(0 = sticky routing, no rebalancing)")
    ap.add_argument("--imbalance-threshold", type=float, default=1.5,
                    help="rebalance when the hottest shard's resident "
                         "pair cost exceeds this multiple of the mean")
    ap.add_argument("--min-gain", type=float, default=0.05,
                    help="migration hysteresis: skip moves that lower the "
                         "hot shard's load by less than this fraction of "
                         "the mean (prevents patient ping-pong)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="enable telemetry and dump the metrics snapshot "
                         "(flat name{labels} -> value JSON) on exit")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable telemetry and dump the span tree as a "
                         "Chrome trace (chrome://tracing / Perfetto) on exit")
    ap.add_argument("--busy-weighted-rebalance", action="store_true",
                    help="weight LPT rebalancing by the device-timed "
                         "shard_load() busy fractions")
    ap.add_argument("--journal-dir", default=None, metavar="DIR",
                    help="append a hash-chained tick journal of every "
                         "session event here and verify it after the run")
    ap.add_argument("--journal-commit-every", type=int, default=16,
                    metavar="N", help="merkle commitment cadence (ticks) "
                                      "for --journal-dir")
    ap.add_argument("--replay-journal", default=None, metavar="DIR",
                    help="skip ingest: reconstruct the session from this "
                         "journal directory (cohort/engine flags are "
                         "ignored — the journal's open entry carries the "
                         "config) and print its state digest")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.replay_journal:
        t0 = time.perf_counter()
        session = MiningSession.replay(args.replay_journal)
        dt = time.perf_counter() - t0
        svc = session.service
        print(f"replayed {args.replay_journal} in {dt:.2f}s "
              f"({svc.n_ticks} ticks)")
        print(f"state_digest={state_digest(svc)}")
        return session
    if args.rebalance_every and args.shards <= 1:
        ap.error("--rebalance-every requires --shards > 1 "
                 "(rebalancing migrates patients between shards)")
    if args.busy_weighted_rebalance and not args.rebalance_every:
        ap.error("--busy-weighted-rebalance requires --rebalance-every")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    telemetry = bool(args.metrics_json or args.trace_out)

    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=args.patients, avg_events=args.avg_events, seed=args.seed)
    db = dbmart.from_rows(pats, dates, phx)

    config = MiningConfig(
        threshold=args.threshold, screen="hash", backend=args.backend,
        n_buckets_log2=args.buckets_log2, tick_patients=args.tick_patients,
        budget_bytes=(args.budget_mb << 20) or None,
        disk_bytes=args.disk_bytes or None,
        n_shards=args.shards, router=args.router,
        placement=args.placement,
        rebalance_every=args.rebalance_every or None,
        imbalance_threshold=args.imbalance_threshold,
        min_gain=args.min_gain, telemetry=telemetry,
        busy_weighted_rebalance=args.busy_weighted_rebalance,
        journal_dir=args.journal_dir,
        journal_commit_every=args.journal_commit_every)
    mesh = None
    router = None
    if args.shards > 1:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        if args.router == "balance":
            router = ShardRouter.balanced(list(range(db.n_patients)),
                                          db.nevents, args.shards)
    start_wave = 0
    if args.resume:
        session = MiningSession.restore(args.checkpoint_dir, mesh=mesh,
                                        vocab=db.vocab)
        start_wave = int(session.restore_extra.get("next_wave", 0))
        print(f"resumed from {args.checkpoint_dir} at wave {start_wave}")
    else:
        session = MiningSession(config, mesh=mesh, router=router,
                                vocab=db.vocab)
    print(session.plan())

    def _status():
        # cheap counters only: a snapshot() here would concat + psum-merge
        # inside the timed loop and skew the reported ingest throughput
        svc = session.service
        if isinstance(svc, ShardedStreamService):
            corpus = sum(len(c[0]) for s in svc.shards for c in s._corpus)
            return (f"corpus={corpus:,} resident=" +
                    "/".join(str(len(s.store.rows)) for s in svc.shards))
        return (f"corpus={sum(len(c[0]) for c in svc._corpus):,} "
                f"resident={len(svc.store.rows)}")

    t0 = time.perf_counter()
    for w in replay_waves(db, session, args.waves, args.seed,
                          start_wave=start_wave):
        session.service.run()
        print(f"wave {w}: {_status()}")
        if args.checkpoint_dir:
            path = session.checkpoint(args.checkpoint_dir, step=w,
                                      extra={"next_wave": w + 1})
            print(f"checkpoint -> {path}")
        if args.stop_after_wave is not None and w >= args.stop_after_wave:
            print(f"stopping after wave {w} (resume with --resume)")
            break
    dt = time.perf_counter() - t0
    svc = session.service
    ev = sum(s.n_events for s in svc.stats)
    pairs = sum(s.n_pairs for s in svc.stats)
    print(f"ingested {ev:,} events / {pairs:,} pairs over "
          f"{len(svc.stats)} ticks in {dt:.2f}s ({ev/dt:,.0f} events/s)")
    if args.shards > 1:
        loads = svc.shard_loads()
        busy = svc.shard_load()
        print(f"migrations={len(svc.migrations)} shard_load_mb=" +
              "/".join(f"{b / (1 << 20):.1f}" for b in loads) +
              " shard_busy=" + "/".join(f"{f:.2f}" for f in busy))

    if args.journal_dir:
        res = session.verify()
        j = session.journal()
        print(f"journal {args.journal_dir}: {j.n_entries} entries, "
              f"{j.n_commits} commitments -> {res}")
        print(f"state_digest={state_digest(svc)}")
        if not res.ok:
            raise SystemExit(f"journal verification failed: {res.proof}")

    if args.metrics_json:
        import json

        with open(args.metrics_json, "w") as fh:
            json.dump(session.metrics(), fh, indent=2, sort_keys=True)
        print(f"metrics snapshot -> {args.metrics_json}")
    if args.trace_out:
        session.trace().dump_chrome_trace(args.trace_out)
        print(f"chrome trace -> {args.trace_out}")

    frame = session.frame()
    covid = db.vocab.phenx_index[synthea.COVID]
    n = frame.starts_with(covid).screen().n_kept
    print(f"sequences starting with COVID-19 (support>={args.threshold}): "
          f"{n:,}")
    n = frame.min_duration(60).screen().n_kept
    print(f"sequences spanning >=60 days (screened): {n:,}")
    return session


if __name__ == "__main__":
    main()

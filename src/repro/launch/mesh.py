"""Production meshes.  A FUNCTION, not a module constant — importing this
module never touches jax device state (the dry-run sets XLA_FLAGS first).

Single pod: 16 x 16 = 256 chips ('data', 'model').
Multi-pod:  2 x 16 x 16 = 512 chips ('pod', 'data', 'model') — 'pod' is the
slow (DCN) axis and carries only the gradient all-reduce (optionally
int8-compressed, distributed/compression.py).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (see launch/dryrun.py)")
    # more devices than needed (e.g. 512 forced, single-pod mesh): subset
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests on forced host devices."""
    n = int(np.prod(shape))
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), axes)


def make_data_mesh(n: int | None = None):
    """1-D ('data',) mesh over up to ``n`` devices — the patient-sharding
    mesh of the streaming service and the batch pipeline (no 'model' axis:
    mining has no weights to TP)."""
    devices = jax.devices()
    n = len(devices) if n is None else min(n, len(devices))
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))


def shard_devices(n_shards: int, mesh=None) -> list:
    """One device per shard slot, in mesh position order.

    Shard ``s`` of the streaming service lives at mesh position ``s`` (its
    sketch table is row ``s`` of the psum-merged [S, 2^H] stack), so its
    planes pin to that position's device.  With fewer devices than shards
    the assignment wraps round-robin — co-resident shards still mine
    correctly, they just share a queue (and the psum fast path falls back
    to the host-gather merge)."""
    devices = list(mesh.devices.flat) if mesh is not None else jax.devices()
    return [devices[s % len(devices)] for s in range(n_shards)]

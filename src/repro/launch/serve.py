"""Serving launcher: LM generation or tSPM+ query serving.

  PYTHONPATH=src python -m repro.launch.serve --arch tspm-mlho --reduced
  PYTHONPATH=src python -m repro.launch.serve --workload queries \\
      --patients 64 --clients 32 --queries 128

``--workload lm`` (default) runs batched generation over the LM wave
scheduler; ``--workload queries`` mines a synthetic cohort through a live
streaming session, stands up ``session.serve()``, and drives concurrent
clients through the batched query path, printing wave/cache stats and the
per-query latency spread.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def main_lm(args):
    import jax

    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.serving.engine import Request, ServeEngine

    cfg = get_config(args.arch, reduced=args.reduced)
    mdl = model_lib.build(cfg)
    params, _ = mdl.init(jax.random.PRNGKey(args.seed))
    print(f"serving {args.arch}: params="
          f"{model_lib.param_count(params):,} batch={args.batch}")

    eng = ServeEngine(mdl, params, batch_size=args.batch,
                      max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(4, cfg.vocab_size, args.prompt_len) \
            .astype(np.int32)
        eng.submit(Request(i, prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    results = eng.run(jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s)")
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid][:12].tolist()} ...")
    return results


def main_queries(args):
    from repro.api import MiningConfig, MiningSession
    from repro.data import dbmart, synthea
    from repro.serving.tspm import plan

    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=args.patients, avg_events=16, seed=args.seed)
    db = dbmart.from_rows(pats, dates, phx)
    session = MiningSession(MiningConfig(threshold=args.threshold,
                                         tick_patients=8))
    server = session.serve(batch_size=args.batch)
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        if n:
            session.submit(p, db.date[p, :n], db.phenx[p, :n])
    session.run()
    view = server.view()
    print(f"serving {view.n_rows:,} mined rows at tick {view.tick} "
          f"(batch={args.batch}, clients={args.clients})")

    rng = np.random.default_rng(args.seed)
    codes = np.unique(db.phenx[db.phenx >= 0]) if db.phenx.size else [0]
    plans = [plan().screen().starts_with(int(rng.choice(codes)))
             for _ in range(args.queries)]

    lats: list[float] = []
    lock = threading.Lock()
    server.start()

    def client(chunk):
        for p in chunk:
            t0 = time.perf_counter()
            server.submit(p).result(timeout=60)
            dt = time.perf_counter() - t0
            with lock:
                lats.append(dt)

    threads = [threading.Thread(
        target=client, args=(plans[i::args.clients],))
        for i in range(args.clients)]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.time() - t0
    server.stop()

    lat = np.sort(np.asarray(lats))
    p50 = float(lat[int(0.50 * (len(lat) - 1))]) * 1e3
    p99 = float(lat[int(0.99 * (len(lat) - 1))]) * 1e3
    st = server.stats()
    print(f"served {st['queries']} queries in {wall:.2f}s "
          f"({st['queries']/wall:.0f} q/s) over {st['waves']} waves")
    print(f"  latency p50={p50:.2f}ms p99={p99:.2f}ms  "
          f"cache hit ratio={st['cache_hit_ratio']:.2f}")
    return st


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "queries"), default="lm")
    # lm workload
    ap.add_argument("--arch", default="tspm-mlho")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # queries workload
    ap.add_argument("--patients", type=int, default=64)
    ap.add_argument("--threshold", type=int, default=3)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--queries", type=int, default=128)
    args = ap.parse_args(argv)
    if args.workload == "queries":
        if args.batch == 4:     # lm default is too small for query waves
            args.batch = 32
        return main_queries(args)
    return main_lm(args)


if __name__ == "__main__":
    main()

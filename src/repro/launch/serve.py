"""Serving launcher: batched generation over the wave scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch tspm-mlho --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tspm-mlho")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mdl = model_lib.build(cfg)
    params, _ = mdl.init(jax.random.PRNGKey(args.seed))
    print(f"serving {args.arch}: params="
          f"{model_lib.param_count(params):,} batch={args.batch}")

    eng = ServeEngine(mdl, params, batch_size=args.batch,
                      max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(4, cfg.vocab_size, args.prompt_len) \
            .astype(np.int32)
        eng.submit(Request(i, prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    results = eng.run(jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s)")
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid][:12].tolist()} ...")
    return results


if __name__ == "__main__":
    main()

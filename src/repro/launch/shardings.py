"""Explicit PartitionSpecs for batches and decode caches, per family.

Params specs come from model init; these cover the *other* step inputs.
``batch_axes`` is ('pod','data') on the multi-pod mesh, ('data',) single-pod.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import ssm_common


def batch_axes_of(mesh, cfg=None):
    axes = ("pod", "data") if cfg is None or cfg.tp_internals \
        else ("pod", "data", "model")   # TP off: pure wide DP
    return tuple(a for a in axes if a in mesh.axis_names)


def batch_pspecs(cfg, batch_tree, mesh):
    ba = batch_axes_of(mesh, cfg)
    b = ba if len(ba) > 1 else (ba[0] if ba else None)

    def spec(path_leaf):
        arr = path_leaf
        return P(b, *([None] * (arr.ndim - 1)))

    return jax.tree.map(spec, batch_tree)


def _attn_cache_spec(b, mode="heads"):
    """KV cache layout [L, B, S, Hkv, hd]: shard heads over 'model'
    (classic TP) or the SEQUENCE dim ('seq': flash-decode style — XLA turns
    the softmax over the sharded dim into tiny stat reductions instead of
    gathering the cache; see EXPERIMENTS.md §Perf iteration 1)."""
    if mode == "seq":
        return {"k": P(None, b, "model", None, None),
                "v": P(None, b, "model", None, None),
                "pos": P(None)}
    return {"k": P(None, b, None, "model", None),
            "v": P(None, b, None, "model", None),
            "pos": P(None)}


def cache_pspecs(cfg, caches, mesh):
    """Spec tree matching model.init_caches output for each family."""
    ba = batch_axes_of(mesh, cfg)
    b = ba if len(ba) > 1 else (ba[0] if ba else None)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return tuple(_attn_cache_spec(b, cfg.decode_kv_shard)
                     for _ in caches)
    if fam == "xlstm":
        tp = "model" if cfg.tp_internals else None
        out = []
        for c in caches:
            if isinstance(c, ssm_common.ScanState):
                out.append(ssm_common.ScanState(
                    P(None, b, None, None, tp), P(None, b, None, None)))
            else:  # slstm dict h/c/n/m: [L, B, H, dh]
                out.append({k: P(None, b, None, tp) for k in c})
        return tuple(out)
    if fam == "hybrid":
        return {
            "mamba": (
                P(None, None, b, None, "model"),       # conv state
                ssm_common.ScanState(
                    P(None, None, b, "model", None, None),
                    P(None, None, b, "model", None)),
            ),
            "attn": _attn_cache_spec(b, cfg.decode_kv_shard),
        }
    if fam == "encdec":
        return {"attn": _attn_cache_spec(b, cfg.decode_kv_shard),
                "memory": P(b, None, None)}
    raise ValueError(fam)


def to_shardings(mesh, spec_tree, struct_tree=None):
    from repro.distributed.sharding import sanitize_tree

    if struct_tree is not None:
        spec_tree = sanitize_tree(spec_tree, struct_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda v: isinstance(v, P))

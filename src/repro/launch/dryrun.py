import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the step function (train_step for train shapes, serve_step
for prefill/decode shapes) is jitted with explicit NamedShardings for
params / optimizer state / batch / caches, lowered against
ShapeDtypeStructs (no allocation), compiled for the production mesh, and
the compiled artifact's memory_analysis / cost_analysis / collective bytes
are recorded to JSON for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/]
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import compat                                     # noqa: E402
from repro.analysis import costmodel                         # noqa: E402
from repro.analysis import roofline as rl                    # noqa: E402
from repro.configs import ARCHS, get_config                  # noqa: E402
from repro.configs.base import SHAPES, shape_applicable      # noqa: E402
from repro.distributed.sharding import axis_rules, param_shardings  # noqa: E402
from repro.launch import shardings as sh                     # noqa: E402
from repro.launch import specs                               # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models import model as model_lib                  # noqa: E402
from repro.training import optimizer as opt_lib              # noqa: E402
from repro.training import train_loop                        # noqa: E402

ASSIGNED = [a for a in ARCHS if a != "tspm-mlho"]


def _abstract_state(mdl):
    def make():
        params, _ = mdl.init(jax.random.PRNGKey(0))
        return train_loop.TrainState(params, opt_lib.init(params))

    return jax.eval_shape(make)


def _parse_overrides(sets: list[str] | None) -> dict:
    out = {}
    for kv in sets or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def lower_cell(arch: str, shape_name: str, mesh, overrides: dict | None = None,
               microbatches: int = 1):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mdl = model_lib.build(cfg)
    params_struct, pspecs = model_lib.abstract_init(mdl)

    from repro.distributed.sharding import default_rules

    rules = default_rules(mesh)
    if not cfg.tp_internals:  # pure wide-DP: batch over every axis
        rules["batch"] = sh.batch_axes_of(mesh, cfg)
    if cfg.sp_residual:
        rules["seq_res"] = "model"
    with axis_rules(mesh, rules):
        p_shard = param_shardings(mesh, pspecs, params_struct)
        if shape.kind == "train":
            state_struct = _abstract_state(mdl)
            state_shard = train_loop.TrainState(
                p_shard, opt_lib.OptState(
                    p_shard, p_shard,
                    jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())))
            batch_struct = specs.train_batch(cfg, shape)
            batch_shard = sh.to_shardings(
                mesh, sh.batch_pspecs(cfg, batch_struct, mesh), batch_struct)
            step = train_loop.make_train_step(
                mdl, opt_lib.OptConfig(), microbatches=microbatches)
            jitted = jax.jit(step,
                             in_shardings=(state_shard, batch_shard),
                             out_shardings=(state_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_struct, batch_struct)
        else:
            cache_struct = specs.cache_specs(cfg, shape, mdl)
            cache_shard = sh.to_shardings(
                mesh, sh.cache_pspecs(cfg, cache_struct, mesh), cache_struct)
            if shape.kind == "prefill":
                batch_struct = specs.train_batch(cfg, shape)
                batch_struct.pop("labels")
                batch_struct.pop("loss_mask")
            else:
                batch_struct = specs.decode_batch(cfg, shape)
            batch_shard = sh.to_shardings(
                mesh, sh.batch_pspecs(cfg, batch_struct, mesh), batch_struct)

            def serve_step(params, batch, caches):
                mode = "prefill" if shape.kind == "prefill" else "decode"
                return mdl.apply(params, batch, mode=mode, caches=caches)

            jitted = jax.jit(serve_step,
                             in_shardings=(p_shard, batch_shard, cache_shard),
                             out_shardings=(None, cache_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_struct, batch_struct, cache_struct)
    return lowered, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             skip_existing=False, overrides: dict | None = None,
             microbatches: int = 1, tag: str = "") -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, name + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "tag": tag, "overrides": overrides or {},
           "microbatches": microbatches}
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped-by-rule"
        rec["reason"] = "full-attention arch: long_500k requires " \
                        "sub-quadratic sequence mixing (DESIGN.md)"
        _write(path, rec)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, cfg, shape = lower_cell(arch, shape_name, mesh, overrides,
                                         microbatches)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compat.cost_analysis(compiled)
        hlo = compiled.as_text()
        coll = rl.collective_bytes(hlo)   # per-device, trip-scaled (exact)
        chips = mesh.devices.size
        total, active = rl.count_params(cfg)
        embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        # FLOPs/bytes: analytic model (XLA cost_analysis counts while
        # bodies once — see analysis/costmodel.py + its validation test);
        # raw cost_analysis kept alongside for transparency.
        flops = costmodel.step_flops(cfg, shape)
        hbm_bytes = costmodel.step_bytes(cfg, shape, active)
        roof = rl.Roofline(
            arch=arch, shape=shape_name, chips=chips,
            hlo_flops=flops,
            hlo_bytes=hbm_bytes,
            coll_bytes=float(sum(coll.values())) * chips,
            coll_breakdown=coll,
            model_flops=rl.model_flops(cfg, shape, active, embed),
            bytes_per_device=getattr(mem, "temp_size_in_bytes", None),
        )
        rec.update(status="ok", t_lower_s=t_lower, t_compile_s=t_compile,
                   params_total=total, params_active=active,
                   memory_analysis=_mem_dict(mem), roofline=roof.row(),
                   raw_cost_analysis={k: float(v) for k, v in cost.items()
                                      if isinstance(v, (int, float))},
                   hlo_bytes_len=len(hlo))
    except Exception as e:  # a failing cell is a bug; record it loudly
        rec.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(path, rec)
    return rec


def _mem_dict(mem):
    keys = ("temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: int(getattr(mem, k)) for k in keys if hasattr(mem, k)}


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf variants)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="", help="variant tag for the record")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    overrides = _parse_overrides(args.set)

    n_fail = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, multi_pod, args.out,
                               args.skip_existing, overrides,
                               args.microbatches, args.tag)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']} "
                             f"frac={r['roofline_fraction']:.3f} "
                             f"compile={rec['t_compile_s']:.0f}s")
                if status == "FAILED":
                    n_fail += 1
                    extra = " " + rec["error"][:160]
                print(f"[{rec['mesh']}] {arch} x {shape_name}: "
                      f"{status}{extra}", flush=True)
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""End-to-end training launcher: tSPM+ pipeline -> LM training.

Synthetic cohort -> transitive mining -> sparsity screen -> token corpus ->
train with checkpoints, preemption guard, straggler watchdog.  Runs on CPU
with reduced configs; the same step function jits with NamedShardings on a
production mesh (launch/dryrun.py proves every assigned cell compiles).

  PYTHONPATH=src python -m repro.launch.train --arch tspm-mlho --reduced \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import obs as obs_lib
from repro.configs import get_config
from repro.data import synthea, tokenize
from repro.data.dbmart import from_rows
from repro.models import model as model_lib
from repro.training import checkpoint, elastic
from repro.training import optimizer as opt_lib
from repro.training import train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tspm-mlho")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--patients", type=int, default=256)
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the training metrics snapshot as JSON on exit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    # data: the paper's pipeline feeding the LM
    pats, dates, phx, _ = synthea.generate_cohort(
        n_patients=args.patients, avg_events=40, seed=args.seed)
    db = from_rows(pats, dates, phx)
    corpus = tokenize.pack_corpus(db, seq_len=args.seq)
    vocab_needed = corpus.vocab_size
    if cfg.vocab_size < vocab_needed:
        cfg = cfg.replace(vocab_size=vocab_needed)
    print(f"corpus: {corpus.tokens.shape} vocab={corpus.vocab_size} "
          f"({db.total_events} events, {db.n_patients} patients)")

    mdl = model_lib.build(cfg)
    state, pspecs = train_loop.init_state(mdl, jax.random.PRNGKey(args.seed))
    print(f"model: {args.arch} params={model_lib.param_count(state.params):,}")

    opt_cfg = opt_lib.OptConfig(peak_lr=args.lr, warmup_steps=20,
                                decay_steps=args.steps)
    step_fn = jax.jit(train_loop.make_train_step(
        mdl, opt_cfg, microbatches=args.microbatches))

    start = 0
    if args.ckpt_dir:
        latest = checkpoint.latest(args.ckpt_dir)
        if latest:
            state, manifest = checkpoint.restore(latest, state)
            state = train_loop.TrainState(*state) if isinstance(state, tuple) \
                else state
            start = manifest["step"]
            print(f"resumed from {latest} at step {start}")

    guard = elastic.PreemptionGuard()
    watchdog = elastic.StepWatchdog()
    batches = tokenize.lm_batches(corpus, args.batch, seed=args.seed)
    # training observability goes through the same registry as the mining
    # stack (repro.obs), not hand-rolled prints: the log line below and the
    # --metrics-json snapshot read from one source of truth
    tel = obs_lib.Telemetry()
    reg = tel.metrics
    m_steps = reg.counter("train.steps")
    m_stragglers = reg.counter("train.stragglers")
    m_loss = reg.gauge("train.loss")
    m_ce = reg.gauge("train.ce")
    m_lr = reg.gauge("train.lr")
    m_step_s = reg.histogram("train.step_s")
    t0 = time.time()
    for step in range(start, args.steps):
        if guard.preempted:
            print(f"preempted at step {step}; checkpointing and exiting")
            if args.ckpt_dir:
                checkpoint.save(args.ckpt_dir, step, state)
            return state
        batch = {k: jax.numpy.asarray(v) for k, v in next(batches).items()}
        watchdog.start()
        ts = time.perf_counter()
        state, metrics = step_fn(state, batch)
        slow = watchdog.stop(step)
        m_step_s.observe(time.perf_counter() - ts)
        m_steps.inc()
        if slow:
            m_stragglers.inc()
        m_loss.set(float(metrics["loss"]))
        m_ce.set(float(metrics["ce"]))
        m_lr.set(float(metrics["lr"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step}: loss={m_loss.value:.4f} "
                  f"ce={m_ce.value:.4f} "
                  f"lr={m_lr.value:.2e}"
                  + (" [straggler]" if slow else ""), flush=True)
        if args.ckpt_dir and step and step % args.ckpt_every == 0:
            checkpoint.save_async(args.ckpt_dir, step, state)
    checkpoint.wait()
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps, state)
    snap = reg.snapshot()
    if args.metrics_json:
        import json

        with open(args.metrics_json, "w") as fh:
            json.dump(snap, fh, indent=2, sort_keys=True)
        print(f"metrics snapshot -> {args.metrics_json}")
    step_sum = snap.get("train.step_s", {})
    print(f"done in {time.time()-t0:.1f}s "
          f"({snap['train.steps']} steps, "
          f"{snap['train.stragglers']} stragglers, "
          f"mean step {step_sum.get('sum', 0.0) / max(snap['train.steps'], 1):.3f}s)")
    return state


if __name__ == "__main__":
    main()

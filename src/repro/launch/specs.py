"""Per-(arch x shape) input specs: ShapeDtypeStructs for the dry-run,
concrete random batches for smoke tests.  Modality frontends are stubs —
[audio]/[vlm] entries receive precomputed frame/patch embeddings here."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _mk(shape, dtype, concrete, rng, kind="normal", maxval=None):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if kind == "tokens":
        return jnp.asarray(rng.integers(0, maxval, shape), dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    return jnp.asarray(rng.standard_normal(shape) * 0.1, dtype)


def train_batch(cfg: ModelConfig, shape: ShapeConfig, *, concrete=False,
                seed=0):
    """Training/prefill inputs for one global batch."""
    rng = np.random.default_rng(seed) if concrete else None
    b, s = shape.global_batch, shape.seq_len
    v = cfg.vocab_size
    if cfg.family == "encdec":
        ss = st = s // 2
        return {
            "src_embeds": _mk((b, ss, cfg.d_model), jnp.dtype(cfg.dtype),
                              concrete, rng),
            "tokens": _mk((b, st), jnp.int32, concrete, rng, "tokens", v),
            "labels": _mk((b, st), jnp.int32, concrete, rng, "tokens", v),
            "loss_mask": _mk((b, st), jnp.bool_, concrete, rng, "ones"),
        }
    if cfg.family == "vlm":
        st = max(s - cfg.n_patches, 8)
        return {
            "patch_embeds": _mk((b, cfg.n_patches, cfg.frontend_dim),
                                jnp.dtype(cfg.dtype), concrete, rng),
            "tokens": _mk((b, st), jnp.int32, concrete, rng, "tokens", v),
            # labels cover the full (patch + text) sequence
            "labels": _mk((b, st + cfg.n_patches), jnp.int32, concrete, rng,
                          "tokens", v),
            "loss_mask": _mk((b, st + cfg.n_patches), jnp.bool_, concrete,
                             rng, "ones"),
        }
    return {
        "tokens": _mk((b, s), jnp.int32, concrete, rng, "tokens", v),
        "labels": _mk((b, s), jnp.int32, concrete, rng, "tokens", v),
        "loss_mask": _mk((b, s), jnp.bool_, concrete, rng, "ones"),
    }


def decode_batch(cfg: ModelConfig, shape: ShapeConfig, *, concrete=False,
                 seed=0):
    """One-token decode inputs (the KV cache itself comes from
    model.init_caches and is an argument of serve_step)."""
    rng = np.random.default_rng(seed) if concrete else None
    b = shape.global_batch
    batch = {"tokens": _mk((b, 1), jnp.int32, concrete, rng, "tokens",
                           cfg.vocab_size)}
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, model):
    """ShapeDtypeStructs of the decode cache at this shape."""
    b, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(
        lambda: model.init_caches(b, s, src_len=s // 2
                                  if cfg.family == "encdec" else None))
    return caches

"""MiningConfig + Plan: the façade's declarative knobs and planner output.

One frozen dataclass carries everything the four execution layers used to
take as scattered keyword arguments — encoding (codec, duration fusing),
screening (threshold, sorted vs hash), execution (backend, byte budgets),
and streaming/sharding (shard count, router, rebalance hysteresis).  A
config is plain data: runtime resources (a mesh, a pre-built router) are
passed to :class:`~repro.api.session.MiningSession` instead.
"""
from __future__ import annotations

import dataclasses

from repro.core.encoding import CODECS

#: Engines the planner can select (and ``MiningConfig.engine`` can force):
#:   batch   — one in-memory mine of the whole cohort (core.mining)
#:   chunked — adaptive patient chunks under ``budget_bytes`` (core.chunking)
#:   files   — chunked with per-chunk .npz spill + merged count table
#:   stream  — incremental delta mining, one shard (stream.service)
#:   sharded — patient-sharded streaming over ``n_shards`` (stream.shard)
ENGINES = ("batch", "chunked", "files", "stream", "sharded")

#: Screen modes:
#:   sorted — the paper's exact sort/mark/re-sort screen
#:   hash   — one-sided hash-bucket screen over the materialized corpus
#:   fused  — corpus-free: hash-bucket counts come from the fused
#:            mine+screen kernel (kernels/tspm_fused) without ever writing
#:            the pair corpus; survivors are materialized afterwards
#:            (requires ``threshold``; same one-sided keep as 'hash')
SCREEN_MODES = ("sorted", "hash", "fused")

#: Shard state placement for the sharded engine:
#:   auto    — planner picks 'devices' when the host has at least one
#:             device per shard, else 'host'
#:   host    — every shard on the default device, shard-serial ticks
#:   devices — one device per shard (launch/mesh.shard_devices), ticks
#:             dispatched on every shard before any is collected, and
#:             migration handoffs admitted at the tick boundary (async)
PLACEMENTS = ("auto", "host", "devices")


@dataclasses.dataclass(frozen=True)
class MiningConfig:
    """Every mining knob in one place (see module docstring)."""

    # --- encoding ---------------------------------------------------------
    codec: str = "bit"              # 'bit' | 'paper' (encoding.pack)
    fuse_duration: bool = False     # fuse bucketed duration into the id
    bucket_days: int = 30           # duration bucket width (days)

    # --- screening --------------------------------------------------------
    threshold: int | None = None    # default support threshold for .screen()
    screen: str = "sorted"          # 'sorted' | 'hash' | 'fused' (see above)
    n_buckets_log2: int = 20        # hash-screen table size (2^H buckets)

    # --- execution --------------------------------------------------------
    backend: str = "jnp"            # 'jnp' | 'kernel' | 'auto' (mining.mine)
    budget_bytes: int | None = None  # mining working-set byte budget
    spill_bytes: int | None = None  # host corpus size that triggers file spill
    spill_dir: str | None = None    # where the file engine spills (tmp if None)
    disk_bytes: int | None = None   # host-spill budget: streaming evictions
    #                                 beyond it demote (oldest first) into the
    #                                 compressed disk tier, same pair-cost
    #                                 model as budget_bytes one boundary down
    #                                 (None = host tier unbounded, no disk)
    disk_dir: str | None = None     # disk-tier blockstore location (tmp if
    #                                 None; sharded engines use per-shard
    #                                 subdirectories)
    engine: str | None = None       # force one of ENGINES (None = planner)

    # --- streaming / sharding ---------------------------------------------
    tick_patients: int = 16         # patient slots per streaming tick
    max_slot_events: int = 512      # flood cap per slot (stream.service)
    n_shards: int = 1               # patient shards (>1 selects 'sharded')
    router: str = "hash"            # 'hash' | 'balance' (LPT, needs nevents)
    placement: str = "auto"         # shard state placement (PLACEMENTS)
    rebalance_every: int | None = None   # auto-rebalance period (ticks)
    imbalance_threshold: float = 1.5     # hot-shard trigger (x mean load)
    min_gain: float = 0.05               # migration hysteresis (x mean load)
    busy_weighted_rebalance: bool = False  # weight LPT by shard_load()

    # --- journaling ---------------------------------------------------------
    journal_dir: str | None = None  # hash-chained tick journal location
    #                                 (repro.journal); None = no journal.
    #                                 Streaming engines only: every delta,
    #                                 tick, eviction, migration, and
    #                                 rebalance is recorded, replayable
    #                                 byte-identically, and verifiable
    journal_commit_every: int = 16  # merkle commitment cadence (ticks)

    # --- observability ------------------------------------------------------
    telemetry: bool = False         # metrics registry + span tracer (repro.obs)
    jax_annotations: bool = False   # mirror spans into jax.profiler traces

    def __post_init__(self):
        if self.codec not in CODECS:
            raise ValueError(f"unknown codec {self.codec!r}; one of {CODECS}")
        if self.screen not in SCREEN_MODES:
            raise ValueError(
                f"unknown screen mode {self.screen!r}; one of {SCREEN_MODES}")
        if self.screen == "fused" and self.threshold is None:
            raise ValueError(
                "screen='fused' materializes survivors during fit, so it "
                "needs a threshold up front (set MiningConfig.threshold)")
        if self.engine is not None and self.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {self.engine!r}; one of {ENGINES}")
        if self.router not in ("hash", "balance"):
            raise ValueError(f"unknown router {self.router!r}")
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {self.placement!r}; one of {PLACEMENTS}")
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.journal_commit_every < 1:
            raise ValueError("journal_commit_every must be >= 1")

    def replace(self, **kw) -> "MiningConfig":
        return dataclasses.replace(self, **kw)


def _fmt_bytes(n: int | None) -> str:
    if n is None:
        return "unbounded"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if n < 1024 or unit == "TiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n} B"
        n /= 1024
    return f"{n} B"


@dataclasses.dataclass(frozen=True)
class Plan:
    """What the planner decided and why — print it, or override it by
    re-running with ``MiningConfig(engine=...)``."""

    engine: str
    reason: str
    working_set_bytes: int = 0
    budget_bytes: int | None = None
    disk_bytes: int | None = None
    corpus_bytes: int = 0
    n_chunks: int = 1
    n_shards: int = 1
    placement: str = "host"     # resolved (never 'auto'): shard placement
    incremental: bool = False
    corpus_free: bool = False   # screen='fused': no [P, n, n] corpus on
    #                             the screen pass, survivors-only alloc

    def __str__(self) -> str:
        lines = [
            f"MiningPlan(engine={self.engine})",
            f"  reason      : {self.reason}",
            f"  working set : {_fmt_bytes(self.working_set_bytes)}"
            f" (budget {_fmt_bytes(self.budget_bytes)})",
            f"  flat corpus : {_fmt_bytes(self.corpus_bytes)}",
        ]
        if self.corpus_free:
            lines.append("  screen      : corpus-free fused counting "
                         "(pairs allocated for survivors only)")
        if self.disk_bytes is not None:
            lines.append(f"  disk tier   : host spill over "
                         f"{_fmt_bytes(self.disk_bytes)} demotes to "
                         "compressed blocks")
        if self.n_chunks > 1:
            lines.append(f"  chunks      : {self.n_chunks}")
        if self.n_shards > 1:
            lines.append(f"  shards      : {self.n_shards}"
                         f" ({self.placement} placement)")
        if self.incremental:
            lines.append("  input       : incremental (submit/tick)")
        return "\n".join(lines)

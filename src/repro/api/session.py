"""MiningSession: config-driven dispatch over every execution engine.

``fit(dbmart)`` runs the planner (``plan()`` shows the decision; a
``MiningConfig.engine`` override forces it) and dispatches:

  * ``batch``   — one in-memory mine (core.mining) + flatten;
  * ``chunked`` — adaptive patient chunks under ``budget_bytes``
    (core.chunking.mine_chunked);
  * ``files``   — chunk spill to .npz + merged bucket-count table
    (mine_to_files / load_files);
  * ``stream``  — the cohort replayed through a StreamService;
  * ``sharded`` — replayed through a ShardedStreamService over
    ``n_shards`` (hash or LPT-balanced router, optional mesh psum merge).

``submit(key, dates, phenx)`` / ``tick()`` feed the same session
incrementally (engine 'stream' or 'sharded' by ``n_shards``); ``tick``
ingests one wave and returns the live frame.  All paths land in a
:class:`~repro.api.frame.SequenceFrame`, and all are byte-identical on the
same cohort (tests/test_api.py) — the engine is purely a resource choice.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile

import numpy as np

from repro import obs as obs_lib
from repro.api import planner
from repro.api.config import MiningConfig, Plan
from repro.api.frame import SequenceFrame
from repro.core import chunking, mining, sparsity
from repro.core.encoding import Vocab
from repro.data.dbmart import DBMart
from repro.storage.state import pack_tree, unpack_tree
from repro.stream.events import CheckpointTaken, EventTap
from repro.stream.service import StreamService
from repro.stream.shard import ShardedStreamService, ShardRouter
from repro.training import checkpoint as ckpt_lib


class MiningSession:
    """One mining session: a config, a planner, and a result frame.

    ``mesh`` (a ('data',)-axis mesh) and a pre-built ``router`` are runtime
    resources for the sharded engine; ``vocab`` decodes incremental frames
    (fit takes it from the DBMart).  Keyword overrides fork the config:
    ``MiningSession(threshold=5)`` == ``MiningSession(MiningConfig(threshold=5))``.
    """

    def __init__(self, config: MiningConfig | None = None, *, mesh=None,
                 router: ShardRouter | None = None, vocab: Vocab | None = None,
                 **overrides):
        config = config if config is not None else MiningConfig()
        self.config = config.replace(**overrides) if overrides else config
        self.mesh = mesh
        self.router = router
        self.vocab = vocab
        self.telemetry = (obs_lib.Telemetry(
            jax_annotations=self.config.jax_annotations)
            if self.config.telemetry else obs_lib.NOOP)
        self.service: StreamService | ShardedStreamService | None = None
        self._journal = None      # TickJournal when config.journal_dir is set
        self.last_plan: Plan | None = None
        self.last_frame: SequenceFrame | None = None
        self.restore_extra: dict = {}   # user extras from the checkpoint
        #                                 this session was restored from

    # --- planning -----------------------------------------------------------
    def plan(self, db: DBMart | None = None) -> Plan:
        """The execution plan: for ``db`` if given, else the plan of the
        last ``fit`` / the live incremental session."""
        if db is not None:
            return planner.make_plan(self.config, db.nevents)
        if self.last_plan is not None:
            return self.last_plan
        return planner.make_plan(self.config, incremental=True)

    # --- batch input --------------------------------------------------------
    def fit(self, db: DBMart) -> SequenceFrame:
        """Mine a whole dbmart through the planned engine."""
        if self.service is not None:
            raise RuntimeError("session is already streaming (submit/tick); "
                               "use a fresh session for batch fit")
        plan = planner.make_plan(self.config, db.nevents)
        self.last_plan = plan
        fit = getattr(self, f"_fit_{plan.engine}")
        with self.telemetry.tracer.span("session.fit", cat="host",
                                        engine=plan.engine):
            self.last_frame = fit(db)
        return self.last_frame

    def _frame(self, seq, dur, patient, mask=None, counts=None,
               vocab=None, n_patients=None) -> SequenceFrame:
        c = self.config
        return SequenceFrame(
            seq, dur, patient, mask, vocab=vocab, codec=c.codec,
            fuse_duration=c.fuse_duration, bucket_days=c.bucket_days,
            n_patients=n_patients, counts=counts,
            n_buckets_log2=c.n_buckets_log2, screen_mode=c.screen,
            threshold=c.threshold)

    def _fit_fused(self, db: DBMart) -> SequenceFrame:
        """screen='fused': corpus-free counting pass, survivors-only
        materialization (chunking.mine_fused) — the only pair-allocating
        path is one re-mine chunk at a time plus the survivors."""
        c = self.config
        out = chunking.mine_fused(
            db, threshold=c.threshold,
            budget_bytes=c.budget_bytes or (1 << 28), codec=c.codec,
            backend=c.backend, n_buckets_log2=c.n_buckets_log2,
            fuse_duration=c.fuse_duration, bucket_days=c.bucket_days)
        return self._frame(out["seq"], out["dur"], out["patient"],
                           counts=out["counts"], vocab=db.vocab,
                           n_patients=db.n_patients)

    def _fit_batch(self, db: DBMart) -> SequenceFrame:
        c = self.config
        if c.screen == "fused":
            return self._fit_fused(db)
        mined = mining.mine(db.phenx, db.date, db.nevents, codec=c.codec,
                            fuse_duration=c.fuse_duration,
                            bucket_days=c.bucket_days, backend=c.backend)
        counts = (sparsity.local_bucket_counts(
            mined.seq, mined.mask, c.n_buckets_log2)
            if c.screen == "hash" else None)
        seq, dur, pat, msk = mining.flatten(mined)
        return self._frame(seq, dur, pat, msk, counts=counts,
                           vocab=db.vocab, n_patients=db.n_patients)

    def _fit_chunked(self, db: DBMart) -> SequenceFrame:
        c = self.config
        if c.screen == "fused":
            return self._fit_fused(db)
        out = chunking.mine_chunked(
            db, budget_bytes=c.budget_bytes or (1 << 28), codec=c.codec,
            backend=c.backend, n_buckets_log2=c.n_buckets_log2,
            fuse_duration=c.fuse_duration, bucket_days=c.bucket_days,
            with_counts=c.screen == "hash")
        return self._frame(out["seq"], out["dur"], out["patient"],
                           out["mask"], counts=out.get("counts"),
                           vocab=db.vocab, n_patients=db.n_patients)

    def _fit_files(self, db: DBMart) -> SequenceFrame:
        c = self.config
        if c.screen == "fused":
            # corpus-free screen first; only survivors ever hit the disk,
            # keeping the spill-directory contract (chunk .npz + merged
            # bucket_counts.npy) intact
            out = chunking.mine_fused(
                db, threshold=c.threshold,
                budget_bytes=c.budget_bytes or (1 << 28), codec=c.codec,
                backend=c.backend, n_buckets_log2=c.n_buckets_log2,
                fuse_duration=c.fuse_duration, bucket_days=c.bucket_days)
            if c.spill_dir:
                os.makedirs(c.spill_dir, exist_ok=True)
                np.save(os.path.join(c.spill_dir, "bucket_counts.npy"),
                        out["counts"])
                np.savez(os.path.join(c.spill_dir, "chunk_00000.npz"),
                         seq=out["seq"], dur=out["dur"],
                         patient=out["patient"])
            return self._frame(out["seq"], out["dur"], out["patient"],
                               counts=out["counts"], vocab=db.vocab,
                               n_patients=db.n_patients)
        out_dir = c.spill_dir or tempfile.mkdtemp(prefix="tspm_spill_")
        try:
            chunking.mine_to_files(
                db, out_dir, budget_bytes=c.budget_bytes or (1 << 28),
                codec=c.codec, backend=c.backend,
                n_buckets_log2=c.n_buckets_log2,
                fuse_duration=c.fuse_duration, bucket_days=c.bucket_days)
            out = chunking.load_files(out_dir)
        finally:
            if c.spill_dir is None:   # we made the dir; don't leak a corpus
                shutil.rmtree(out_dir, ignore_errors=True)
        return self._frame(out["seq"], out["dur"], out["patient"],
                           counts=out["counts"], vocab=db.vocab,
                           n_patients=db.n_patients)

    def _replay(self, db: DBMart, svc) -> None:
        for p in range(db.n_patients):
            n = int(db.nevents[p])
            if n:
                svc.submit(p, db.date[p, :n], db.phenx[p, :n])
        svc.run()

    def _fit_stream(self, db: DBMart) -> SequenceFrame:
        svc = self._make_service(sharded=False)
        self._replay(db, svc)
        return self._snap_frame(svc, vocab=db.vocab,
                                n_patients=db.n_patients)

    def _fit_sharded(self, db: DBMart) -> SequenceFrame:
        router = self.router
        if router is None and self.config.router == "balance":
            router = ShardRouter.balanced(
                list(range(db.n_patients)), np.asarray(db.nevents),
                self.config.n_shards)
        svc = self._make_service(sharded=True, router=router)
        self._replay(db, svc)
        return self._snap_frame(svc, vocab=db.vocab,
                                n_patients=db.n_patients)

    # --- incremental input --------------------------------------------------
    def submit(self, key, dates, phenx) -> None:
        """Queue one patient delta; ingest with ``tick()`` / ``run()``."""
        self._ensure_service().submit(key, dates, phenx)

    def tick(self) -> SequenceFrame:
        """Ingest one wave (every shard with queued work) and return the
        live frame over the updated corpus."""
        self._ensure_service().tick()
        return self.frame()

    def run(self) -> SequenceFrame:
        """Drain the queue, then return the live frame."""
        self._ensure_service().run()
        return self.frame()

    def frame(self) -> SequenceFrame:
        """The current result: the live streaming corpus, or the last
        ``fit`` result for a batch session."""
        if self.service is None:
            if self.last_frame is not None:
                return self.last_frame
            raise RuntimeError("nothing mined yet: fit() a dbmart or "
                               "submit() deltas first")
        return self._snap_frame(self.service, vocab=self.vocab)

    # --- serving ------------------------------------------------------------
    def serve(self, **kw):
        """Stand up a :class:`~repro.serving.tspm.server.QueryServer` over
        this session — the read path.

        Live streaming sessions get a replica that re-publishes at every
        tick boundary (queries never block ``submit``/``tick`` and never
        see a half-applied tick); batch-fit sessions serve a static view
        of ``last_frame``.  Keywords forward to ``QueryServer``:
        ``batch_size``, ``cache_entries``, ``feature_ids`` (streams the
        per-patient feature matrix), ``auto_publish``.  Calling ``serve``
        on a fresh incremental session stands the service up first so the
        server can subscribe to tick boundaries."""
        from repro.serving.tspm import QueryServer
        if self.service is None and self.last_frame is None:
            self._ensure_service()
        return QueryServer(self, **kw)

    def _ensure_service(self):
        if self.service is None:
            if self.last_frame is not None:
                raise RuntimeError(
                    "session already ran a batch fit; use a fresh session "
                    "for incremental submit/tick")
            plan = planner.make_plan(self.config, incremental=True)
            if plan.engine not in ("stream", "sharded"):
                raise ValueError(
                    f"engine {plan.engine!r} cannot ingest incrementally; "
                    "leave MiningConfig.engine unset or pick stream/sharded")
            self.last_plan = plan
            self.service = self._make_service(sharded=plan.engine == "sharded",
                                              router=self.router)
        return self.service

    def _make_service(self, sharded: bool, router: ShardRouter | None = None):
        c = self.config
        kw = dict(tick_patients=c.tick_patients, codec=c.codec,
                  backend=c.backend, n_buckets_log2=c.n_buckets_log2,
                  budget_bytes=c.budget_bytes, fuse_duration=c.fuse_duration,
                  bucket_days=c.bucket_days, max_slot_events=c.max_slot_events,
                  disk_bytes=c.disk_bytes, disk_dir=c.disk_dir)
        tel = self.telemetry if self.telemetry.enabled else None
        if not sharded:
            svc = StreamService(telemetry=tel, **kw)
        else:
            svc = ShardedStreamService(
                n_shards=c.n_shards, router=router, mesh=self.mesh,
                rebalance_every=c.rebalance_every,
                imbalance_threshold=c.imbalance_threshold,
                min_gain=c.min_gain,
                busy_weighted_rebalance=c.busy_weighted_rebalance,
                placement=planner.resolve_placement(c), telemetry=tel, **kw)
        if c.journal_dir is not None:
            from repro.journal.journal import TickJournal
            self._journal = TickJournal(c.journal_dir,
                                        commit_every=c.journal_commit_every,
                                        telemetry=tel)
            self._journal.attach(svc,
                                 engine="sharded" if sharded else "stream",
                                 config=dataclasses.asdict(c))
        return svc

    # --- checkpoint / resume ------------------------------------------------
    def checkpoint(self, ckpt_dir: str, step: int | None = None,
                   extra: dict | None = None) -> str:
        """Atomically capture the live streaming session to ``ckpt_dir``.

        Everything that makes continuation byte-identical goes in: store
        planes and residency tiers, sketch tables, queued deltas, the
        mined corpus, router pins, in-flight migration payloads, and tick
        counters — via the training checkpoint layout (``arrays.npz`` +
        ``manifest.json`` in a tmp dir, atomically renamed), so a crash
        mid-save never corrupts the previous checkpoint.  ``step``
        defaults to the service's tick count; ``extra`` is a JSON-able
        user dict surfaced as ``restore_extra`` after :meth:`restore`.
        Returns the checkpoint path."""
        if self.service is None:
            raise RuntimeError("nothing to checkpoint: only live streaming "
                               "sessions persist; submit()/tick() first "
                               "(batch fit results are already a frame)")
        with self.telemetry.tracer.span("checkpoint.save", cat="host"):
            sharded = isinstance(self.service, ShardedStreamService)
            state = self.service.state_dict()
            if step is None:
                step = int(state["tick_count"] if sharded
                           else state["n_ticks"])
            tree = {"format": "tspm-session-v1",
                    "engine": "sharded" if sharded else "stream",
                    "config": dataclasses.asdict(self.config),
                    "state": state}
            json_tree, arrays = pack_tree(tree)
            path = ckpt_lib.save(ckpt_dir, step, arrays,
                                 extra={"session": json_tree,
                                        "user": extra or {}})
        if self.service.events.wants(CheckpointTaken):
            self.service.events.emit(
                CheckpointTaken(step=int(step), path=path))
        return path

    @classmethod
    def restore(cls, ckpt_dir: str, *, mesh=None,
                vocab: Vocab | None = None) -> "MiningSession":
        """Rebuild a streaming session from a :meth:`checkpoint` directory
        (or one specific ``step_*`` path inside it) and continue exactly
        where it left off — the restarted service's corpus, sketch, and
        router state are byte-identical to the uninterrupted run's.
        Runtime resources (``mesh``, ``vocab``) are re-supplied by the
        caller, like the constructor."""
        path = ckpt_dir
        if not os.path.exists(os.path.join(path, "manifest.json")):
            found = ckpt_lib.latest(ckpt_dir)
            if found is None:
                raise FileNotFoundError(
                    f"no checkpoint under {ckpt_dir!r}")
            path = found
        leaves, manifest = ckpt_lib.load(path)
        tree = unpack_tree(manifest["extra"]["session"], leaves)
        if tree.get("format") != "tspm-session-v1":
            raise ValueError(f"{path!r} is not a session checkpoint "
                             f"(format {tree.get('format')!r})")
        config = MiningConfig(**tree["config"])
        session = cls(config, mesh=mesh, vocab=vocab)
        with session.telemetry.tracer.span("checkpoint.restore", cat="host"):
            sharded = tree["engine"] == "sharded"
            svc = session._make_service(sharded=sharded)
            svc.load_state_dict(tree["state"])
            session.service = svc
            session.last_plan = planner.make_plan(config, incremental=True)
        session.restore_extra = manifest["extra"].get("user", {})
        return session

    # --- events / journal ---------------------------------------------------
    def events(self, kinds=None, maxlen: int | None = 4096) -> EventTap:
        """A pull-side tap on the session's typed event stream
        (:mod:`repro.stream.events`): iterate it to drain every
        ``SessionEvent`` (``DeltaSubmitted`` / ``TickCompleted`` /
        ``Evicted`` / ``Migrated`` / ``Rebalanced`` / ``CheckpointTaken``)
        emitted since the last drain.  ``kinds`` filters to an event
        class or tuple of them.  Push-side consumers subscribe on
        ``session.service.subscribe(fn, kinds=...)`` instead."""
        return EventTap(self._ensure_service(), kinds=kinds, maxlen=maxlen)

    def journal(self):
        """The live :class:`~repro.journal.journal.TickJournal`, or None
        when the session was built without ``journal_dir``."""
        return self._journal

    def verify(self, journal_dir: str | None = None):
        """Verify a journal against this live session -> ``VerifyResult``.

        With no argument, verifies the session's own journal; pass a
        ``journal_dir`` to check a foreign copy (an auditor's, a
        claimed fork).  Three layers (see :mod:`repro.journal.verify`):
        segment/chain structure, byte-exact replay through a shadow
        journal (merkle commitments re-derived and compared), and —
        because a live session is present — an entry-by-entry fork
        check against the session's own log plus a final-state
        comparison.  Any failure carries a typed ``FraudProof`` naming
        the first divergent tick."""
        from repro.journal import verify as jv
        own = self._journal
        if own is not None:
            own.flush()
        target = journal_dir if journal_dir is not None else \
            (own.root if own is not None else None)
        if target is None:
            raise RuntimeError("nothing to verify: the session has no "
                               "journal (set MiningConfig.journal_dir) and "
                               "no journal_dir was given")
        res, replayed = jv.verify_replay(target, mesh=self.mesh,
                                         vocab=self.vocab)
        if not res.ok:
            return res
        if own is not None and journal_dir is not None \
                and os.path.abspath(journal_dir) != os.path.abspath(own.root):
            proof = jv.compare_journals(own.entries(),
                                        jv.read_journal(journal_dir))
            if proof is not None:
                return dataclasses.replace(res, ok=False, proof=proof)
        if self.service is not None and replayed is not None:
            proof = jv.state_divergence(self.service, replayed.service,
                                        n_ticks=res.n_ticks)
            if proof is not None:
                return dataclasses.replace(res, ok=False, proof=proof)
        return res

    @classmethod
    def replay(cls, journal_dir: str, upto_tick: int | None = None, *,
               mesh=None, vocab: Vocab | None = None) -> "MiningSession":
        """Reconstruct a session from a journal by re-applying its
        recorded commands — corpus, sketch table, and router pins are
        byte-identical to the recorded run's state (optionally only
        through ``upto_tick``).  Complements :meth:`restore`: a
        checkpoint is a state snapshot, the journal is the full
        audited history."""
        from repro.journal import verify as jv
        return jv.replay(journal_dir, upto_tick=upto_tick, mesh=mesh,
                         vocab=vocab)

    # --- observability ------------------------------------------------------
    def metrics(self) -> dict:
        """Flat snapshot of every telemetry metric (``name{labels}`` ->
        value, histograms as summary dicts).  Snapshot-time gauges (plane
        bytes, occupancy, sketch load factor, queue depths) are refreshed
        from the live service first.  Requires ``MiningConfig(telemetry=True)``."""
        if not self.telemetry.enabled:
            raise RuntimeError("telemetry is disabled; build the session "
                               "with MiningConfig(telemetry=True)")
        if self.service is not None:
            self.service.sample_metrics()
        return self.telemetry.metrics.snapshot()

    def trace(self):
        """The session's :class:`~repro.obs.SpanTracer` (span trees over
        ticks, shards, migrations; export with ``to_chrome_trace()`` /
        ``dump_chrome_trace(path)``).  Requires ``MiningConfig(telemetry=True)``."""
        if not self.telemetry.enabled:
            raise RuntimeError("telemetry is disabled; build the session "
                               "with MiningConfig(telemetry=True)")
        return self.telemetry.tracer

    def shard_load(self) -> list[float]:
        """Device-timed busy fraction per shard since the last poll
        (sharded engine only; see ShardedStreamService.shard_load)."""
        svc = self.service
        if not isinstance(svc, ShardedStreamService):
            raise RuntimeError("shard_load() needs a live sharded service")
        return svc.shard_load()

    def _snap_frame(self, svc, vocab=None, n_patients=None) -> SequenceFrame:
        snap = svc.snapshot()
        if isinstance(svc, ShardedStreamService):
            p2k = svc.pid_to_key()
        else:
            p2k = {pid: k for k, pid in svc.store.pids.items()}
        if p2k and all(isinstance(k, (int, np.integer))
                       for k in p2k.values()):
            # patient column = original integer keys, via a pid lut (pids
            # are dense admission-order ints, possibly with retired holes)
            lut = np.full(max(p2k) + 1, -1, np.int64)
            for pid, key in p2k.items():
                lut[pid] = key
            patient = lut[snap.patient].astype(np.int32)
        else:
            patient = snap.patient    # non-int keys: keep dense pids
        seq, dur = snap.seq, snap.dur
        if self.config.screen == "fused":
            # the sketch table already equals the batch bucket counts
            # (property-tested); compact the snapshot to its hash-screen
            # survivors so streaming frames match the fused batch frames
            seq, dur, patient = sparsity.screen_survivors(
                seq, dur, patient, np.asarray(snap.counts),
                self.config.threshold, self.config.n_buckets_log2)
        return self._frame(seq, dur, patient, counts=snap.counts,
                           vocab=vocab, n_patients=n_patients)

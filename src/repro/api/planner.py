"""Execution planner: pick the engine from cohort size vs budget vs arrival.

The R package "split[s] the dbmart in chunks with an adaptive size to fit
the available memory limitations" and falls back to a file-based mode; the
streaming subsystem added incremental arrival and sharding.  The planner
encodes that decision tree once, using the same cost model everywhere
(``chunking.BYTES_PER_PAIR`` over padded pair slabs):

  * incremental input          -> 'stream' (or 'sharded' when n_shards > 1);
  * batch input, n_shards > 1  -> 'sharded' (the config asked for shards);
  * working set fits budget    -> 'batch';
  * flat corpus > spill_bytes  -> 'files' (host RAM is the next wall);
  * otherwise                  -> 'chunked'.

For the sharded engine the plan also resolves *placement*: with
``placement='auto'`` shards are pinned one-per-device whenever sharding
is actually on (``n_shards > 1``) and the host has at least as many
devices as shards (every tick then overlaps across devices and migration
handoffs admit asynchronously), else they stay host-serial on the
default device.  Both placements are byte-identical;
the choice is again purely a resource decision.

``MiningConfig.engine`` short-circuits everything — the plan records that
it was forced.  Every engine yields byte-identical results (the conformance
suite), so the choice is purely a resource decision.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.api.config import MiningConfig, Plan
from repro.analysis import roofline
from repro.core import chunking

# flat corpus row: 8B seq + 4B dur + 4B patient + 1B mask
_BYTES_PER_ROW = 17


def _working_set(nevents: np.ndarray, config: MiningConfig,
                 pad_multiple: int = 8) -> int:
    """One-shot mining working set: the whole cohort as a single chunk."""
    e = int(np.max(nevents, initial=1))
    e = max(-(-e // pad_multiple) * pad_multiple, 1)
    factor = 1.0 if config.backend == "kernel" else 0.5  # dense vs triangular
    return int(len(nevents) * e * e * chunking.BYTES_PER_PAIR * factor)


def _fused_working_set(nevents: np.ndarray, config: MiningConfig,
                       pad_multiple: int = 8) -> int:
    """Screen-pass working set under ``screen='fused'``: one patient block
    of dense pair slabs plus the [2^H] table — independent of P once the
    cohort exceeds a block.  This is the planner's second, much cheaper
    budget regime (the corpus is never materialized before the screen)."""
    e = int(np.max(nevents, initial=1))
    e = max(-(-e // pad_multiple) * pad_multiple, 1)
    plan = roofline.mining_tile_plan(e, config.n_buckets_log2)
    blk = min(plan.block_patients, len(nevents))
    return int(blk * e * e * chunking.BYTES_PER_PAIR
               + (4 << config.n_buckets_log2))


def _corpus_bytes(nevents: np.ndarray) -> int:
    n = nevents.astype(np.int64)
    return int(np.sum(n * (n - 1) // 2)) * _BYTES_PER_ROW


def resolve_placement(config: MiningConfig) -> str:
    """Shard placement for the sharded engine, 'auto' resolved against the
    visible devices: pin one shard per device when the host can (ticks
    overlap across devices, migration admits async), else host-serial.
    Forced 'devices' is honored even with fewer devices than shards
    (round-robin assignment — still correct, shards just share devices)."""
    if config.placement != "auto":
        return config.placement
    if config.n_shards > 1 and len(jax.devices()) >= config.n_shards:
        return "devices"
    return "host"


def make_plan(config: MiningConfig, nevents=None,
              incremental: bool = False) -> Plan:
    """Decide the engine for a cohort (``nevents`` per patient) or an
    incremental session (``incremental=True``, no cohort known up front)."""
    nevents = (np.zeros(0, np.int64) if nevents is None
               else np.asarray(nevents, np.int64))
    fused = config.screen == "fused"
    if len(nevents):
        ws = (_fused_working_set(nevents, config) if fused
              else _working_set(nevents, config))
    else:
        ws = 0
    corpus = _corpus_bytes(nevents) if len(nevents) else 0
    budget = config.budget_bytes
    n_chunks = (len(chunking.plan_chunks(nevents, budget))
                if budget is not None and len(nevents) else 1)
    placement = resolve_placement(config)
    common = dict(working_set_bytes=ws, budget_bytes=budget,
                  disk_bytes=config.disk_bytes,
                  corpus_bytes=corpus, n_chunks=n_chunks,
                  n_shards=config.n_shards, placement=placement,
                  incremental=incremental, corpus_free=fused)

    if config.engine is not None:
        return Plan(config.engine,
                    "forced by MiningConfig.engine override", **common)
    if incremental:
        if config.n_shards > 1:
            return Plan("sharded", f"incremental input over "
                        f"{config.n_shards} patient shards "
                        f"({placement} placement)", **common)
        return Plan("stream", "incremental input (submit/tick)", **common)
    if config.n_shards > 1:
        return Plan("sharded", f"config requests {config.n_shards} patient "
                    "shards; batch input replayed through them "
                    f"({placement} placement)", **common)
    # spill is a host-RAM decision, independent of the device working set:
    # a cohort can fit the mining budget chunk-by-chunk and still produce a
    # flat corpus too big to hold in memory
    if config.spill_bytes is not None and corpus > config.spill_bytes:
        return Plan("files", "flat corpus exceeds spill_bytes; chunks spill "
                    "to disk and screen via the merged count table", **common)
    if budget is None or ws <= budget:
        reason = ("corpus-free fused screen working set fits the byte "
                  "budget" if fused
                  else "mining working set fits the byte budget")
        return Plan("batch", reason, **common)
    return Plan("chunked", "working set exceeds budget_bytes; mining "
                f"adaptively in {n_chunks} patient chunks", **common)

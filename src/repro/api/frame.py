"""SequenceFrame: the façade's unified mining result.

Every engine — batch, chunked, file-based, streaming, sharded — lands in
the same place: a flat (seq, dur, patient) corpus in a *canonical order*
(lexicographic by sequence id, then patient, then duration), with padding
rows already dropped.  That canonicalization is what makes the conformance
guarantee byte-identical rather than merely set-equal: two engines that
mine the same pairs produce the same arrays, whatever order they touched
patients in.

Mask methods are **chainable and lazily composed**: each returns a new
frame sharing the corpus, with one more predicate appended; nothing is
evaluated until a terminal (``collect``, ``unique``, ``decode``,
``to_features``, ``arrays``, ``n_kept``) forces the composed keep mask.
Predicates see the keep mask accumulated so far, so order matters where it
should — ``.screen(5).transitive_ends_with(x)`` builds its end-set table
from screened sequences only.

Support is the paper's *distinct-patient* support, computed exactly from
the canonical corpus; ``screen`` applies it directly (mode 'sorted') or
via the engines' shared hash-bucket table (mode 'hash', one-sided error —
both modes are engine-invariant).  Duration-fused ids are first-class: the
frame knows ``fuse_duration`` and routes every unpack-based helper through
the fuse-aware path (core/queries), so ``starts_with`` on a fused corpus
reads phenX codes, not duration bits.
"""
from __future__ import annotations

import threading
from typing import Callable, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import msmr, queries, sparsity
from repro.core.encoding import Vocab


class Result(NamedTuple):
    """Kept rows in canonical order + their distinct-patient support."""

    seq: np.ndarray      # [K] int64
    dur: np.ndarray      # [K] int32
    patient: np.ndarray  # [K] int32
    support: np.ndarray  # [K] int32


class Decoded(NamedTuple):
    seq_id: int
    text: str
    support: int


class _Corpus:
    """Shared immutable canonical corpus + lazily-filled caches.

    Chained frames all point at one ``_Corpus``, so support and the hash
    table are computed at most once per mining run, not per mask method.
    Construction materializes the engine's flat arrays to host (the same
    work the hand-wired flow's ``np.asarray`` does) but defers the
    padding compaction + canonical lexsort until a mask or terminal first
    needs row access — ``fit`` alone costs what ``mine`` + ``flatten``
    cost (benchmarks/api_overhead.py holds this under 5%).
    """

    __slots__ = ("n_buckets_log2", "_raw", "_n_rows",
                 "_seq", "_dur", "_patient",
                 "_counts", "_support", "_pair_first",
                 "_prefix_cache", "_lock")

    #: forced-prefix masks kept per corpus before the cache resets — masks
    #: are [N] bools, so even the cap costs well under the corpus itself
    PREFIX_CACHE_MAX = 256

    def __init__(self, seq, dur, patient, mask, counts, n_buckets_log2):
        seq = np.asarray(seq, np.int64).reshape(-1)
        dur = np.asarray(dur, np.int32).reshape(-1)
        patient = np.asarray(patient, np.int32).reshape(-1)
        if mask is not None:
            mask = np.asarray(mask, bool).reshape(-1)
        self._raw = (seq, dur, patient, mask)
        self._n_rows = len(seq) if mask is None else None  # lazy when masked
        self._seq = self._dur = self._patient = None
        self.n_buckets_log2 = n_buckets_log2
        self._counts = None if counts is None else np.asarray(counts, np.int32)
        self._support = None
        self._pair_first = None
        # keep masks memoized per op-chain prefix: chained frames share
        # their parents' op tuples structurally, so forcing a long chain
        # reuses every already-forced prefix instead of re-running it
        self._prefix_cache: dict[tuple, np.ndarray] = {}
        # serving replicas force one corpus from several query threads;
        # double-checked in _canonicalize so the hot path stays lock-free
        self._lock = threading.Lock()

    def _canonicalize_locked(self) -> None:
        seq, dur, patient, mask = self._raw
        if mask is not None:
            seq, dur, patient = seq[mask], dur[mask], patient[mask]
        order = np.lexsort((dur, patient, seq))
        # _seq is the published-flag the lock-free fast path checks, so it
        # is assigned last; _raw stays readable for any reader already past
        # the check (it only flips to None after everything is in place)
        self._dur, self._patient = dur[order], patient[order]
        self._seq = seq[order]
        self._raw = None

    def _canonicalize(self) -> None:
        if self._seq is not None:
            return
        with self._lock:
            if self._seq is None:
                self._canonicalize_locked()

    @property
    def seq(self) -> np.ndarray:
        self._canonicalize()
        return self._seq

    @property
    def dur(self) -> np.ndarray:
        self._canonicalize()
        return self._dur

    @property
    def patient(self) -> np.ndarray:
        self._canonicalize()
        return self._patient

    def __len__(self) -> int:
        if self._n_rows is None:
            # capture _raw before checking _seq: a concurrent canonicalize
            # flips _seq first and _raw last, so a stale local _raw is
            # still valid (the arrays themselves never mutate)
            raw = self._raw
            if self._seq is not None:
                self._n_rows = len(self._seq)
            else:
                self._n_rows = int(raw[3].sum())
        return self._n_rows

    def pair_first(self) -> np.ndarray:
        """First-occurrence flags of each distinct (seq, patient) pair —
        the per-patient dedup of the paper's support semantics."""
        if self._pair_first is None:
            if len(self.seq) == 0:
                self._pair_first = np.zeros(0, bool)
            else:
                new_seq = np.concatenate(
                    [[True], self.seq[1:] != self.seq[:-1]])
                self._pair_first = new_seq | np.concatenate(
                    [[True], self.patient[1:] != self.patient[:-1]])
        return self._pair_first

    def support(self) -> np.ndarray:
        """Exact distinct-patient support aligned to every corpus row."""
        if self._support is None:
            n = len(self.seq)
            if n == 0:
                self._support = np.zeros(0, np.int32)
            else:
                new_seq = np.concatenate(
                    [[True], self.seq[1:] != self.seq[:-1]])
                seg = np.cumsum(new_seq) - 1
                per_seq = np.bincount(
                    seg[self.pair_first()], minlength=seg[-1] + 1)
                self._support = per_seq[seg].astype(np.int32)
        return self._support

    def counts(self) -> np.ndarray:
        """Hash-bucket support table.  Engines hand over their native table
        (batch screen counts, spill-file table, streaming sketch, psum-merged
        shard tables — all exactly equal, property-tested); frames built
        without one derive it here from the canonical corpus."""
        if self._counts is None:
            ids = self.seq[self.pair_first()]
            h = np.asarray(sparsity.hash_bucket(ids, self.n_buckets_log2))
            counts = np.zeros(1 << self.n_buckets_log2, np.int32)
            np.add.at(counts, h, 1)
            self._counts = counts
        return self._counts


def _rank_by_support(ids: np.ndarray, sup: np.ndarray,
                     k: int | None = None) -> np.ndarray:
    """Indices of ``ids`` ordered most-supported first, ties on the smaller
    id — the one deterministic ranking behind ``top_k`` / ``decode`` /
    ``to_features``, so every engine picks the same set."""
    order = np.lexsort((ids, -sup))
    return order if k is None else order[:max(k, 0)]


_Op = tuple[str, Callable]


class SequenceFrame:
    """Chainable view over a mined corpus (see module docstring)."""

    def __init__(self, seq, dur, patient, mask=None, *, vocab: Vocab | None = None,
                 codec: str = "bit", fuse_duration: bool = False,
                 bucket_days: int = 30, n_patients: int | None = None,
                 counts=None, n_buckets_log2: int = 20,
                 screen_mode: str = "sorted", threshold: int | None = None,
                 _corpus: _Corpus | None = None, _ops: tuple[_Op, ...] = ()):
        self._corpus = _corpus if _corpus is not None else _Corpus(
            seq, dur, patient, mask, counts, n_buckets_log2)
        self.vocab = vocab
        self.codec = codec
        self.fuse_duration = fuse_duration
        self.bucket_days = bucket_days
        self._n_patients = int(n_patients) if n_patients is not None else None
        self.screen_mode = screen_mode
        self.threshold = threshold
        self._ops = _ops
        self._keep_cache: np.ndarray | None = None

    @property
    def n_patients(self) -> int:
        if self._n_patients is None:
            c = self._corpus
            self._n_patients = int(c.patient.max()) + 1 if len(c) else 0
        return self._n_patients

    # --- chaining machinery -------------------------------------------------
    def _chain(self, op: _Op) -> "SequenceFrame":
        return SequenceFrame(
            None, None, None, vocab=self.vocab, codec=self.codec,
            fuse_duration=self.fuse_duration, bucket_days=self.bucket_days,
            n_patients=self._n_patients,
            n_buckets_log2=self._corpus.n_buckets_log2,
            screen_mode=self.screen_mode, threshold=self.threshold,
            _corpus=self._corpus, _ops=self._ops + (op,))

    def keep_mask(self) -> np.ndarray:
        """Force the lazily-composed predicate chain; cached per frame,
        and memoized per op-chain *prefix* on the shared corpus: chained
        frames extend their parent's ``_ops`` tuple structurally, so
        ``f.screen()``, ``f.screen().starts_with(x)`` and
        ``f.screen().starts_with(x).top_k(k)`` force each op exactly once
        between them, whichever is evaluated first.  Masks in the cache
        are never mutated (every op composes with ``&`` into a new
        array), so sharing them across frames is safe."""
        if self._keep_cache is None:
            cache = self._corpus._prefix_cache
            n = len(self._ops)
            run_from, keep = 0, None
            for i in range(n, 0, -1):       # longest already-forced prefix
                keep = cache.get(self._ops[:i])
                if keep is not None:
                    run_from = i
                    break
            if keep is None:
                keep = np.ones(len(self._corpus), bool)
            for j in range(run_from, n):
                keep = self._ops[j][1](self, keep)
                if len(cache) >= self._corpus.PREFIX_CACHE_MAX:
                    cache.clear()
                cache[self._ops[:j + 1]] = keep
            self._keep_cache = keep
        return self._keep_cache

    def __repr__(self) -> str:
        ops = ".".join(name for name, _ in self._ops) or "(all)"
        pats = "?" if self._n_patients is None else self._n_patients
        return (f"SequenceFrame({len(self._corpus):,} rows, "
                f"{pats} patients, ops={ops})")

    def __len__(self) -> int:
        return len(self._corpus)

    # --- chainable masks ----------------------------------------------------
    def screen(self, threshold: int | None = None) -> "SequenceFrame":
        """Sparsity screen at distinct-patient ``threshold`` (default: the
        config's).  Mode 'sorted' uses exact support; 'hash' the engines'
        shared bucket table (one-sided: collisions only ever over-keep);
        'fused' frames hold corpus-free-screened survivors and re-screen
        against the same table (idempotent at the fit threshold, exact for
        any higher one)."""
        thr = self.threshold if threshold is None else threshold
        if thr is None:
            raise ValueError("no threshold: pass one or set MiningConfig.threshold")

        def op(fr: "SequenceFrame", keep: np.ndarray) -> np.ndarray:
            if fr.screen_mode in ("hash", "fused"):
                return np.asarray(sparsity.screen_hash_from_counts(
                    fr._corpus.seq, keep, fr._corpus.counts(), thr,
                    fr._corpus.n_buckets_log2))
            return keep & (fr._corpus.support() >= thr)

        return self._chain((f"screen({thr})", op))

    def starts_with(self, phenx_id: int) -> "SequenceFrame":
        def op(fr, keep):
            return keep & np.asarray(queries.starts_with(
                fr._corpus.seq, phenx_id, fr.codec, fused=fr.fuse_duration))
        return self._chain((f"starts_with({phenx_id})", op))

    def ends_with(self, phenx_id: int) -> "SequenceFrame":
        def op(fr, keep):
            return keep & np.asarray(queries.ends_with(
                fr._corpus.seq, phenx_id, fr.codec, fused=fr.fuse_duration))
        return self._chain((f"ends_with({phenx_id})", op))

    def min_duration(self, days: int) -> "SequenceFrame":
        def op(fr, keep):
            return keep & np.asarray(queries.min_duration(fr._corpus.dur, days))
        return self._chain((f"min_duration({days})", op))

    def transitive_ends_with(self, start_phenx_id: int) -> "SequenceFrame":
        """Rows whose end phenX ends any *currently-kept* sequence starting
        with ``start_phenx_id`` (the paper's combined helper; chain it after
        ``screen`` to restrict the table to supported sequences)."""
        def op(fr, keep):
            return keep & np.asarray(queries.transitive_ends_with(
                fr._corpus.seq, keep, start_phenx_id, fr.codec,
                fused=fr.fuse_duration))
        return self._chain((f"transitive_ends_with({start_phenx_id})", op))

    def top_k(self, k: int) -> "SequenceFrame":
        """Keep only the ``k`` most-supported distinct sequence ids among
        currently-kept rows (ties break on the smaller id — deterministic,
        so every engine picks the same set)."""
        def op(fr, keep):
            ids = fr._corpus.seq[keep]
            if len(ids) == 0:
                return keep
            sup = fr._corpus.support()[keep]
            u, idx = np.unique(ids, return_index=True)
            allowed = np.sort(u[_rank_by_support(u, sup[idx], k)])
            if len(allowed) == 0:
                return np.zeros_like(keep)
            pos = np.clip(np.searchsorted(allowed, fr._corpus.seq),
                          0, len(allowed) - 1)
            return keep & (allowed[pos] == fr._corpus.seq)
        return self._chain((f"top_k({k})", op))

    # --- terminals ----------------------------------------------------------
    @property
    def n_kept(self) -> int:
        return int(self.keep_mask().sum())

    def collect(self) -> Result:
        keep = self.keep_mask()
        c = self._corpus
        return Result(c.seq[keep], c.dur[keep], c.patient[keep],
                      c.support()[keep])

    def unique(self) -> tuple[np.ndarray, np.ndarray]:
        """(distinct kept ids sorted ascending, their supports)."""
        keep = self.keep_mask()
        ids = self._corpus.seq[keep]
        u, idx = np.unique(ids, return_index=True)
        return u, self._corpus.support()[keep][idx]

    def decode(self, limit: int | None = None) -> list[Decoded]:
        """Kept distinct sequences as human-readable strings, most-supported
        first (ties on the smaller id).  Needs a vocab on the frame."""
        if self.vocab is None:
            raise ValueError("frame has no vocab; build the session from a "
                             "DBMart with one to decode sequences")
        ids, sup = self.unique()
        order = _rank_by_support(ids, sup, limit)
        return [Decoded(int(ids[i]),
                        self.vocab.decode_sequence(
                            int(ids[i]), self.codec, fused=self.fuse_duration),
                        int(sup[i]))
                for i in order]

    def to_features(self, k: int | None = None,
                    feature_ids=None) -> msmr.FeatureMatrix:
        """Patient x sequence binary feature matrix (the MSMR front half):
        features are the kept distinct ids (optionally the ``k`` most
        supported), presence is computed over kept rows only."""
        if feature_ids is None:
            ids, sup = self.unique()
            if k is not None:
                ids = ids[np.sort(_rank_by_support(ids, sup, k))]
            feature_ids = ids
        feature_ids = np.asarray(feature_ids, np.int64).reshape(-1)
        if len(feature_ids) == 0 or self.n_patients == 0:
            return msmr.FeatureMatrix(
                jnp.zeros((self.n_patients, len(feature_ids)), jnp.float32),
                jnp.asarray(feature_ids), jnp.asarray(len(feature_ids)))
        return msmr.feature_matrix(
            self._corpus.seq, self._corpus.patient, self.keep_mask(),
            jnp.asarray(feature_ids), n_patients=self.n_patients)

    def arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(seq, dur, patient, keep) over the full canonical corpus — the
        legacy hand-wired interface (core.postcovid et al. take these)."""
        c = self._corpus
        return c.seq, c.dur, c.patient, self.keep_mask()

"""Unified tSPM+ session API — one façade over every execution engine.

The paper ships its C++ core behind an R-package API "for an easy
integration into already existing machine learning workflows"; this package
is that surface for the repro.  Four execution engines exist underneath
(in-memory batch, chunked, file-based spill, streaming, sharded streaming),
each with its own calling convention — the façade folds them behind three
objects:

  * :class:`MiningConfig` — every knob in one frozen dataclass (codec,
    duration fusing, screen mode, backend, memory budget, shard count,
    rebalance hysteresis);
  * :class:`MiningSession` — ``fit(dbmart)`` for batch input,
    ``submit(...)`` / ``tick()`` for incremental input, and ``plan()`` to
    inspect (or override, via ``MiningConfig.engine``) which engine the
    planner picked;
  * :class:`SequenceFrame` — the unified result: flat (seq, dur, patient)
    arrays in a canonical order with chainable, lazily-composed mask
    methods (``.screen``, ``.starts_with``, ``.transitive_ends_with``,
    ``.top_k``, ``.to_features``, ``.decode``, ...).

Streaming sessions additionally expose the typed event stream
(``session.events()`` / ``session.service.subscribe``) and, with
``MiningConfig(journal_dir=...)``, the verifiable tick journal:
``session.journal()``, ``session.verify()``, and
``MiningSession.replay(journal_dir)`` (see :mod:`repro.journal`).

Conformance invariant (tests/test_api.py): for a fixed cohort,
``MiningSession.fit`` output — kept sequences, supports, decoded strings —
is byte-identical across every engine the planner can select.

Quickstart::

    from repro.api import MiningConfig, MiningSession

    session = MiningSession(MiningConfig(threshold=5))
    frame = session.fit(db)                       # planner picks the engine
    for d in frame.screen().top_k(8).decode():
        print(d.text, d.support)
"""
from repro.api.config import ENGINES, MiningConfig, Plan  # noqa: F401
from repro.api.frame import Decoded, Result, SequenceFrame  # noqa: F401
from repro.api.session import MiningSession  # noqa: F401

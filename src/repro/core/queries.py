"""Sequence utility queries (the paper's C++ helper functions).

The library "facilitates tasks such as extracting [sequences] with given
start phenX, end phenX or specified minimum durations.  Another function
combines these ... all sequences that end with a phenX which is an end phenX
of all sequences with a given start phenX" — the transitive expansion used
by the Post-COVID vignette.  All masks compose with the mining mask.

Duration-fused ids (``encoding.fuse_duration``) carry the bucketed duration
in the low ``DUR_BITS``; every helper takes ``fused=True`` to strip it
before unpacking — unpacking a fused id raw would read garbage phenX codes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.core.encoding import SENTINEL


def unpack_seq(seq, codec: str = "bit", fused: bool = False):
    """(start, end) phenX of a sequence id, stripping a fused duration
    bucket first when ``fused``."""
    seq = jnp.asarray(seq, jnp.int64)
    if fused:
        seq, _ = encoding.split_duration(seq)
    return encoding.unpack(seq, codec)


def starts_with(seq, phenx_id, codec: str = "bit", fused: bool = False):
    s, _ = unpack_seq(seq, codec, fused)
    return s == jnp.int32(phenx_id)


def ends_with(seq, phenx_id, codec: str = "bit", fused: bool = False):
    _, e = unpack_seq(seq, codec, fused)
    return e == jnp.int32(phenx_id)


def min_duration(dur, days: int):
    return jnp.asarray(dur) >= jnp.int32(days)


def _membership(values, table_sorted):
    """value in sorted sentinel-padded table (vectorized binary search)."""
    idx = jnp.searchsorted(table_sorted, values)
    idx = jnp.clip(idx, 0, table_sorted.shape[0] - 1)
    return table_sorted[idx] == values


def end_set(seq, mask, start_phenx_id, codec: str = "bit", max_set: int | None = None,
            fused: bool = False):
    """Sorted, sentinel-padded set of end-phenX over sequences starting with
    ``start_phenx_id``.  ``max_set`` bounds the static output size."""
    seq = jnp.asarray(seq, jnp.int64).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    s, e = unpack_seq(seq, codec, fused)
    sel = mask & (s == jnp.int32(start_phenx_id))
    ends = jnp.where(sel, e.astype(jnp.int64), SENTINEL)
    ends = jnp.sort(ends)
    first = jnp.concatenate([jnp.ones(1, bool), ends[1:] != ends[:-1]])
    ends = jnp.sort(jnp.where(first, ends, SENTINEL))
    if max_set is not None:
        ends = ends[:max_set]
    return ends


def transitive_ends_with(seq, mask, start_phenx_id, codec: str = "bit",
                         max_set: int | None = None, fused: bool = False):
    """Mask of sequences whose END phenX is an end of any sequence that
    STARTS with ``start_phenx_id`` (the paper's combined helper)."""
    table = end_set(seq, mask, start_phenx_id, codec, max_set, fused)
    _, e = unpack_seq(seq, codec, fused)
    return _membership(e.astype(jnp.int64), table) & jnp.asarray(mask, bool)


def per_patient_pair_stats(seq, dur, patient, mask, n_patients: int, n_pairs: int):
    """For each (patient, sequence-id) group: occurrence count, min/max
    duration.  Grouping key = (patient, rank of seq id); returns sorted keys
    plus stats aligned to the sorted layout.  Used by the Post-COVID rules
    ("occurs only once", "max duration spread < 2 buckets")."""
    seq = jnp.asarray(seq, jnp.int64).reshape(-1)
    dur = jnp.asarray(dur, jnp.int32).reshape(-1)
    patient = jnp.asarray(patient, jnp.int32).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    n = seq.shape[0]
    key = jnp.where(mask, seq, SENTINEL)
    # lexicographic (patient, seq) grouping; sentinel rows sort to the end
    pkey = jnp.where(mask, patient, jnp.int32(2**31 - 1))
    pkey, key, dur = jax.lax.sort((pkey, key, dur), num_keys=2)
    change = jnp.concatenate(
        [jnp.ones(1, bool), (key[1:] != key[:-1]) | (pkey[1:] != pkey[:-1])])
    seg = jnp.cumsum(change) - 1
    ones = jnp.where(key != SENTINEL, 1, 0).astype(jnp.int32)
    cnt = jax.ops.segment_sum(ones, seg, num_segments=n)
    dmin = jax.ops.segment_min(jnp.where(key != SENTINEL, dur, 2**31 - 1), seg, num_segments=n)
    dmax = jax.ops.segment_max(jnp.where(key != SENTINEL, dur, -1), seg, num_segments=n)
    return pkey, key, seg, cnt[seg], dmin[seg], dmax[seg], change

"""MSMR-lite: Minimize-Sparsity-Maximize-Relevance feature selection.

The MLHO vignette pipes screened sequences through MSMR (Estiri et al.):
sparsity screening, then (joint) mutual information against the label to
keep the top-K most relevant sequences.  This module builds the
patient x sequence feature matrix from mined ids and ranks features by
mutual information, with an optional greedy JMI pass.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import SENTINEL


class FeatureMatrix(NamedTuple):
    x: jax.Array          # [P, K] float32 binary presence
    feature_ids: jax.Array  # [K] int64 sequence ids (sentinel padded)
    n_features: jax.Array   # scalar


def top_sequences(u_ids, u_support, k: int):
    """Top-k unique sequence ids by support (host or device)."""
    u_ids = jnp.asarray(u_ids, jnp.int64)
    order = jnp.argsort(-jnp.where(u_ids != SENTINEL, u_support, -1))
    ids = u_ids[order][:k]
    return jnp.sort(ids)  # sorted for binary-search membership


@functools.partial(jax.jit, static_argnames=("n_patients",))
def feature_matrix(seq, patient, mask, feature_ids, n_patients: int) -> FeatureMatrix:
    """Binary presence matrix via binary search into sorted feature ids."""
    seq = jnp.asarray(seq, jnp.int64).reshape(-1)
    patient = jnp.asarray(patient, jnp.int32).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    k = feature_ids.shape[0]
    idx = jnp.clip(jnp.searchsorted(feature_ids, seq), 0, k - 1)
    hit = (feature_ids[idx] == seq) & mask & (seq != SENTINEL)
    x = jnp.zeros((n_patients, k), jnp.float32)
    x = x.at[patient, idx].max(hit.astype(jnp.float32))
    return FeatureMatrix(x, feature_ids, jnp.sum(feature_ids != SENTINEL))


def _mi_binary(x, y):
    """MI(feature; label) for binary feature columns x [P, K], labels y [P]."""
    y = y.astype(jnp.float32)[:, None]
    p = x.shape[0]
    eps = 1e-9
    p1 = x.mean(0)
    py = y.mean()
    p11 = (x * y).sum(0) / p
    mi = jnp.zeros(x.shape[1], jnp.float32)
    for xv in (0, 1):
        for yv in (0, 1):
            pxy = p11 if (xv, yv) == (1, 1) else None
            if (xv, yv) == (1, 0):
                pxy = p1 - p11
            elif (xv, yv) == (0, 1):
                pxy = py - p11
            elif (xv, yv) == (0, 0):
                pxy = 1 - p1 - py + p11
            px = p1 if xv else 1 - p1
            pyv = py if yv else 1 - py
            mi += pxy * (jnp.log(pxy + eps) - jnp.log(px + eps) - jnp.log(pyv + eps))
    return mi


@jax.jit
def mi_scores(x, y):
    return _mi_binary(jnp.asarray(x, jnp.float32), jnp.asarray(y))


def select_jmi(x, y, k: int) -> np.ndarray:
    """Greedy JMI: argmax_f sum_{s in S} I(f, s; y), seeded by max MI.

    Joint MI of a feature pair is computed on the 4-valued joint variable
    (2 bits).  Host-side loop (k is small, e.g. 200)."""
    x = np.asarray(x) > 0.5
    y = np.asarray(y) > 0.5
    P, K = x.shape
    k = min(k, K)
    base = np.asarray(mi_scores(x, y))
    selected = [int(np.argmax(base))]
    scores = np.zeros(K)
    for _ in range(k - 1):
        s = x[:, selected[-1]]
        joint = x.astype(np.int8) * 2 + s[:, None]  # [P, K] in {0..3}
        for v in range(4):
            xv = joint == v
            pv = xv.mean(0)
            p1 = (xv & y[:, None]).mean(0)
            p0 = pv - p1
            py = y.mean()
            eps = 1e-12
            scores += p1 * (np.log(p1 + eps) - np.log(pv + eps) - np.log(py + eps))
            scores += p0 * (np.log(p0 + eps) - np.log(pv + eps) - np.log(1 - py + eps))
        masked = scores.copy()
        masked[selected] = -np.inf
        selected.append(int(np.argmax(masked)))
    return np.asarray(selected, np.int32)

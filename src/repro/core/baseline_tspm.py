"""Faithful re-implementation of the ORIGINAL tSPM algorithm (the baseline).

The paper benchmarks tSPM+ against Estiri et al.'s original R implementation:
row-wise iteration, *string* sequence representations, and a dictionary-based
sparsity screen.  We reproduce that computational shape in pure Python/numpy
(no vectorization of the pair loop, string keys — deliberately slow) so the
comparison benchmark (paper Table 1) measures the same algorithmic gap, and
so tests have an independent oracle.

Pseudocode (paper Fig. 1):
    sort(dbmart, by(patient_num, date))
    for all patient p:    for all phenx x in p:    for all y with y.date>=x.date:
        sparseSequences.add(createSequence(x, y))
    nonSparseSequences = sparsityScreen(sparseSequences)
"""
from __future__ import annotations

from collections import defaultdict

from repro.data.dbmart import DBMart


def mine_strings(db: DBMart):
    """Original tSPM: list of (patient, 'start-end' string, duration)."""
    out = []
    vocab = db.vocab
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        for i in range(n):
            xi = int(db.phenx[p, i])
            di = int(db.date[p, i])
            si = vocab.phenx_strings[xi] if vocab else str(xi)
            for j in range(i + 1, n):
                xj = int(db.phenx[p, j])
                sj = vocab.phenx_strings[xj] if vocab else str(xj)
                out.append((p, si + "-" + sj, int(db.date[p, j]) - di))
    return out


def sparsity_screen(rows, threshold: int):
    """Dictionary-based distinct-patient support screen on string rows."""
    patients = defaultdict(set)
    for p, s, _ in rows:
        patients[s].add(p)
    return [r for r in rows if len(patients[r[1]]) >= threshold]


def mine_and_screen(db: DBMart, threshold: int | None = None):
    rows = mine_strings(db)
    if threshold is not None:
        rows = sparsity_screen(rows, threshold)
    return rows

"""Adaptive dbmart partitioning + file-based mining (paper's two modes).

The R package "split[s] the dbmart in chunks with an adaptive size to fit
the available memory limitations", and the C++ library has a *file-based*
mode that spills per-patient sequence files.  Here the same two ideas govern
HBM instead of RAM:

  * ``plan_chunks`` — greedy patient ranges such that the mining working set
    ``P_chunk * E_chunk^2 * BYTES_PER_PAIR`` fits the byte budget;
    per-chunk ``E`` adapts to the longest patient in the chunk (padded to a
    tile multiple), so short-history chunks pack many more patients.
  * ``mine_chunked`` — in-memory mode: mine chunk-by-chunk, merge on host.
  * ``mine_to_files`` / ``screen_files`` — file-based mode: spill each
    chunk's packed sequences to ``.npz`` and stream them back for a global
    hash-count screen (counts merge across chunks exactly like the psum in
    the distributed screen).

Chunked == unchunked is property-tested.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterable

import numpy as np

from repro.core import mining, sparsity
from repro.data.dbmart import DBMart

# dense pair tile: 8B seq + 4B dur + 1B mask, x2 for sort scratch
BYTES_PER_PAIR = 26


@dataclasses.dataclass(frozen=True)
class Chunk:
    start: int
    stop: int
    max_events: int

    @property
    def n_patients(self) -> int:
        return self.stop - self.start


def plan_chunks(nevents: np.ndarray, budget_bytes: int,
                pad_multiple: int = 8, layout: str = "triangular") -> list[Chunk]:
    """Greedy adaptive partitioning under a working-set byte budget."""
    chunks: list[Chunk] = []
    P = len(nevents)
    factor = 0.5 if layout == "triangular" else 1.0
    i = 0
    while i < P:
        e = max(int(nevents[i]), 1)
        e = -(-e // pad_multiple) * pad_multiple
        j = i + 1
        while j < P:
            e2 = max(e, -(-max(int(nevents[j]), 1) // pad_multiple) * pad_multiple)
            cost = (j + 1 - i) * e2 * e2 * BYTES_PER_PAIR * factor
            if cost > budget_bytes and j > i:
                break
            e = e2
            j += 1
        if (j - i) * e * e * BYTES_PER_PAIR * factor > budget_bytes and j - i > 1:
            j -= 1
            e = max(1, -(-int(max(nevents[i:j], default=1)) // pad_multiple) * pad_multiple)
        chunks.append(Chunk(i, j, e))
        i = j
    return chunks


def mine_chunked(db: DBMart, budget_bytes: int = 1 << 28, threshold: int | None = None,
                 codec: str = "bit", backend: str = "jnp",
                 n_buckets_log2: int = 22, fuse_duration: bool = False,
                 bucket_days: int = 30, with_counts: bool = False) -> dict:
    """In-memory chunked mining (+ optional global hash screen).

    Returns flat numpy arrays {seq, dur, patient, mask} over all chunks
    (concatenated; masks mark real pairs), plus 'keep' when screening and
    'counts' (the merged bucket table) when screening or ``with_counts``.
    """
    chunks = plan_chunks(np.asarray(db.nevents), budget_bytes)
    parts = []
    counts = None
    for ch in chunks:
        sub = db.slice_patients(ch.start, ch.stop, ch.max_events)
        mined = mining.mine(sub.phenx, sub.date, sub.nevents, codec=codec,
                            fuse_duration=fuse_duration,
                            bucket_days=bucket_days, backend=backend)
        if threshold is not None or with_counts:
            c = sparsity.local_bucket_counts(mined.seq, mined.mask, n_buckets_log2)
            counts = c if counts is None else sparsity.merge_bucket_counts(counts, c)
        seq, dur, pat, msk = mining.flatten(mined, patient_offset=ch.start)
        parts.append((np.asarray(seq), np.asarray(dur), np.asarray(pat),
                      np.asarray(msk)))
    out = {
        "seq": np.concatenate([p[0] for p in parts]),
        "dur": np.concatenate([p[1] for p in parts]),
        "patient": np.concatenate([p[2] for p in parts]),
        "mask": np.concatenate([p[3] for p in parts]),
    }
    if counts is not None:
        out["counts"] = np.asarray(counts)
    if threshold is not None:
        keep = sparsity.screen_hash_from_counts(
            out["seq"], out["mask"], np.asarray(counts), threshold, n_buckets_log2)
        out["keep"] = np.asarray(keep)
    return out


def mine_fused(db: DBMart, threshold: int, budget_bytes: int = 1 << 28,
               codec: str = "bit", backend: str = "jnp",
               n_buckets_log2: int = 20, fuse_duration: bool = False,
               bucket_days: int = 30) -> dict:
    """Screen-then-materialize: corpus-free counting, survivors-only pairs.

    Pass 1 builds the global [2^H] bucket table with the fused mine+screen
    kernel (``kernels/tspm_fused``) — no [P, n, n] corpus exists during the
    screen.  Pass 2 re-mines chunk-by-chunk under ``budget_bytes`` and
    compacts each chunk straight to its hash-screen survivors, so the only
    pair allocations are one chunk slab at a time plus the survivors
    themselves.  Byte-identical to mine + hash screen (keeping is per-id,
    so supports and canonical order are preserved).

    Returns compacted numpy {seq, dur, patient} (every row real) plus the
    global 'counts' table.
    """
    from repro.kernels.tspm_fused import ops as fused_ops

    counts = np.asarray(fused_ops.fused_bucket_counts(
        db.phenx, db.date, db.nevents, codec=codec,
        fuse_duration=fuse_duration, bucket_days=bucket_days,
        n_buckets_log2=n_buckets_log2, backend=backend))
    chunks = plan_chunks(np.asarray(db.nevents), budget_bytes)
    parts = []
    for ch in chunks:
        sub = db.slice_patients(ch.start, ch.stop, ch.max_events)
        mined = mining.mine(sub.phenx, sub.date, sub.nevents, codec=codec,
                            fuse_duration=fuse_duration,
                            bucket_days=bucket_days, backend=backend)
        seq, dur, pat, msk = mining.flatten(mined, patient_offset=ch.start)
        parts.append(sparsity.screen_survivors(
            seq, dur, pat, counts, threshold, n_buckets_log2, mask=msk))
    cat = lambda k, dt: (np.concatenate([p[k] for p in parts]) if parts
                         else np.zeros(0, dt))
    return {"seq": cat(0, np.int64), "dur": cat(1, np.int32),
            "patient": cat(2, np.int32), "counts": counts}


def mine_to_files(db: DBMart, out_dir: str, budget_bytes: int = 1 << 28,
                  codec: str = "bit", backend: str = "jnp",
                  n_buckets_log2: int = 22, fuse_duration: bool = False,
                  bucket_days: int = 30) -> list[str]:
    """File-based mode: one .npz per chunk + a merged bucket-count table."""
    os.makedirs(out_dir, exist_ok=True)
    for name in os.listdir(out_dir):   # stale spill from a previous cohort
        if name.startswith("chunk_") or name == "bucket_counts.npy":
            os.remove(os.path.join(out_dir, name))
    chunks = plan_chunks(np.asarray(db.nevents), budget_bytes)
    paths = []
    counts = None
    for k, ch in enumerate(chunks):
        sub = db.slice_patients(ch.start, ch.stop, ch.max_events)
        mined = mining.mine(sub.phenx, sub.date, sub.nevents, codec=codec,
                            fuse_duration=fuse_duration,
                            bucket_days=bucket_days, backend=backend)
        c = sparsity.local_bucket_counts(mined.seq, mined.mask, n_buckets_log2)
        counts = c if counts is None else sparsity.merge_bucket_counts(counts, c)
        seq, dur, pat, msk = mining.flatten(mined, patient_offset=ch.start)
        path = os.path.join(out_dir, f"chunk_{k:05d}.npz")
        # compact before spilling: only real pairs hit the disk
        msk = np.asarray(msk)
        np.savez(path, seq=np.asarray(seq)[msk], dur=np.asarray(dur)[msk],
                 patient=np.asarray(pat)[msk])
        paths.append(path)
    np.save(os.path.join(out_dir, "bucket_counts.npy"), np.asarray(counts))
    return paths


def load_files(out_dir: str) -> dict:
    """Read a spill directory back unscreened: flat compacted {seq, dur,
    patient} arrays (every row real — spills drop padding) + the merged
    'counts' table.  The screening twin of this loader is
    :func:`screen_files`; the API façade's file engine uses this one so a
    threshold can still be applied (and re-applied) lazily."""
    counts = np.load(os.path.join(out_dir, "bucket_counts.npy"))
    seq, dur, pat = [], [], []
    for name in sorted(os.listdir(out_dir)):
        if not name.startswith("chunk_"):
            continue
        z = np.load(os.path.join(out_dir, name))
        seq.append(z["seq"])
        dur.append(z["dur"])
        pat.append(z["patient"])
    cat = lambda parts, dt: (np.concatenate(parts) if parts
                             else np.zeros(0, dt))
    return {"seq": cat(seq, np.int64), "dur": cat(dur, np.int32),
            "patient": cat(pat, np.int32), "counts": counts}


def screen_files(out_dir: str, threshold: int,
                 n_buckets_log2: int = 22) -> Iterable[dict]:
    """Stream chunks back, applying the merged global count table."""
    counts = np.load(os.path.join(out_dir, "bucket_counts.npy"))
    for name in sorted(os.listdir(out_dir)):
        if not name.startswith("chunk_"):
            continue
        z = np.load(os.path.join(out_dir, name))
        seq = z["seq"]
        keep = np.asarray(sparsity.screen_hash_from_counts(
            seq, np.ones(seq.shape, bool), counts, threshold, n_buckets_log2))
        yield {"seq": seq[keep], "dur": z["dur"][keep],
               "patient": z["patient"][keep]}

"""Sparsity screening — sort-based (paper-faithful) and hash-based (scalable).

The paper screens sequences by *patient support*: a sequence is sparse when
it occurs for fewer than ``threshold`` distinct patients.  Its C++ recipe:

  1. parallel-sort all sequences by id (ips4o);
  2. linear pass: run boundaries -> per-sequence patient counts;
  3. mark sparse entries by writing UINT_MAX into the key;
  4. one more sort; truncate at the first sentinel.

``screen_sorted`` is the exact TPU port of that recipe (lax.sort +
shifted-compare + segment_sum + sentinel re-sort; static shapes, so
"truncate" returns a valid-prefix length instead of shrinking).

``screen_hash`` is the *beyond-paper distributed* variant: per-patient
dedupe, multiply-shift hash into 2^H buckets, scatter-add, one psum over the
patient-sharded mesh axes.  Collisions merge counts, so the error is
one-sided — a sparse sequence may survive, a non-sparse one is NEVER
dropped (property-tested).  This turns a global sort into one all-reduce.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import SENTINEL

# multiply-shift hash constant (odd; splitmix64's golden-gamma)
_HASH_K = jnp.int64(-7046029254386353131)  # == 0x9E3779B97F4A7C15 mod 2^64
# the same constant as an unsigned Python int, for host-side modular
# arithmetic (kernels/tspm_fused derives its limb-decomposed per-field
# hash constants from this; the two spellings must stay equal mod 2^64)
HASH_MULT = 0x9E3779B97F4A7C15


class Screened(NamedTuple):
    """Sort-compacted screening result (paper's post-truncate layout).

    Arrays are full length; the first ``n_kept`` entries are the surviving
    sequences in sorted-id order, the rest carry the SENTINEL key."""

    seq: jax.Array      # [N] int64, sorted, kept-prefix
    dur: jax.Array      # [N] int32
    patient: jax.Array  # [N] int32
    support: jax.Array  # [N] int32 distinct-patient support (0 on sentinel)
    n_kept: jax.Array   # scalar int64


def _run_flags(keys, patients):
    """(new-sequence, new-(sequence,patient)) flags on sorted arrays."""
    seq_change = jnp.concatenate(
        [jnp.ones(1, bool), keys[1:] != keys[:-1]])
    pat_change = jnp.concatenate(
        [jnp.ones(1, bool), (patients[1:] != patients[:-1])]) | seq_change
    return seq_change, pat_change


@functools.partial(jax.jit, static_argnames=())
def support_counts(seq, patient, mask):
    """Distinct-patient support per element + unique table.

    Returns (sorted keys, sorted patients, per-element support, unique ids
    (sentinel-padded, sorted, compacted to front), unique supports,
    n_unique).
    """
    seq = jnp.asarray(seq, jnp.int64).reshape(-1)
    patient = jnp.asarray(patient, jnp.int32).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    n = seq.shape[0]
    keys = jnp.where(mask, seq, SENTINEL)
    keys, patient = jax.lax.sort((keys, patient), num_keys=2)
    seq_change, pat_change = _run_flags(keys, patient)
    seg = jnp.cumsum(seq_change) - 1
    seg_support = jax.ops.segment_sum(
        pat_change.astype(jnp.int32), seg, num_segments=n)
    support = jnp.where(keys != SENTINEL, seg_support[seg], 0)
    first = seq_change & (keys != SENTINEL)
    u_key = jnp.where(first, keys, SENTINEL)
    u_key, u_support = jax.lax.sort(
        (u_key, jnp.where(first, support, 0)), num_keys=1)
    return keys, patient, support, u_key, u_support, jnp.sum(first)


@functools.partial(jax.jit, static_argnames=())
def screen_sorted(seq, dur, patient, mask, threshold) -> Screened:
    """Paper-faithful sort/mark/re-sort/truncate sparsity screen (exact)."""
    seq = jnp.asarray(seq, jnp.int64).reshape(-1)
    dur = jnp.asarray(dur, jnp.int32).reshape(-1)
    patient = jnp.asarray(patient, jnp.int32).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    n = seq.shape[0]

    keys = jnp.where(mask, seq, SENTINEL)
    keys, patient, dur = jax.lax.sort((keys, patient, dur), num_keys=2)
    seq_change, pat_change = _run_flags(keys, patient)
    seg = jnp.cumsum(seq_change) - 1
    seg_support = jax.ops.segment_sum(
        pat_change.astype(jnp.int32), seg, num_segments=n)
    support = seg_support[seg]
    keep = (support >= threshold) & (keys != SENTINEL)

    # the paper's marking trick: sparse entries get the sentinel key, one
    # more sort pushes them to the tail, n_kept is the truncation point.
    marked = jnp.where(keep, keys, SENTINEL)
    marked, patient, dur, support = jax.lax.sort(
        (marked, patient, dur, jnp.where(keep, support, 0)), num_keys=2)
    return Screened(marked, dur, patient, support, jnp.sum(keep))


# --- hash-based distributed screen (beyond paper) ---------------------------
def hash_bucket(seq, n_buckets_log2: int):
    """Multiply-shift hash of int64 sequence ids into [0, 2^H)."""
    seq = jnp.asarray(seq, jnp.int64)
    h = (seq * _HASH_K) >> (64 - n_buckets_log2)
    return (h & ((1 << n_buckets_log2) - 1)).astype(jnp.int32)


def row_first_flags(sorted_rows):
    """First-occurrence flags on row-wise sorted sentinel-padded id rows —
    the per-patient dedup step shared by the batch screen and the streaming
    sketch (stream/counts), so their distinct-(patient, sequence) semantics
    cannot drift apart."""
    first = jnp.concatenate(
        [jnp.ones((sorted_rows.shape[0], 1), bool),
         sorted_rows[:, 1:] != sorted_rows[:, :-1]], axis=1)
    return first & (sorted_rows != SENTINEL)


def local_bucket_counts(seq, mask, n_buckets_log2: int):
    """Per-shard distinct-patient bucket counts for row-major [P, T] input.

    Rows are patients; dedupes (patient, sequence) by a row-wise sort before
    counting, matching the paper's distinct-patient support semantics.
    """
    seq = jnp.asarray(seq, jnp.int64)
    mask = jnp.asarray(mask, bool)
    P = seq.shape[0]
    flat = jnp.where(mask, seq, SENTINEL).reshape(P, -1)
    srt = jnp.sort(flat, axis=1)
    first = row_first_flags(srt)
    h = hash_bucket(srt, n_buckets_log2)
    counts = jnp.zeros(1 << n_buckets_log2, jnp.int32)
    return counts.at[h.reshape(-1)].add(first.reshape(-1).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("n_buckets_log2", "axis_names"))
def screen_hash(seq, mask, threshold, n_buckets_log2: int = 20,
                axis_names: tuple[str, ...] | None = None):
    """Keep-mask for [P, T] mined rows; one psum when patient-sharded.

    Inside shard_map pass ``axis_names`` (e.g. ('pod', 'data')) to reduce
    bucket counts over the patient-sharded axes.  One-sided error under
    collisions (false-keep only).
    """
    counts = local_bucket_counts(seq, mask, n_buckets_log2)
    if axis_names:
        counts = jax.lax.psum(counts, axis_names)
    keep = counts[hash_bucket(seq, n_buckets_log2)] >= threshold
    return keep & jnp.asarray(mask, bool)


def merge_bucket_counts(*counts):
    """Host-side merge of per-chunk bucket count arrays (chunked pipeline)."""
    out = counts[0]
    for c in counts[1:]:
        out = out + c
    return out


def screen_hash_from_counts(seq, mask, counts, threshold, n_buckets_log2: int):
    """Apply a pre-merged global bucket-count table to a chunk."""
    keep = counts[hash_bucket(seq, n_buckets_log2)] >= threshold
    return keep & jnp.asarray(mask, bool)


def screen_survivors(seq, dur, patient, counts, threshold,
                     n_buckets_log2: int, mask=None):
    """Host-compacted survivors of the hash screen (corpus-free path).

    The materialization half of ``screen="fused"``: given the global
    bucket-count table from the corpus-free counting pass, keep only the
    rows whose bucket clears ``threshold`` and compact them to numpy
    arrays.  Keeping is per-*id* (every row of a surviving id survives),
    so supports, re-screens and the canonical lexsort order of the
    compacted arrays are byte-identical to screening the materialized
    corpus with the same table.
    """
    seq = jnp.asarray(seq, jnp.int64).reshape(-1)
    if mask is None:
        mask = seq != SENTINEL
    else:
        mask = jnp.asarray(mask, bool).reshape(-1)
    keep = np.asarray(screen_hash_from_counts(
        seq, mask, jnp.asarray(counts), threshold, n_buckets_log2))
    return (np.asarray(seq)[keep],
            np.asarray(jnp.asarray(dur, jnp.int32).reshape(-1))[keep],
            np.asarray(jnp.asarray(patient, jnp.int32).reshape(-1))[keep])

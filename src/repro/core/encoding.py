"""Numeric encoding of dbmarts and 64-bit sequence packing (paper §Methods).

The paper dictionary-encodes phenX strings and patient ids to dense integers,
then packs a (start_phenx, end_phenx) pair into a single 64-bit integer:

  * ``paper`` codec — decimal shift: ``seq = start * 10**7 + end``
    (the paper appends the zero-padded 7-digit end code; vocab < 10**7).
  * ``bit`` codec (TPU-native default) — ``seq = (start << 24) | end``
    (vocab < 2**24; shifts are single VPU ops, no integer multiply, and the
    id space is larger).  See DESIGN.md §2.

Durations (days) are carried separately as int32 (paper default), and can be
*fused* into the low bits of the id with a bucketed bit-shift — the paper's
"cheap bitshift operations to shift the duration on the last bits of the
sequence" — which makes (sequence, duration-bucket) support counting a plain
64-bit key operation.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# --- codec constants -------------------------------------------------------
BIT_SHIFT = 24                      # bits for the end-phenX slot
BIT_MASK = (1 << BIT_SHIFT) - 1
PAPER_SHIFT = 10**7                 # the paper's 7-digit decimal shift
DUR_BITS = 15                       # fused-duration bucket bits (63-bit total)
DUR_MASK = (1 << DUR_BITS) - 1
MAX_BIT_VOCAB = 1 << BIT_SHIFT
MAX_PAPER_VOCAB = PAPER_SHIFT
SENTINEL = np.iinfo(np.int64).max   # the paper's UINT_MAX marking trick

CODECS = ("bit", "paper")


def _check_codec(codec: str) -> None:
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r}; expected one of {CODECS}")


# --- packing (jittable, int64) --------------------------------------------
def pack(start, end, codec: str = "bit"):
    """Pack (start, end) phenX ids into a single int64 sequence id."""
    _check_codec(codec)
    start = jnp.asarray(start, jnp.int64)
    end = jnp.asarray(end, jnp.int64)
    if codec == "bit":
        return (start << BIT_SHIFT) | end
    return start * PAPER_SHIFT + end


def unpack(seq, codec: str = "bit"):
    """Invert :func:`pack`; returns (start, end) as int32."""
    _check_codec(codec)
    seq = jnp.asarray(seq, jnp.int64)
    if codec == "bit":
        return (seq >> BIT_SHIFT).astype(jnp.int32), (seq & BIT_MASK).astype(jnp.int32)
    return (seq // PAPER_SHIFT).astype(jnp.int32), (seq % PAPER_SHIFT).astype(jnp.int32)


def fuse_duration(seq, dur_bucket):
    """Shift a bucketed duration into the low bits of the id (paper trick)."""
    seq = jnp.asarray(seq, jnp.int64)
    b = jnp.clip(jnp.asarray(dur_bucket, jnp.int64), 0, DUR_MASK)
    return (seq << DUR_BITS) | b


def split_duration(fused):
    fused = jnp.asarray(fused, jnp.int64)
    return fused >> DUR_BITS, (fused & DUR_MASK).astype(jnp.int32)


def bucket_duration(dur_days, bucket_days: int = 30):
    """Duration (days) -> coarse bucket id (default: ~months)."""
    d = jnp.asarray(dur_days, jnp.int32)
    return jnp.clip(d // jnp.int32(bucket_days), 0, DUR_MASK).astype(jnp.int32)


def max_vocab(codec: str = "bit") -> int:
    _check_codec(codec)
    return MAX_BIT_VOCAB if codec == "bit" else MAX_PAPER_VOCAB


# --- host-side lookup tables (paper: "requires lookup tables") -------------
@dataclasses.dataclass
class Vocab:
    """Bidirectional phenX / patient lookup tables (host-side, numpy)."""

    phenx_strings: list[str]
    patient_keys: list
    phenx_index: dict[str, int] = dataclasses.field(default_factory=dict)
    patient_index: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.phenx_index:
            self.phenx_index = {s: i for i, s in enumerate(self.phenx_strings)}
        if not self.patient_index:
            self.patient_index = {k: i for i, k in enumerate(self.patient_keys)}

    @property
    def n_phenx(self) -> int:
        return len(self.phenx_strings)

    @property
    def n_patients(self) -> int:
        return len(self.patient_keys)

    def decode_phenx(self, pid: int) -> str:
        return self.phenx_strings[int(pid)]

    def decode_sequence(self, seq_id: int, codec: str = "bit",
                        fused: bool = False) -> str:
        """Human-readable 'start -> end' (paper: reversible representation).

        ``fused`` strips a fused duration bucket first and appends it as
        ``[bucket k]`` — decoding a fused id raw would index garbage."""
        seq_id = np.int64(seq_id)
        bucket = None
        if fused:
            seq_id, b = split_duration(seq_id)
            bucket = int(b)
        s, e = unpack(seq_id, codec)
        text = f"{self.phenx_strings[int(s)]} -> {self.phenx_strings[int(e)]}"
        return text if bucket is None else f"{text} [bucket {bucket}]"


def build_vocab(patients: Sequence, phenx: Sequence[str]) -> Vocab:
    """Assign running numbers starting at 0 to unique phenX / patients.

    Matches the paper: ids are assigned in first-appearance order so the
    patient id doubles as an array index.
    """
    phenx_strings: list[str] = []
    phenx_index: dict[str, int] = {}
    patient_keys: list = []
    patient_index: dict = {}
    for p in patients:
        if p not in patient_index:
            patient_index[p] = len(patient_keys)
            patient_keys.append(p)
    for x in phenx:
        if x not in phenx_index:
            phenx_index[x] = len(phenx_strings)
            phenx_strings.append(x)
    return Vocab(phenx_strings, patient_keys, phenx_index, patient_index)


def encode_rows(
    patients: Sequence, dates: Sequence[int], phenx: Sequence[str], vocab: Vocab | None = None
):
    """Alphanumeric rows -> numeric (patient_id, date, phenx_id) arrays."""
    if vocab is None:
        vocab = build_vocab(patients, phenx)
    pid = np.fromiter((vocab.patient_index[p] for p in patients), np.int32, len(patients))
    xid = np.fromiter((vocab.phenx_index[x] for x in phenx), np.int32, len(phenx))
    return pid, np.asarray(dates, np.int32), xid, vocab

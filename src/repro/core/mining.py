"""Transitive sequence mining (the tSPM/tSPM+ core loop) in JAX.

For every patient, every ordered pair of events ``(i, j)`` with ``i < j`` in
(date-sorted) position order becomes one sequence:

    seq_id   = pack(phenx[i], phenx[j])       (64-bit, see encoding.py)
    duration = date[j] - date[i]              (days; >= 0 by the sort)

yielding exactly ``n(n-1)/2`` sequences per patient with ``n`` events —
the paper's count.  The C++ version grows thread-local vectors; on TPU the
output is a *statically shaped, masked* tensor instead (DESIGN.md §2):

  * ``mine_triangular`` — packed upper-triangular ``[P, T]``, T = E(E-1)/2
    (pure-jnp; memory-lean; what the chunker uses on host);
  * ``mine_dense`` — dense ``[P, E, E]`` tiles (what the Pallas kernel
    produces; MXU/VPU-friendly layout, masked below the diagonal).

``mine(...)`` dispatches to the Pallas kernel (kernels/tspm_pairgen) or the
jnp reference.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding


class Mined(NamedTuple):
    """Masked mined sequences.  ``seq`` is int64 (optionally duration-fused),
    ``dur`` int32 days, ``mask`` marks real (non-padding) pairs.
    Patient identity is the leading row index (+ chunk offset)."""

    seq: jax.Array   # [P, T] or [P, E, E] int64
    dur: jax.Array   # int32
    mask: jax.Array  # bool

    @property
    def n_mined(self):
        return jnp.sum(self.mask)


@functools.lru_cache(maxsize=64)
def pair_indices(E: int) -> tuple[np.ndarray, np.ndarray]:
    """Static upper-triangular (i, j) index pair table for E events."""
    i, j = np.triu_indices(E, k=1)
    return i.astype(np.int32), j.astype(np.int32)


def n_pairs(E: int) -> int:
    return E * (E - 1) // 2


def _fuse(seq, dur, fuse_duration: bool, bucket_days: int):
    if not fuse_duration:
        return seq
    return encoding.fuse_duration(seq, encoding.bucket_duration(dur, bucket_days))


@functools.partial(jax.jit, static_argnames=("codec", "fuse_duration", "bucket_days"))
def mine_triangular(
    phenx, date, nevents, codec: str = "bit",
    fuse_duration: bool = False, bucket_days: int = 30,
) -> Mined:
    """Pure-jnp reference mining to packed-triangular [P, T] layout."""
    phenx = jnp.asarray(phenx, jnp.int32)
    date = jnp.asarray(date, jnp.int32)
    nevents = jnp.asarray(nevents, jnp.int32)
    E = phenx.shape[-1]
    i_idx, j_idx = pair_indices(E)
    seq = encoding.pack(phenx[..., i_idx], phenx[..., j_idx], codec)
    dur = date[..., j_idx] - date[..., i_idx]
    mask = j_idx[None, :] < nevents[:, None]
    seq = _fuse(seq, dur, fuse_duration, bucket_days)
    return Mined(jnp.where(mask, seq, encoding.SENTINEL), dur * mask, mask)


@functools.partial(jax.jit, static_argnames=("codec", "fuse_duration", "bucket_days"))
def mine_dense(
    phenx, date, nevents, codec: str = "bit",
    fuse_duration: bool = False, bucket_days: int = 30,
) -> Mined:
    """Pure-jnp reference mining to dense [P, E, E] layout (kernel oracle)."""
    phenx = jnp.asarray(phenx, jnp.int32)
    date = jnp.asarray(date, jnp.int32)
    nevents = jnp.asarray(nevents, jnp.int32)
    E = phenx.shape[-1]
    seq = encoding.pack(phenx[:, :, None], phenx[:, None, :], codec)
    dur = date[:, None, :] - date[:, :, None]
    ar = jnp.arange(E, dtype=jnp.int32)
    upper = ar[:, None] < ar[None, :]
    mask = upper[None] & (ar[None, None, :] < nevents[:, None, None])
    seq = _fuse(seq, dur, fuse_duration, bucket_days)
    return Mined(jnp.where(mask, seq, encoding.SENTINEL), dur * mask, mask)


def mine(
    phenx, date, nevents, codec: str = "bit", fuse_duration: bool = False,
    bucket_days: int = 30, backend: str = "auto", interpret: bool | None = None,
) -> Mined:
    """Mine transitive sequences.  backend: 'kernel' | 'jnp' | 'auto'.

    'kernel' uses the Pallas pair-generation kernel (dense layout);
    'jnp' the packed-triangular reference.  'auto' uses the kernel on TPU
    and the reference elsewhere.
    """
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "jnp"
    if backend == "kernel":
        from repro.kernels.tspm_pairgen import ops as pairgen_ops

        return pairgen_ops.pairgen(
            phenx, date, nevents, codec=codec, fuse_duration=fuse_duration,
            bucket_days=bucket_days, interpret=interpret,
        )
    return mine_triangular(phenx, date, nevents, codec, fuse_duration, bucket_days)


def flatten(mined: Mined, patient_offset: int = 0):
    """[P, ...] masked layout -> flat (seq, dur, patient, mask) arrays."""
    P = mined.seq.shape[0]
    T = int(np.prod(mined.seq.shape[1:]))
    pat = jnp.broadcast_to(
        (jnp.arange(P, dtype=jnp.int32) + patient_offset)[:, None], (P, T)
    ).reshape(-1)
    return (
        mined.seq.reshape(-1),
        mined.dur.reshape(-1),
        pat,
        mined.mask.reshape(-1),
    )


def count_sequences(nevents) -> jax.Array:
    """Closed-form total: sum_p n_p (n_p - 1) / 2 (the paper's count)."""
    n = jnp.asarray(nevents, jnp.int64)
    return jnp.sum(n * (n - 1) // 2)

"""Post-COVID-19 (WHO definition) identification from mined sequences.

The paper's second vignette: a symptom is a Post-COVID-19 (PCC) symptom for
a patient when it (a) occurs after a COVID-19 infection, (b) is ongoing for
at least two months, and (c) cannot be explained by a competing cause.
The vignette implements this purely on transitive sequences + durations:

  1. candidate sequences = sequences starting with covid whose end phenX is
     in the transitive end-set of covid (queries.transitive_ends_with);
  2. per patient, drop candidates that occur only once or whose duration
     spread (max - min over occurrences) is below ~2 months;
  3. exclusion by correlation: for each remaining candidate end-phenX, look
     at *other* sequences ending in it; if some start phenX c is tightly
     aligned with the symptom run (same duration spread as the covid
     sequence — the vectorized proxy for the vignette's pairwise
     correlation on (sequence, duration-bucket) tuples), proximate
     (min duration <= proximity_days) and itself a point event (rare),
     the candidate is explained away and removed for that patient.

Deviation note (DESIGN.md §9): the vignette computes Pearson correlations
per (sequence, end-duration-bucket) tuple; with perfectly aligned runs the
correlation is 1 exactly when the duration *spreads* coincide, so we use
|spread_c - spread_covid| <= align_tol_days as the vectorizable criterion,
plus the significance guard (occurrence-count and proximity), which keeps
the rule exact on point-cause explanations and avoids per-triple host loops.

Everything is dense [P, V, V] tables built by scatter from the flat mined
arrays — V is the (small) phenX vocabulary of the cohort or the
candidate-restricted subset.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding


@dataclasses.dataclass
class PostCovidConfig:
    covid_id: int
    min_occurrences: int = 2        # rule: "occur only once" -> drop
    min_spread_days: int = 56       # WHO: ongoing for at least two months
    proximity_days: int = 30        # competing cause close to run start
    align_tol_days: int = 7         # spread-match tolerance (corr proxy)
    anchor_rate_min: float = 0.5    # cohort: cause anchors the run when
    anchor_support_min: int = 2     #   co-present (correlation+significance)
    assoc_ratio_min: float = 3.0    # cohort: run-rate ratio covid/non-covid
    assoc_support_min: int = 2      #   minimum run cases among covid patients


@functools.partial(jax.jit, static_argnames=("n_patients", "n_phenx", "codec"))
def pair_tables(seq, dur, patient, mask, n_patients: int, n_phenx: int,
                codec: str = "bit"):
    """Dense per-patient pair stats: count / dmin / dmax as [P, V, V]."""
    seq = jnp.asarray(seq, jnp.int64).reshape(-1)
    dur = jnp.asarray(dur, jnp.int32).reshape(-1)
    patient = jnp.asarray(patient, jnp.int32).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    s, e = encoding.unpack(seq, codec)
    s = jnp.where(mask, s, 0)
    e = jnp.where(mask, e, 0)
    p = jnp.where(mask, patient, 0)
    m = mask.astype(jnp.int32)
    big = jnp.int32(2**31 - 1)
    cnt = jnp.zeros((n_patients, n_phenx, n_phenx), jnp.int32).at[p, s, e].add(m)
    dmin = jnp.full((n_patients, n_phenx, n_phenx), big, jnp.int32).at[p, s, e].min(
        jnp.where(mask, dur, big))
    dmax = jnp.full((n_patients, n_phenx, n_phenx), -1, jnp.int32).at[p, s, e].max(
        jnp.where(mask, dur, -1))
    # masked lanes scatter neutral elements (0 / +inf / -1) -> no pollution
    return cnt, dmin, dmax


@functools.partial(jax.jit, static_argnames=("n_patients", "n_phenx"))
def occurrence_counts(phenx, nevents, n_patients: int, n_phenx: int):
    """[P, V] event occurrence counts from the padded dbmart."""
    phenx = jnp.asarray(phenx, jnp.int32)
    P, E = phenx.shape
    valid = jnp.arange(E, dtype=jnp.int32)[None, :] < jnp.asarray(nevents)[:, None]
    rows = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[:, None], (P, E))
    occ = jnp.zeros((n_patients, n_phenx), jnp.int32)
    return occ.at[rows, jnp.where(valid, phenx, 0)].add(valid.astype(jnp.int32))


def identify(seq, dur, patient, mask, phenx, nevents, cfg: PostCovidConfig,
             n_patients: int, n_phenx: int, codec: str = "bit"):
    """Returns (pcc [P, V] bool, candidates [P, V] bool)."""
    cnt, dmin, dmax = pair_tables(seq, dur, patient, mask, n_patients,
                                  n_phenx, codec)
    occ = occurrence_counts(phenx, nevents, n_patients, n_phenx)
    cv = cfg.covid_id
    spread = jnp.where(cnt >= 1, dmax - dmin, -1)

    covid_cnt = cnt[:, cv, :]                      # [P, V]
    covid_spread = spread[:, cv, :]
    # a persisting run: >= min_occurrences spanning >= ~2 months after covid
    has_run = (covid_cnt >= cfg.min_occurrences) & \
              (covid_spread >= cfg.min_spread_days)
    # new onset: an s->covid sequence proves s occurred BEFORE the infection
    # (WHO: PCC symptoms are new after infection) — mined for free.
    new_onset = cnt[:, :, cv] == 0                 # [P, V]

    # cohort-level relevance (the vignette's correlation "significance",
    # MSMR-style): persisting *runs* of the code must be covid-associated,
    # which screens out background care codes (labs, visits) that form late
    # runs in covid and non-covid patients alike.  Run presence for any
    # patient comes free from the s->s diagonal of the pair tables: the
    # spread of a code against itself is its overall date spread.
    has_covid = occ[:, cv] >= 1                    # [P]
    present = occ >= 1                             # [P, V]
    diag = jnp.arange(cnt.shape[1])
    self_spread = spread[:, diag, diag]            # [P, V]
    run_any = (occ >= cfg.min_occurrences) & \
              (self_spread >= cfg.min_spread_days)
    n_cov = jnp.maximum(jnp.sum(has_covid), 1)
    n_non = jnp.maximum(jnp.sum(~has_covid), 1)
    runs_cov = jnp.sum(run_any & has_covid[:, None], 0)
    rate_cov = runs_cov / n_cov
    rate_non = jnp.sum(run_any & ~has_covid[:, None], 0) / n_non
    covid_assoc = (rate_cov >= cfg.assoc_ratio_min * jnp.maximum(rate_non, 1e-9)) \
        & (runs_cov >= cfg.assoc_support_min)      # [V]

    candidates = has_run & new_onset & covid_assoc[None, :]

    # exclusion by competing cause: c anchors the run locally (proximate,
    # same occurrence count and duration spread as the covid sequence — the
    # vectorized stand-in for corr == 1 on aligned duration series) ...
    aligned = (jnp.abs(spread - covid_spread[:, None, :]) <= cfg.align_tol_days) \
        & (cnt == covid_cnt[:, None, :])
    proximate = (dmin >= 0) & (dmin <= cfg.proximity_days) & \
                (cnt >= cfg.min_occurrences)
    anchors = aligned & proximate                  # [P, Vc, Vs]
    V = cnt.shape[1]
    not_self = ~jnp.eye(V, dtype=bool)[None]       # c != s
    anchors &= not_self
    anchors = anchors.at[:, cv, :].set(False)      # c != covid
    # ... and does so consistently across the cohort wherever c co-occurs
    # with an s-run (the "high correlation and significance" criterion):
    co_present = present[:, :, None] & has_run[:, None, :]   # [P, Vc, Vs]
    n_co = jnp.sum(co_present, 0)                  # [Vc, Vs]
    n_anchor = jnp.sum(anchors & co_present, 0)
    cause_rate = n_anchor / jnp.maximum(n_co, 1)
    significant = (cause_rate >= cfg.anchor_rate_min) & \
                  (n_co >= cfg.anchor_support_min)
    excluded = jnp.any(anchors & significant[None], axis=1)  # [P, Vs]

    pcc = candidates & ~excluded
    pcc = pcc.at[:, cv].set(False)
    return pcc, candidates


def decode_symptoms(pcc: np.ndarray, vocab) -> list[set[str]]:
    """[P, V] bool -> per-patient human-readable symptom sets (paper: back
    to fully human readable via the lookup tables)."""
    out = []
    pcc = np.asarray(pcc)
    for p in range(pcc.shape[0]):
        out.append({vocab.phenx_strings[int(v)] for v in np.nonzero(pcc[p])[0]})
    return out

"""gemma2-27b [dense]: 46L d4608 32H (kv=16) d_ff=36864 vocab=256000 —
local/global alternating, softcaps, GeGLU [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
        head_dim=128, vocab_size=256_000, local_global=True,
        sliding_window=4096, attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, mlp_act="gelu", embed_scale=True,
        tie_embeddings=True, dtype="bfloat16", remat="dots",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256,
                          sliding_window=16, dtype="float32", remat="none",
                          fsdp=False)

"""Model / run configuration dataclasses (plain dataclasses, no deps)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | xlstm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None            # default d_model // n_heads

    # attention
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0              # glm4 rotates half the dims
    qkv_bias: bool = False                  # qwen1.5
    attn_softcap: float | None = None       # gemma2
    final_softcap: float | None = None      # gemma2
    sliding_window: int | None = None       # gemma2 local layers
    local_global: bool = False              # gemma2 alternating pattern
    attn_impl: str = "auto"                 # auto | flash | xla

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_interleave: int = 1                 # every k-th layer is MoE
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM / xLSTM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0
    ssm_chunk: int = 64
    slstm_every: int = 0                    # xlstm: 1-in-k blocks is sLSTM
    shared_attn_every: int = 0              # zamba2
    n_shared_attn_blocks: int = 2           # zamba2

    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # multimodal stubs (frontends provide precomputed embeddings)
    n_patches: int = 0
    frontend_dim: int = 0

    # distribution knobs (perf-iterated; see EXPERIMENTS.md §Perf)
    decode_kv_shard: str = "heads"          # heads | seq (flash-decode SP)
    tp_internals: bool = True               # TP block internals over 'model'
    moe_dispatch: str = "gspmd"             # gspmd | shard_map_ep
    sp_residual: bool = False               # Megatron-SP: seq-shard residual

    # numerics / execution
    mlp_act: str = "silu"                   # silu | gelu (gemma2)
    embed_scale: bool = False               # gemma2 scales by sqrt(d)
    post_norms: bool = False                # gemma2 post-block norms
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "float32"                  # activation/param dtype
    remat: str = "none"                     # none | dots | full
    fsdp: bool = True                       # shard params over the data axis
    subquadratic: bool = False              # may run long_500k decode

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# smoke-test shape (reduced configs, CPU)
SMOKE = ShapeConfig("smoke", 64, 2, "train")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True

"""deepseek-moe-16b [moe]: 28L d2048 16H (kv=16) vocab=102400,
2 shared + 64 routed top-6 fine-grained experts (d_ff_expert=1408)
[arXiv:2401.06066]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
        vocab_size=102_400, n_experts=64, n_shared_experts=2,
        experts_per_token=6, moe_d_ff=1408, moe_interleave=1,
        tie_embeddings=False, dtype="bfloat16", remat="dots",
        # §Perf iteration 3a: replicated-routing shard_map EP (local-slice
        # dispatch + one psum combine): t_coll 29.5s -> 3.1s
        moe_dispatch="shard_map_ep", decode_kv_shard="seq",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256, n_experts=8,
                          experts_per_token=2, moe_d_ff=32, dtype="float32",
                          remat="none", fsdp=False)

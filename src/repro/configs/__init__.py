"""Architecture config registry (``--arch <id>``)."""
from __future__ import annotations

import importlib

ARCHS = (
    "xlstm-125m",
    "deepseek-moe-16b",
    "llama4-maverick-400b-a17b",
    "gemma2-2b",
    "glm4-9b",
    "qwen1.5-110b",
    "gemma2-27b",
    "pixtral-12b",
    "seamless-m4t-large-v2",
    "zamba2-2.7b",
    "tspm-mlho",  # the paper's own downstream-classifier config
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str, reduced: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced() if reduced else mod.full()

"""pixtral-12b [vlm]: 40L d5120 32H (kv=8) d_ff=14336 vocab=131072 —
mistral-nemo decoder; the pixtral-ViT frontend is a STUB: input_specs()
provides precomputed patch embeddings [hf:mistralai/Pixtral-12B-2409]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
        head_dim=128, vocab_size=131_072, n_patches=1024, frontend_dim=1024,
        tie_embeddings=False, dtype="bfloat16", remat="dots",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256, n_patches=8,
                          frontend_dim=32, dtype="float32", remat="none",
                          fsdp=False)

"""zamba2-2.7b [hybrid]: 54L d2560 32H (kv=32) d_ff=10240 ssm_state=64 —
Mamba2 backbone + 2 weight-shared attention blocks (width 2*d = 5120,
32 heads x hd 160), every 6 layers [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
        vocab_size=32_000, ssm_state=64, ssm_heads=80, ssm_expand=2,
        ssm_chunk=128, shared_attn_every=6, n_shared_attn_blocks=2,
        subquadratic=True, tie_embeddings=True, dtype="bfloat16",
        remat="dots",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=4, d_model=32, n_heads=4, n_kv_heads=4,
                          d_ff=64, vocab_size=256, ssm_state=16, ssm_heads=4,
                          ssm_chunk=8, shared_attn_every=2, dtype="float32",
                          remat="none", fsdp=False)

"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H (kv=8) d_ff=8192
vocab=202048, 128 routed experts top-1 + 1 shared, MoE interleaved with
dense layers (step 2, as published) [hf:meta-llama/Llama-4-*]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        head_dim=128, vocab_size=202_048, n_experts=128, n_shared_experts=1,
        experts_per_token=1, moe_d_ff=8192, moe_interleave=2,
        tie_embeddings=False, dtype="bfloat16", remat="dots",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256, n_experts=8,
                          experts_per_token=1, moe_d_ff=64, dtype="float32",
                          remat="none", fsdp=False)

"""tspm-mlho: the paper's own downstream config — a compact dense LM
trained on tSPM+-mined clinical event streams (the MLHO-workflow model,
also the ~100M end-to-end training-driver config)."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="tspm-mlho", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
        vocab_size=4096, tie_embeddings=True, dtype="float32", remat="none",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, fsdp=False)

"""glm4-9b [dense]: 40L d4096 32H (kv=2) d_ff=13696 vocab=151552 —
partial RoPE (half dims), QKV bias, extreme GQA [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
        head_dim=128, vocab_size=151_552, rope_fraction=0.5, qkv_bias=True,
        tie_embeddings=False, dtype="bfloat16", remat="dots",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256,
                          dtype="float32", remat="none", fsdp=False)

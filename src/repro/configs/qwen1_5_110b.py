"""qwen1.5-110b [dense]: 80L d8192 64H (kv=8) d_ff=49152 vocab=152064 —
QKV bias [hf:Qwen/Qwen1.5-110B]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
        head_dim=128, vocab_size=152_064, qkv_bias=True,
        tie_embeddings=False, dtype="bfloat16", remat="dots",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256,
                          dtype="float32", remat="none", fsdp=False)

"""xlstm-125m [ssm]: 12L d768 4H (kv=4) d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517].  d_ff=0: expansion lives inside the blocks."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="xlstm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=50_304, ssm_expand=2, slstm_every=6, ssm_chunk=128,
        subquadratic=True, tie_embeddings=True, dtype="bfloat16",
        remat="dots",
        # §Perf iteration 2d: a 125M model must NOT be tensor-parallel on a
        # 256-chip pod — wide DP + shard_map'd sLSTM: frac 0.011 -> 0.556
        tp_internals=False, decode_kv_shard="seq",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=6, d_model=64, n_heads=4, slstm_every=3,
                          vocab_size=256, ssm_chunk=8, dtype="float32",
                          remat="none", fsdp=False)

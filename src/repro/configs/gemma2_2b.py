"""gemma2-2b [dense]: 26L d2304 8H (kv=4) d_ff=9216 vocab=256000 —
local(4096)/global alternating, attn softcap 50 / final softcap 30,
GeGLU, post-norms, scaled tied embeddings [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
        head_dim=256, vocab_size=256_000, local_global=True,
        sliding_window=4096, attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, mlp_act="gelu", embed_scale=True,
        tie_embeddings=True, dtype="bfloat16", remat="dots",
        # §Perf iteration 1: sequence-sharded KV cache (flash-decode):
        # decode collective bytes 14.7GiB -> 48MiB per device per step
        decode_kv_shard="seq",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256,
                          sliding_window=16, dtype="float32", remat="none",
                          fsdp=False)

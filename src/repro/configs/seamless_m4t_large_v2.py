"""seamless-m4t-large-v2 [audio]: enc-dec, 24L (24 enc + 24 dec), d1024
16H (kv=16) d_ff=8192 vocab=256206 — the speech frontend is a STUB:
input_specs() provides precomputed frame embeddings [arXiv:2308.11596]."""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=48, n_enc_layers=24, n_dec_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
        vocab_size=256_206, tie_embeddings=True, dtype="bfloat16",
        remat="dots",
    )


def reduced() -> ModelConfig:
    return full().replace(n_layers=4, n_enc_layers=2, n_dec_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                          vocab_size=256, dtype="float32", remat="none",
                          fsdp=False)

"""TickJournal: the append-only, hash-chained session journal.

A :class:`TickJournal` subscribes to a service's typed event stream
(``repro.stream.events``) and appends one entry per event — submitted
deltas, completed ticks (with a digest of the tick's mined delta feed),
evictions/demotions, migrations (full patient state for external
admits, a content digest for internal moves the replayer re-derives),
rebalances, and checkpoints — each chained by
``h_i = sha256(h_{i-1} || entry)``.  Every ``commit_every`` ticks it
appends a merkle commitment over the corpus, sketch table, router pins
and pid table (:mod:`repro.journal.merkle`) and flushes.

Segments ride the :class:`~repro.storage.blockstore.CompressedBlockStore`
raw-blob API: each flush writes one crc-indexed segment blob
(``uvarint(count)`` then per entry ``uvarint(len) entry hash32``) under
an ordered key, with the store's atomic index giving the same
durability story as the disk residency tier.  ``root=None`` keeps the
journal in memory — the replay verifier runs one as the *shadow*
journal and compares its bytes against the recorded stream.

Re-attaching to an existing journal directory resumes the chain (the
open entry is only written once), so a checkpoint-restored session can
keep journaling into the same genesis-rooted log.
"""
from __future__ import annotations

import os

from repro import obs as obs_lib
from repro.journal import merkle
from repro.journal.entries import FORMAT_VERSION, GENESIS, Reader, \
    chain_hash, encode_entry, entry_kind, pack_state, state_digest, \
    uvarint, wave_digest
from repro.storage import codec as codec_lib
from repro.storage.blockstore import CompressedBlockStore
from repro.stream.events import CheckpointTaken, DeltaSubmitted, Evicted, \
    Migrated, Rebalanced, TickCompleted


def _seg_key(i: int) -> str:
    return f"jseg{i:08d}"


def parse_segment(blob: bytes) -> list[tuple[bytes, bytes]]:
    """One segment -> its [(entry_bytes, stored_hash)] list."""
    r = Reader(blob)
    n = r.uvarint()
    out = [(r.take(r.uvarint()), r.take(32)) for _ in range(n)]
    if not r.eof():
        raise ValueError("trailing bytes after segment entries")
    return out


def build_segment(entries: list[tuple[bytes, bytes]]) -> bytes:
    return b"".join([uvarint(len(entries))]
                    + [uvarint(len(e)) + e + h for e, h in entries])


class TornSegmentError(Exception):
    """A segment failed its crc or framing; carries everything readable
    before the tear so the verifier can name the tick."""

    def __init__(self, segment: str, entries_ok: list):
        super().__init__(f"journal segment {segment} is torn or corrupt")
        self.segment = segment
        self.entries_ok = entries_ok


def read_journal(root: str) -> list[tuple[bytes, bytes]]:
    """Every entry (with its stored chain hash) across all segments, in
    append order; raises :class:`TornSegmentError` on a bad segment."""
    store = CompressedBlockStore(root)
    try:
        out: list[tuple[bytes, bytes]] = []
        for key in sorted(k for k in store.keys()
                          if isinstance(k, str) and k.startswith("jseg")):
            try:
                out.extend(parse_segment(store.get_bytes(key)))
            except (IOError, ValueError, TypeError):
                raise TornSegmentError(key, out) from None
        return out
    finally:
        store.close()


def write_journal(root: str, entries: list[bytes]) -> None:
    """(Re)write a journal from raw entry bytes, re-deriving the chain —
    tooling for tests and repair, and the forge an *adversary* would
    use: a rewritten journal is internally consistent, so only replay
    (shadow-stream + commitment comparison) can catch it."""
    store = CompressedBlockStore(root)
    try:
        for key in list(store.keys()):
            if isinstance(key, str) and key.startswith("jseg"):
                store.discard(key)
        prev = GENESIS
        chained = []
        for e in entries:
            prev = chain_hash(prev, e)
            chained.append((e, prev))
        store.put_bytes(_seg_key(0), build_segment(chained))
    finally:
        store.close()


class TickJournal:
    """Writer (and tail reader) over one journal directory; see module
    docstring.  ``root=None`` -> in-memory (the verifier's shadow)."""

    def __init__(self, root: str | None = None, commit_every: int = 16,
                 telemetry=None):
        if commit_every < 1:
            raise ValueError("commit_every must be >= 1")
        self.root = root
        self.commit_every = commit_every
        self.obs = telemetry if telemetry is not None else obs_lib.NOOP
        self._store = (CompressedBlockStore(root)
                       if root is not None else None)
        #: in-memory mode keeps the full log; disk mode only the
        #: unflushed tail (segments are re-read on demand)
        self.log: list[tuple[bytes, bytes]] = []
        self._tail: list[tuple[bytes, bytes]] = []
        self._last_hash = GENESIS
        self._n_segments = 0
        self.n_entries = 0
        self.n_ticks = 0
        self.n_commits = 0
        #: merkle leaf caches keyed (shard, array) — valid while corpus
        #: logs only append; dropped on migration/rebalance (the only
        #: paths that shrink or reorder a shard's corpus)
        self._commit_caches: dict = {}
        m = self.obs.metrics
        self._m_entries = m.counter("journal.entries")
        self._m_commits = m.counter("journal.commits")
        self._m_bytes = m.counter("journal.bytes")
        if self._store is not None and len(self._store):
            for e, h in read_journal(root):
                self._account(entry_kind(e))
                self._last_hash = h
            self._n_segments = sum(
                1 for k in self._store.keys()
                if isinstance(k, str) and k.startswith("jseg"))

    def _account(self, kind: str) -> None:
        self.n_entries += 1
        if kind == "tick":
            self.n_ticks += 1
        elif kind == "commit":
            self.n_commits += 1

    # --- write side ---------------------------------------------------------
    def append(self, kind: str, fields: dict | None = None,
               arrays: dict | None = None,
               blobs: dict | None = None) -> bytes:
        entry = encode_entry(kind, fields, arrays, blobs)
        self._last_hash = chain_hash(self._last_hash, entry)
        rec = (entry, self._last_hash)
        if self._store is None:
            self.log.append(rec)
        else:
            self._tail.append(rec)
        self._account(kind)
        self._m_entries.inc()
        self._m_bytes.inc(len(entry))
        return entry

    def flush(self) -> None:
        """Seal the unflushed tail into one durable segment."""
        if self._store is None or not self._tail:
            return
        self._store.put_bytes(_seg_key(self._n_segments),
                              build_segment(self._tail))
        self._n_segments += 1
        self._tail = []

    def close(self) -> None:
        self.flush()
        if self._store is not None:
            self._store.close()
            self._store = None

    def entries(self) -> list[tuple[bytes, bytes]]:
        """The full (entry, hash) log, flushed segments included."""
        if self._store is None:
            return list(self.log)
        return read_journal(self.root) + list(self._tail)

    # --- event side ---------------------------------------------------------
    def attach(self, service, engine: str | None = None,
               config: dict | None = None) -> None:
        """Write the open entry (first attach only) and subscribe to the
        service's event stream.  The open entry freezes everything a
        replayer needs to rebuild the session: format version, engine,
        the full config dict, the commit cadence, and the router's
        initial pins (a pre-built balanced router is a runtime resource,
        not config)."""
        if self.n_entries == 0:
            router = getattr(service, "router", None)
            self.append("open", {
                "format": FORMAT_VERSION,
                "engine": engine or ("sharded" if hasattr(service, "shards")
                                     else "stream"),
                "commit_every": self.commit_every,
                "config": config or {},
                "router_pinned": [
                    [codec_lib.encode_key(k), int(s)]
                    for k, s in router.pinned.items()] if router else [],
            })
            self.flush()
        # isolate=False: a journal append failure must fail the tick —
        # an audit log that silently drops records is worse than no log
        service.subscribe(self.handle, isolate=False)

    def handle(self, ev) -> None:
        """One SessionEvent -> one (or two, at commit ticks) entries."""
        if isinstance(ev, DeltaSubmitted):
            # raw int32 arrays, not the varint codec: delta entries are
            # the journal's per-event hot path, and the pure-python
            # varint encoder alone costs more than the <5% overhead bar
            # (submit already normalized both arrays to int32)
            self.append("delta",
                        {"key": codec_lib.encode_key(ev.key),
                         "shard": ev.shard},
                        arrays={"dates": ev.dates, "phenx": ev.phenx})
        elif isinstance(ev, TickCompleted):
            self.append("tick", {
                "tick": int(ev.tick), "n": int(len(ev.seq)),
                "wave": wave_digest(ev.keys, ev.slot_idx, ev.seq, ev.dur)})
            if ev.tick % self.commit_every == 0:
                with self.obs.tracer.span("journal.commit", cat="host",
                                          tick=int(ev.tick)):
                    self.append("commit",
                                merkle.commitment(ev.service, ev.tick,
                                                  self._commit_caches))
                    self.flush()
                self._m_commits.inc()
        elif isinstance(ev, Evicted):
            self.append("evict", {
                "shard": ev.shard,
                "keys": [codec_lib.encode_key(k) for k in ev.keys],
                "demoted": [codec_lib.encode_key(k) for k in ev.demoted]})
        elif isinstance(ev, Migrated):
            self._commit_caches.clear()
            if ev.src is None:
                # external admit: the journal is the only place this
                # state exists, so it rides along in full
                fields, arrays = pack_state(ev.state)
                fields.update(src=None, dst=int(ev.dst),
                              digest=state_digest(ev.state))
                self.append("migrate", fields, arrays)
            else:
                self.append("migrate", {
                    "key": codec_lib.encode_key(ev.key),
                    "src": int(ev.src), "dst": int(ev.dst),
                    "digest": (state_digest(ev.state)
                               if ev.state is not None else None)})
        elif isinstance(ev, Rebalanced):
            self._commit_caches.clear()
            self.append("rebalance", {
                "moves": [[codec_lib.encode_key(k), int(a), int(b)]
                          for k, a, b in ev.moves]})
        elif isinstance(ev, CheckpointTaken):
            self.append("checkpoint", {"step": int(ev.step),
                                       "path": os.path.basename(ev.path)})
            self.flush()

"""Typed journal entries: a self-describing binary framing.

One entry = one session event (or journal bookkeeping record), framed
as::

    uvarint(len(header))  header-json  [uvarint(len(part)) part]*

The header is canonical JSON (sorted keys, no whitespace) carrying the
entry kind, its scalar fields, and descriptors for the binary parts
that follow — named arrays (raw little-endian bytes + dtype/shape;
int64 corpus ids round-trip exactly where the 35-bit zigzag-varint
codec could not, and raw ``tobytes`` keeps delta entries off the
pure-python varint encoder, whose cost alone would blow the journaling
overhead budget) and named opaque blobs.  Canonical framing matters
more than compactness: the hash chain and the replay shadow comparison
both operate on entry *bytes*, so two encodings of the same logical
entry must be byte-identical.

Patient keys serialize through ``storage.codec.encode_key`` (tagged
s-expressions), the same typed round-trip checkpoints use.
"""
from __future__ import annotations

import hashlib
import json
import struct

import numpy as np

from repro.storage import codec as codec_lib

#: journal format version (open-entry field; bump on framing changes)
FORMAT_VERSION = 1

#: chain genesis: the "previous hash" of the first entry
GENESIS = b"\x00" * 32

#: every entry kind, in a stable order
ENTRY_KINDS = ("open", "delta", "tick", "evict", "migrate", "rebalance",
               "checkpoint", "commit")

#: kinds the replay shadow stream must reproduce byte-for-byte; the
#: rest (open / rebalance / checkpoint) are session metadata — their
#: *effects* are already covered by the migrate/tick entries around them
REPLAYED_KINDS = frozenset({"delta", "tick", "evict", "migrate", "commit"})


def uvarint(n: int) -> bytes:
    """LEB128 length prefix (unsigned)."""
    if n < 0:
        raise ValueError("uvarint is unsigned")
    if n < 0x80:
        return bytes((n,))
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        out.append(b | (0x80 if n else 0))
        if not n:
            return bytes(out)


class Reader:
    """Cursor over one entry (or segment) buffer."""

    def __init__(self, buf: bytes, pos: int = 0):
        self.buf = buf
        self.pos = pos

    def eof(self) -> bool:
        return self.pos >= len(self.buf)

    def uvarint(self) -> int:
        n = shift = 0
        while True:
            if self.pos >= len(self.buf):
                raise ValueError("truncated uvarint")
            b = self.buf[self.pos]
            self.pos += 1
            n |= (b & 0x7F) << shift
            if not b & 0x80:
                return n
            shift += 7

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise ValueError("truncated entry payload")
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out


def encode_entry(kind: str, fields: dict | None = None,
                 arrays: dict | None = None,
                 blobs: dict | None = None) -> bytes:
    """Frame one entry (see module doc).  ``fields`` must be JSON-safe;
    binary parts are emitted in sorted-name order (canonical bytes)."""
    if kind not in ENTRY_KINDS:
        raise ValueError(f"unknown entry kind {kind!r}")
    arrays = arrays or {}
    blobs = blobs or {}
    hdr = {"k": kind, "f": fields or {}}
    parts: list[bytes] = []
    if arrays:
        hdr["a"] = []
        for name in sorted(arrays):
            arr = np.ascontiguousarray(arrays[name])
            hdr["a"].append([name, arr.dtype.str, list(arr.shape)])
            parts.append(arr.tobytes())
    if blobs:
        hdr["b"] = sorted(blobs)
        parts.extend(bytes(blobs[name]) for name in sorted(blobs))
    hj = json.dumps(hdr, sort_keys=True, separators=(",", ":")).encode()
    return b"".join([uvarint(len(hj)), hj]
                    + [uvarint(len(p)) + p for p in parts])


def decode_entry(buf: bytes) -> tuple[str, dict, dict, dict]:
    """Exact inverse of :func:`encode_entry` ->
    ``(kind, fields, arrays, blobs)``."""
    r = Reader(buf)
    hdr = json.loads(r.take(r.uvarint()))
    arrays: dict = {}
    for name, dtype, shape in hdr.get("a", []):
        raw = r.take(r.uvarint())
        arrays[name] = np.frombuffer(raw, dtype=np.dtype(dtype)) \
            .reshape(shape).copy()
    blobs = {name: r.take(r.uvarint()) for name in hdr.get("b", [])}
    if not r.eof():
        raise ValueError("trailing bytes after entry payload")
    return hdr["k"], hdr["f"], arrays, blobs


def entry_kind(buf: bytes) -> str:
    """Kind without decoding the payload."""
    r = Reader(buf)
    return json.loads(r.take(r.uvarint()))["k"]


def chain_hash(prev: bytes, entry: bytes) -> bytes:
    """``h_i = sha256(h_{i-1} || entry_bytes)`` — the append-only link."""
    return hashlib.sha256(prev + entry).digest()


# --- event payload helpers ---------------------------------------------------

def pack_state(state) -> tuple[dict, dict]:
    """A PatientState as (fields, arrays) — full fidelity, for external
    admits the replayer must reproduce from the journal alone."""
    return ({"key": codec_lib.encode_key(state.key)},
            {"phenx": np.asarray(state.phenx, np.int32),
             "date": np.asarray(state.date, np.int32),
             "seq_ids": np.asarray(state.seq_ids, np.int64),
             "corpus_seq": np.asarray(state.corpus_seq, np.int64),
             "corpus_dur": np.asarray(state.corpus_dur, np.int32)})


def unpack_state(fields: dict, arrays: dict):
    from repro.stream.service import PatientState
    return PatientState(
        codec_lib.decode_key(fields["key"]),
        np.asarray(arrays["phenx"], np.int32),
        np.asarray(arrays["date"], np.int32),
        np.asarray(arrays["seq_ids"], np.int64),
        np.asarray(arrays["corpus_seq"], np.int64),
        np.asarray(arrays["corpus_dur"], np.int32))


def state_digest(state) -> str:
    """Content digest of a PatientState — internal migrations journal
    this instead of the full payload (replay re-derives the state; the
    digest pins that it re-derived the *same* state)."""
    h = hashlib.sha256()
    h.update(json.dumps(codec_lib.encode_key(state.key)).encode())
    for name, dt in (("phenx", np.int32), ("date", np.int32),
                     ("seq_ids", np.int64), ("corpus_seq", np.int64),
                     ("corpus_dur", np.int32)):
        h.update(np.ascontiguousarray(
            getattr(state, name), dtype=dt).tobytes())
    return h.digest()[:16].hex()


#: golden-ratio / murmur-style odd constants for the vectorized fold
_K1 = np.uint64(0x9E3779B97F4A7C15)
_K2 = np.uint64(0xC2B2AE3D27D4EB4F)


def _fold64(arr) -> int:
    """Value-sensitive 64-bit fold of one integer array in three
    vectorized passes (wrapping uint64 arithmetic is deterministic).
    The fold is multiset-shaped — any changed *value* flips it w.h.p.;
    order sensitivity is the merkle commitment's job."""
    x = np.ascontiguousarray(arr, dtype=np.int64).view(np.uint64)
    with np.errstate(over="ignore"):
        acc = np.add.reduce((x ^ _K1) * _K2) if x.size else np.uint64(0)
        return int(acc ^ (np.uint64(x.size) * _K1))


def wave_digest(keys, slot_idx, seq, dur) -> str:
    """Digest of one tick's mined delta feed — the tick entry pins it so
    a divergent replay is caught *at the tick*, not at the next merkle
    commitment.

    The arrays fold through :func:`_fold64` rather than sha256: the
    verifier recomputes this digest from the journal's *delta entries*
    (the ground truth), so a forged journal must be internally
    consistent to pass — and an internally-consistent forgery is caught
    by the sha256 merkle commitment at the window boundary, or by the
    against-live comparison.  Collision resistance therefore buys
    nothing at the tick level; sensitivity does, and the vectorized
    fold keeps per-tick journaling off the mining hot path."""
    h = hashlib.sha256()
    for k in keys:
        h.update(json.dumps(codec_lib.encode_key(k)).encode())
        h.update(b"\x00")
    h.update(struct.pack("<QQQ", _fold64(slot_idx), _fold64(seq),
                         _fold64(dur)))
    return h.digest()[:16].hex()

"""Merkle commitments over live mining state.

A commit entry pins the whole session state at a tick boundary with a
handful of 32-byte roots: per-shard merkle roots over the mined corpus
and the sketch bucket table, plus digests of the router pins and the
global pid table.  Chunked leaves (64 KiB) keep the tree shape
deterministic and let a future fraud-proof protocol open a single chunk
instead of shipping the full table.

The corpus root combines three *per-array* roots (seq, dur, patient)
instead of hashing their concatenation: each array's byte stream is
append-only between commits, so a caller-held leaf cache makes the
commit cost O(new bytes), not O(corpus) — the difference between a
bounded audit tax and one that grows linearly with session age.  The
sketch table mutates in place every tick, so it is always rehashed
(it has a fixed size; the corpus does not).

Everything here is **mutation-free**: commitments read per-shard
snapshots (``StreamService.snapshot`` compacts the corpus log, which is
logically transparent) and never touch the sharded service's
whole-cohort paths — those flush pending migration admits, and a
*reader* advancing the migration schedule would make journaling itself
observable.  At commit time (inside a tick boundary) pending admits are
provably empty anyway — ``tick`` lands them before any wave — and the
commitment records the count to keep that assumption checked.
"""
from __future__ import annotations

import hashlib
import json

import numpy as np

from repro.storage import codec as codec_lib

#: merkle leaf width over array bytes
CHUNK_BYTES = 1 << 16


def _leaf(data) -> bytes:
    # sha256 everywhere (chain, tree, digests): one primitive to audit,
    # and openssl's SHA-NI path is ~2x blake2b on commit-sized tables
    h = hashlib.sha256(b"\x00")
    h.update(data)
    return h.digest()


def _node(left: bytes, right: bytes) -> bytes:
    return hashlib.sha256(b"\x01" + left + right).digest()


def merkle_root(data, cache: list | None = None) -> bytes:
    """Root over 64 KiB chunks (odd nodes promote a level unchanged).

    ``data`` is any bytes-like (a zero-copy memoryview works).  With
    ``cache`` (a list the *caller* owns), leaf hashes of full chunks are
    reused and extended in place; the caller guarantees the cached
    prefix of ``data`` is unchanged since the leaves were computed —
    appends only.  The trailing partial chunk is always rehashed and
    never cached."""
    n_full = len(data) // CHUNK_BYTES
    if cache is None:
        cache = []
    elif len(cache) > n_full:
        del cache[n_full:]
    for i in range(len(cache), n_full):
        cache.append(_leaf(data[i * CHUNK_BYTES:(i + 1) * CHUNK_BYTES]))
    level = list(cache)
    tail = data[n_full * CHUNK_BYTES:]
    if len(tail) or not level:
        level.append(_leaf(tail))
    while len(level) > 1:
        nxt = [_node(level[i], level[i + 1])
               for i in range(0, len(level) - 1, 2)]
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


def _keys_digest(pairs) -> str:
    """Digest over an iterable of (encoded-key-json-able, int) pairs in
    iteration order (dict insertion order is state here: pid numbering
    and router pins are both order-sensitive)."""
    h = hashlib.sha256()
    for k, v in pairs:
        h.update(json.dumps(codec_lib.encode_key(k)).encode())
        h.update(int(v).to_bytes(8, "little", signed=True))
    return h.digest()[:16].hex()


def _array_root(arr, dtype, cache: list | None) -> bytes:
    a = np.ascontiguousarray(arr, dtype)
    return merkle_root(memoryview(a).cast("B"), cache)


def commitment(service, tick: int, caches: dict | None = None) -> dict:
    """The commit-entry fields for a (sharded or single-shard) service.

    ``caches`` maps ``(shard_index, array_name)`` to a leaf-hash list
    (see :func:`merkle_root`); the owner must drop it whenever a shard's
    corpus log can shrink or reorder — patient migration and rebalance
    are the only such paths, and the journal observes both events."""
    shards = getattr(service, "shards", None) or [service]

    def cache_for(i, name):
        return None if caches is None else caches.setdefault((i, name), [])

    corpus, sketch = [], []
    for i, svc in enumerate(shards):
        snap = svc.snapshot()
        corpus.append(_node(
            _node(_array_root(snap.seq, np.int64, cache_for(i, "seq")),
                  _array_root(snap.dur, np.int32, cache_for(i, "dur"))),
            _array_root(snap.patient, np.int32,
                        cache_for(i, "patient"))).hex())
        sketch.append(_array_root(snap.counts, np.int32, None).hex())
    if hasattr(service, "router"):
        router = _keys_digest(service.router.pinned.items())
        pids = _keys_digest(service.pids.items())
        pending = sum(len(p) for p in service._pending_admits)
    else:
        router = ""
        pids = _keys_digest(service.store.pids.items())
        pending = 0
    return {"tick": int(tick), "corpus": corpus, "sketch": sketch,
            "router": router, "pids": pids, "pending": pending}

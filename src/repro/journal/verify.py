"""Journal verification: chain checks, byte-exact replay, fraud proofs.

Three layers, cheapest first:

  1. **structural** (:func:`verify_journal`) — segments parse (blockstore
     crc + framing), the hash chain holds, the journal opens with an
     open entry.  Catches torn storage and naive in-place tampering.
  2. **replay** (:func:`verify_replay`) — rebuild a fresh session from
     the open entry's config and re-apply every *command* entry (delta /
     tick / migrate).  A shadow in-memory :class:`TickJournal`
     subscribed to the replayed session re-derives the *effect* stream
     (evictions, tick wave digests, merkle commitments), which is
     compared byte-for-byte against the recorded one as replay
     progresses.  Catches semantic forgery — a re-chained journal whose
     entries are internally consistent but do not describe a run the
     engine would actually produce — and names the first divergent tick.
  3. **against a live session** (``MiningSession.verify``) — the
     replayed session's final corpus / sketch / router / pid state is
     compared with the live one, and a foreign journal is compared
     entry-by-entry with the session's own log to catch forks and
     truncations the replay alone cannot see.

Every failure is a typed :class:`FraudProof` carrying the first
divergent tick (1-based; ``tick=1`` means the journal diverges before
any tick completed) and the offending entry index.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.journal import entries as entries_lib
from repro.journal.entries import GENESIS, REPLAYED_KINDS, chain_hash, \
    decode_entry, entry_kind
from repro.journal.journal import TickJournal, TornSegmentError, read_journal
from repro.storage import codec as codec_lib


# --- fraud proofs ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FraudProof:
    """A verifiable claim that a journal is wrong, pinned to the first
    divergent tick and entry index (``index=-1``: past the last entry)."""

    tick: int
    index: int
    reason: str

    def __str__(self) -> str:
        return (f"{type(self).__name__}(tick={self.tick}, "
                f"entry={self.index}): {self.reason}")


class TornSegment(FraudProof):
    """A segment blob failed its crc or framing — storage-level damage."""


class ChainBreak(FraudProof):
    """An entry's stored hash does not extend the chain — in-place edit,
    reorder, or splice without re-deriving the chain."""


class Divergence(FraudProof):
    """Replay of the journal's own commands produces a different event
    stream (or final state) than the journal records — the journal
    describes a run the engine would not perform."""


class CommitmentMismatch(FraudProof):
    """A merkle commitment does not match the state replay reaches at
    that tick — corpus/sketch/router tampering with a re-chained log."""


class Truncated(FraudProof):
    """The journal ends before the events its own commands imply (or
    before the live session's log does) — a rollback fork."""


@dataclasses.dataclass(frozen=True)
class VerifyResult:
    """Outcome of a verification pass: ``ok`` + the first
    :class:`FraudProof` (or None), plus journal shape counters."""

    ok: bool
    proof: FraudProof | None
    n_entries: int
    n_ticks: int
    n_commits: int

    def __bool__(self) -> bool:
        return self.ok

    def __str__(self) -> str:
        if self.ok:
            return (f"VerifyResult(ok: {self.n_entries} entries, "
                    f"{self.n_ticks} ticks, {self.n_commits} commitments)")
        return f"VerifyResult(FAILED: {self.proof})"


def _fail(res: VerifyResult, proof: FraudProof) -> VerifyResult:
    return dataclasses.replace(res, ok=False, proof=proof)


# --- layer 1: structural -----------------------------------------------------

def _kind(e: bytes) -> str:
    """Entry kind, tolerant of corrupt bytes (a tampered entry may not
    even decode as JSON — the chain check still localizes it)."""
    try:
        return entry_kind(e)
    except Exception:
        return "?"


def verify_journal(root: str) -> VerifyResult:
    """Structural check (see module doc, layer 1).  Never replays."""
    try:
        entries = read_journal(root)
    except TornSegmentError as err:
        kinds = [_kind(e) for e, _ in err.entries_ok]
        return VerifyResult(
            False,
            TornSegment(tick=kinds.count("tick") + 1,
                        index=len(err.entries_ok),
                        reason=f"segment {err.segment!r} failed its "
                               "checksum or framing"),
            len(err.entries_ok), kinds.count("tick"), kinds.count("commit"))
    kinds = [_kind(e) for e, _ in entries]
    res = VerifyResult(True, None, len(entries), kinds.count("tick"),
                       kinds.count("commit"))
    prev = GENESIS
    for i, (e, h) in enumerate(entries):
        if chain_hash(prev, e) != h:
            return _fail(res, ChainBreak(
                tick=kinds[:i].count("tick") + 1, index=i,
                reason="stored hash does not extend the chain "
                       "(edited, reordered, or spliced entry)"))
        prev = h
    if not entries or kinds[0] != "open":
        return _fail(res, Truncated(
            tick=1, index=0, reason="journal does not start with an "
                                    "open entry"))
    return res


# --- layer 2: replay ---------------------------------------------------------

def _build_session(open_fields: dict, mesh=None, vocab=None):
    """A fresh MiningSession from an open entry: same config, forced
    engine, journaling/telemetry/auto-rebalance off (rebalance *moves*
    are journaled as migrate entries and re-applied directly — letting
    the replayed service re-trigger them would double them), router
    rebuilt from the journaled initial pins."""
    from repro.api.config import MiningConfig
    from repro.api.session import MiningSession
    from repro.stream.shard import ShardRouter
    cfg = dict(open_fields.get("config") or {})
    cfg.update(engine=open_fields["engine"], journal_dir=None,
               rebalance_every=None, busy_weighted_rebalance=False,
               telemetry=False, jax_annotations=False)
    config = MiningConfig(**cfg)
    router = None
    if open_fields["engine"] == "sharded":
        router = ShardRouter(config.n_shards, pinned={
            codec_lib.decode_key(k): int(s)
            for k, s in open_fields.get("router_pinned", [])})
    session = MiningSession(config, mesh=mesh, router=router, vocab=vocab)
    session._ensure_service()
    return session


def _apply(svc, kind: str, fields: dict, arrays: dict, blobs: dict) -> None:
    """Re-apply one command entry to the replayed service.  Effect
    entries (evict / commit) and metadata (rebalance / checkpoint) are
    not applied — the service re-derives the effects itself."""
    if kind == "delta":
        svc.submit(codec_lib.decode_key(fields["key"]),
                   arrays["dates"], arrays["phenx"])
    elif kind == "tick":
        svc.tick()
    elif kind == "migrate":
        if fields.get("src") is None:
            state = entries_lib.unpack_state(fields, arrays)
            if hasattr(svc, "shards"):
                svc.admit_patient(state, dst=int(fields["dst"]))
            else:
                svc.admit_patient(state)
        else:
            svc.migrate(codec_lib.decode_key(fields["key"]),
                        int(fields["dst"]))


def _replay(entries: list, upto_tick: int | None = None, mesh=None,
            vocab=None, shadow: TickJournal | None = None):
    """Core replay loop -> ``(session, proof_or_None)``.

    With a ``shadow`` journal the re-derived event stream is compared
    byte-for-byte against the recorded REPLAYED_KINDS entries as it
    grows.  The streams may transiently lead/lag each other inside one
    tick (the recorded evict/tick entries are read before the tick
    command is applied, the shadow's commit lands before the recorded
    one is read), so comparison only consumes the common prefix and the
    final drain settles the tails."""
    kinds = [entry_kind(e) for e, _ in entries]
    expected: list = []         # (entry index, entry bytes) to reproduce
    matched = 0                 # common prefix already compared
    session = None

    def mismatch(i: int) -> FraudProof:
        idx, want = expected[i]
        got = shadow.log[i][0]
        tick = kinds[:idx].count("tick") + 1
        a, b = entry_kind(want), entry_kind(got)
        if a == b == "commit":
            return CommitmentMismatch(
                tick=tick, index=idx,
                reason="recorded merkle commitment does not match the "
                       "state replay reaches at this tick")
        return Divergence(
            tick=tick, index=idx,
            reason=f"recorded {a!r} entry differs from the {b!r} entry "
                   "replay produces at this position")

    for idx, (e, _h) in enumerate(entries):
        kind, fields, arrays, blobs = decode_entry(e)
        if kind == "open":
            if session is not None:
                return session, Divergence(
                    tick=kinds[:idx].count("tick") + 1, index=idx,
                    reason="second open entry mid-journal")
            session = _build_session(fields, mesh=mesh, vocab=vocab)
            if shadow is not None:
                session.service.subscribe(shadow.handle, isolate=False)
            continue
        if session is None:
            return None, Truncated(
                tick=1, index=idx,
                reason=f"{kind!r} entry before any open entry")
        if kind == "tick" and upto_tick is not None \
                and int(fields["tick"]) > upto_tick:
            break
        if kind in REPLAYED_KINDS:
            expected.append((idx, e))
        _apply(session.service, kind, fields, arrays, blobs)
        if shadow is not None:
            while matched < min(len(expected), len(shadow.log)):
                if expected[matched][1] != shadow.log[matched][0]:
                    return session, mismatch(matched)
                matched += 1
    if shadow is not None:
        if len(shadow.log) > len(expected):
            k2 = [_kind(e) for _, e in expected]
            return session, Truncated(
                tick=k2.count("tick") + 1, index=-1,
                reason=f"replay produced {len(shadow.log) - len(expected)} "
                       "event(s) past the journal's end (rolled-back tail)")
        if len(expected) > len(shadow.log):
            idx = expected[len(shadow.log)][0]
            return session, Divergence(
                tick=kinds[:idx].count("tick") + 1, index=idx,
                reason="journal records events replay never produces")
    return session, None


def replay(root: str, upto_tick: int | None = None, *, mesh=None,
           vocab=None):
    """Reconstruct a fresh ``MiningSession`` from a journal directory by
    re-applying its command entries (optionally only through
    ``upto_tick``) — byte-identical to the recorded run's state at that
    point.  No verification beyond what replay inherently does; use
    :func:`verify_replay` for the full shadow-stream check."""
    session, proof = _replay(read_journal(root), upto_tick=upto_tick,
                             mesh=mesh, vocab=vocab)
    if proof is not None:
        raise ValueError(f"journal at {root!r} is not replayable: {proof}")
    return session


def verify_replay(root: str, *, mesh=None, vocab=None):
    """Layers 1 + 2 -> ``(VerifyResult, replayed session or None)``."""
    res = verify_journal(root)
    if not res.ok:
        return res, None
    entries = read_journal(root)
    open_fields = decode_entry(entries[0][0])[1]
    shadow = TickJournal(root=None,
                         commit_every=int(open_fields["commit_every"]))
    session, proof = _replay(entries, mesh=mesh, vocab=vocab, shadow=shadow)
    if proof is not None:
        return _fail(res, proof), session
    return res, session


# --- layer 3: against a live session -----------------------------------------

def compare_journals(reference: list, candidate: list) -> FraudProof | None:
    """Entry-by-entry comparison of a candidate journal against the
    reference (a live session's own log): forks and rollbacks that an
    internally-consistent journal hides from replay alone."""
    kinds = [_kind(e) for e, _ in reference]
    for i in range(min(len(reference), len(candidate))):
        if reference[i][0] != candidate[i][0]:
            return Divergence(
                tick=kinds[:i].count("tick") + 1, index=i,
                reason="journal forks from the live session's log")
    if len(candidate) < len(reference):
        return Truncated(
            tick=kinds[:len(candidate)].count("tick") + 1,
            index=len(candidate),
            reason=f"journal ends {len(reference) - len(candidate)} "
                   "entr(ies) before the live session's log")
    if len(candidate) > len(reference):
        return Divergence(
            tick=kinds.count("tick") + 1, index=len(reference),
            reason="journal extends past the live session's log")
    return None


def state_divergence(live_svc, replayed_svc, n_ticks: int) \
        -> FraudProof | None:
    """Final-state comparison (snapshot level, so pending migration
    admits land on both sides): corpus, sketch table, router pins, pid
    table.  A difference here with a clean entry stream means the live
    session mutated outside its journal."""
    a, b = live_svc.snapshot(), replayed_svc.snapshot()
    for name in ("seq", "dur", "patient", "counts"):
        if not np.array_equal(np.asarray(getattr(a, name)),
                              np.asarray(getattr(b, name))):
            return Divergence(
                tick=n_ticks, index=-1,
                reason=f"live session's {name} differs from replay at "
                       "the journal's end")
    sharded = hasattr(live_svc, "shards")
    live_pids = live_svc.pids if sharded else live_svc.store.pids
    rep_pids = replayed_svc.pids if sharded else replayed_svc.store.pids
    if dict(live_pids) != dict(rep_pids):
        return Divergence(tick=n_ticks, index=-1,
                          reason="live session's pid table differs from "
                                 "replay at the journal's end")
    if sharded and dict(live_svc.router.pinned) \
            != dict(replayed_svc.router.pinned):
        return Divergence(tick=n_ticks, index=-1,
                          reason="live session's router pins differ from "
                                 "replay at the journal's end")
    return None

"""Verifiable tick journal: hash-chained audit log + replay + fraud proofs.

Every mutation a mining session performs — submitted deltas, completed
ticks, evictions, migrations, rebalances, checkpoints — lands as one
typed entry in an append-only journal whose entries are chained by
``sha256(h_{i-1} || entry)`` and punctuated by merkle commitments over
the mined corpus, the support sketch, and the router state.  The
journal is *sufficient*: ``replay(journal_dir)`` reconstructs a fresh
session byte-identical to the recorded run, and ``verify_replay``
re-derives the whole effect stream through a shadow journal, producing
a typed :class:`~repro.journal.verify.FraudProof` naming the first
divergent tick for any tampered, forked, or truncated log.

  * ``entries`` — typed entry framing, hash chain, state digests;
  * ``merkle``  — chunked merkle commitments over live session state;
  * ``journal`` — :class:`TickJournal`: the subscriber/writer (segments
    ride the storage blockstore) and the segment reader;
  * ``verify``  — structural checks, byte-exact replay, fraud proofs.

Façade: ``MiningConfig(journal_dir=...)`` attaches a journal to any
streaming session; ``MiningSession.verify()`` / ``.replay()`` wrap the
functions here.
"""
from repro.journal import entries, merkle  # noqa: F401
from repro.journal.entries import FORMAT_VERSION, GENESIS  # noqa: F401
from repro.journal.journal import TickJournal, TornSegmentError, \
    read_journal, write_journal  # noqa: F401
from repro.journal.verify import ChainBreak, CommitmentMismatch, \
    Divergence, FraudProof, TornSegment, Truncated, VerifyResult, \
    compare_journals, replay, state_divergence, verify_journal, \
    verify_replay  # noqa: F401

"""Metrics registry: counters, gauges, exponential-bucket histograms.

The mining stack's quantitative claims (speedup, memory, O(log)
recompiles) need in-process measurement, not one-shot bench scripts; this
registry is the substrate.  Three metric kinds, all label-aware:

  * **Counter** — monotone accumulator (``inc``): ticks, events, pairs,
    evictions, migrations, jit retraces;
  * **Gauge** — last-value sample (``set``): queue depth, plane occupancy,
    resident bytes vs budget, sketch bucket load factor;
  * **Histogram** — exponential buckets (``observe``): tick latencies,
    where a mean hides the retrace spikes the geometric-growth policy is
    supposed to bound.

Hot-path contract: callers resolve metric objects **once** (construction
time) and call ``inc``/``set``/``observe`` per tick — no dict lookup, no
string formatting, no allocation on the instrumented path.  The same key
(name + labels) always resolves to the same object, so instrumentation in
two layers (service and its store) can share a counter.

Disabled telemetry swaps in :data:`NOOP_REGISTRY`, whose accessors return
one shared do-nothing metric (``__slots__ = ()``, methods are no-ops): an
uninstrumented and an instrumentation-disabled run execute the same
per-tick work minus three attribute calls.  Exactness is never at stake —
metrics only ever *read* host-side integers and floats.
"""
from __future__ import annotations

from bisect import bisect_left


def _key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _fmt_key(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone accumulator; ``inc(n)`` is the whole hot-path API."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Last-value sample; ``set(v)`` overwrites."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Exponential-bucket histogram.

    Bucket ``i`` covers ``(scale * base**(i-1), scale * base**i]`` with an
    underflow bucket below ``scale`` and an overflow bucket past the last
    boundary.  Defaults (``base=2, scale=1e-6, n_buckets=40``) span 1 us
    to ~12.7 days — one configuration covers tick latencies and whole-run
    walls.  ``observe`` is one ``bisect`` into a precomputed boundary
    list: O(log buckets), allocation-free.
    """

    __slots__ = ("bounds", "buckets", "count", "sum", "min", "max")

    def __init__(self, base: float = 2.0, scale: float = 1e-6,
                 n_buckets: int = 40):
        if base <= 1.0 or scale <= 0 or n_buckets < 1:
            raise ValueError("need base > 1, scale > 0, n_buckets >= 1")
        self.bounds = [scale * base ** i for i in range(n_buckets)]
        self.buckets = [0] * (n_buckets + 1)   # +1 overflow
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v) -> None:
        self.buckets[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v

    def summary(self) -> dict:
        out = {"count": self.count, "sum": self.sum,
               "min": self.min, "max": self.max, "buckets": {}}
        for i, n in enumerate(self.buckets):
            if n:
                le = (f"{self.bounds[i]:.3e}" if i < len(self.bounds)
                      else "+inf")
                out["buckets"][f"le={le}"] = n
        return out


class MetricsRegistry:
    """Name+labels -> metric object; one registry per telemetry session.

    The accessor for an existing key returns the *same* object (resolve
    once, mutate per tick); asking for the same key as a different kind is
    an error — a silent kind change would corrupt the snapshot.
    """

    enabled = True

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, kind, name: str, labels: dict, **kw):
        key = _key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = kind(**kw)
        elif not isinstance(m, kind):
            raise TypeError(f"metric {_fmt_key(key)} already registered "
                            f"as {type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, base: float = 2.0, scale: float = 1e-6,
                  n_buckets: int = 40, **labels) -> Histogram:
        return self._get(Histogram, name, labels, base=base, scale=scale,
                         n_buckets=n_buckets)

    def value(self, name: str, **labels):
        """Current value of a counter/gauge (KeyError if never created)."""
        return self._metrics[_key(name, labels)].value

    def snapshot(self) -> dict:
        """JSON-ready flat dict: ``name{label=v,...}`` -> value/summary."""
        out = {}
        for key in sorted(self._metrics, key=_fmt_key):
            m = self._metrics[key]
            out[_fmt_key(key)] = (m.summary() if isinstance(m, Histogram)
                                  else m.value)
        return out

    def reset(self) -> None:
        """Zero every metric in place (objects stay valid: cached
        references held by instrumented code keep working)."""
        for key, m in self._metrics.items():
            if isinstance(m, Histogram):
                m.buckets = [0] * len(m.buckets)
                m.count = 0
                m.sum = 0.0
                m.min = m.max = None
            else:
                m.value = 0


class _NoopMetric:
    """Shared do-nothing Counter/Gauge/Histogram stand-in."""

    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0
    min = None
    max = None

    def inc(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass

    def summary(self) -> dict:
        return {"count": 0, "sum": 0.0, "min": None, "max": None,
                "buckets": {}}


NOOP_METRIC = _NoopMetric()


class NoopRegistry:
    """Disabled registry: every accessor returns the one shared no-op
    metric; nothing is recorded, nothing is allocated per call."""

    __slots__ = ()
    enabled = False

    def counter(self, name: str, **labels) -> _NoopMetric:
        return NOOP_METRIC

    def gauge(self, name: str, **labels) -> _NoopMetric:
        return NOOP_METRIC

    def histogram(self, name: str, **labels) -> _NoopMetric:
        return NOOP_METRIC

    def value(self, name: str, **labels):
        return 0

    def snapshot(self) -> dict:
        return {}

    def reset(self) -> None:
        pass


NOOP_REGISTRY = NoopRegistry()

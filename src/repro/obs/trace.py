"""Span tracer: begin/finish span trees, JSON + Chrome-trace export.

Host walls lie under async dispatch: a sharded tick *begins* every
shard's wave before *collecting* any, so per-shard begin-to-finish
windows overlap and their sum exceeds real elapsed time
(``TickStats.wall_s``'s documented flaw).  Spans make the overlap
visible instead of silently double-counted: each instrumented region is
a ``(name, track, t0, t1)`` interval — ticks and migrations on a
sharded service emit *dispatch* (host wave assembly), *device*
(dispatch-end to completion-read; these overlap across shards under
device placement) and *collect* (host materialization) spans on a
per-shard track, so a Chrome-trace viewer shows the per-device rows
running concurrently.

Begin/finish are explicit (``begin`` returns the span; ``finish`` stamps
it) because async regions cross function boundaries — the dispatch side
opens the device span, the collect side closes it, possibly after other
shards' spans opened.  Synchronous regions use the ``span(...)`` context
manager.  Nesting is tracked per track: a span's parent is whatever span
was open on its track when it began, and out-of-order finishes are legal
(the open-stack removes by identity, not position).

Exports:

  * ``to_chrome_trace()`` — the Chrome trace-event JSON object
    (``chrome://tracing`` / Perfetto load it directly): one complete
    ("ph": "X") event per finished span, ``tid`` = track;
  * ``to_json()`` — the span forest as nested dicts (children inline),
    for programmatic assertions.

``jax_annotations=True`` additionally wraps every span in
``jax.profiler.TraceAnnotation`` (feature-detected; a no-op outside an
active ``jax.profiler.trace`` capture), so spans line up with XLA's own
timeline when profiling on a real accelerator.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager


class Span:
    """One timed interval; ``t1 is None`` while still open."""

    __slots__ = ("name", "cat", "track", "t0", "t1", "parent", "args",
                 "_annotation")

    def __init__(self, name, cat, track, t0, parent=None, args=None):
        self.name = name
        self.cat = cat
        self.track = track
        self.t0 = t0
        self.t1 = None
        self.parent = parent
        self.args = args or {}
        self._annotation = None

    @property
    def duration_s(self) -> float | None:
        return None if self.t1 is None else self.t1 - self.t0

    def __repr__(self):
        dur = self.duration_s
        return (f"Span({self.name!r}, track={self.track!r}, "
                f"dur={'open' if dur is None else f'{dur * 1e6:.0f}us'})")


def _jax_annotation(name: str):
    try:
        from jax.profiler import TraceAnnotation

        return TraceAnnotation(name)
    except Exception:
        return None


class SpanTracer:
    """Collects spans relative to a construction-time epoch."""

    enabled = True

    def __init__(self, jax_annotations: bool = False):
        self.epoch = time.perf_counter()
        self.jax_annotations = jax_annotations
        self.spans: list[Span] = []     # finished, finish order
        self._open: dict = {}           # track -> [open spans]

    def begin(self, name: str, cat: str = "host", track: str = "main",
              **args) -> Span:
        stack = self._open.setdefault(track, [])
        parent = stack[-1] if stack else None
        sp = Span(name, cat, track, time.perf_counter() - self.epoch,
                  parent=parent, args=args)
        if self.jax_annotations:
            ann = _jax_annotation(name)
            if ann is not None:
                ann.__enter__()
                sp._annotation = ann
        stack.append(sp)
        return sp

    def finish(self, span: Span, **args) -> Span:
        span.t1 = time.perf_counter() - self.epoch
        if args:
            span.args.update(args)
        if span._annotation is not None:
            span._annotation.__exit__(None, None, None)
            span._annotation = None
        stack = self._open.get(span.track)
        if stack is not None and span in stack:
            stack.remove(span)
        self.spans.append(span)
        return span

    @contextmanager
    def span(self, name: str, cat: str = "host", track: str = "main",
             **args):
        sp = self.begin(name, cat, track, **args)
        try:
            yield sp
        finally:
            self.finish(sp)

    def reset(self) -> None:
        self.spans = []
        self._open = {}
        self.epoch = time.perf_counter()

    # --- export -------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome trace-event format (load in chrome://tracing/Perfetto).

        Tracks map to ``tid`` (sorted name order), so each shard renders
        as its own row; timestamps are microseconds since the epoch."""
        tracks = sorted({sp.track for sp in self.spans})
        tids = {t: i for i, t in enumerate(tracks)}
        events = [{"name": t, "ph": "M", "pid": 0, "tid": tid,
                   "args": {"name": t}}
                  for t, tid in tids.items()]
        # thread_name metadata needs its own name field
        for ev in events:
            ev["name"] = "thread_name"
        for sp in sorted(self.spans, key=lambda s: s.t0):
            ev = {"name": sp.name, "cat": sp.cat, "ph": "X", "pid": 0,
                  "tid": tids[sp.track], "ts": sp.t0 * 1e6,
                  "dur": (sp.duration_s or 0.0) * 1e6}
            if sp.args:
                ev["args"] = dict(sp.args)
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def to_json(self) -> list[dict]:
        """The finished-span forest as nested dicts (children inline)."""
        nodes = {id(sp): {"name": sp.name, "cat": sp.cat,
                          "track": sp.track, "t0": sp.t0, "t1": sp.t1,
                          "args": dict(sp.args), "children": []}
                 for sp in self.spans}
        roots = []
        for sp in sorted(self.spans, key=lambda s: s.t0):
            node = nodes[id(sp)]
            parent = nodes.get(id(sp.parent)) if sp.parent else None
            (parent["children"] if parent is not None else roots).append(node)
        return roots

    def find(self, name: str, track: str | None = None) -> list[Span]:
        """Finished spans by name (and track), begin order."""
        return sorted((sp for sp in self.spans if sp.name == name
                       and (track is None or sp.track == track)),
                      key=lambda s: s.t0)


class _NoopSpan:
    """Shared do-nothing span; its own context manager."""

    __slots__ = ()
    name = cat = track = ""
    t0 = t1 = 0.0
    duration_s = 0.0
    args: dict = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """Disabled tracer: records nothing, allocates nothing per call."""

    __slots__ = ()
    enabled = False
    spans: list = []

    def begin(self, name, cat="host", track="main", **args):
        return NOOP_SPAN

    def finish(self, span, **args):
        return span

    def span(self, name, cat="host", track="main", **args):
        return NOOP_SPAN

    def reset(self):
        pass

    def to_chrome_trace(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def dump_chrome_trace(self, path):
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def to_json(self):
        return []

    def find(self, name, track=None):
        return []


NOOP_TRACER = NoopTracer()

"""Runtime telemetry for the mining stack (metrics, spans, retraces).

The paper's claims are quantitative; the reproduction's self-measurement
was one-shot bench scripts over host walls that overlap under async
dispatch.  This package is the in-process substrate those scripts (and
the rebalancer, and CI gates) read instead:

  * ``metrics`` — a registry of counters / gauges / exponential-bucket
    histograms with labels; near-zero-cost no-op when disabled;
  * ``trace``   — begin/finish span trees with per-shard tracks,
    exported as JSON or Chrome-trace format (chrome://tracing,
    Perfetto), optionally bridged to ``jax.profiler.TraceAnnotation``;
  * ``telemetry`` — the per-session bundle of both, plus the
    :class:`RetraceTracker` that turns jax's compiled-variant counts
    into a per-tick ``jit.retraces`` counter (the O(log) recompile
    invariant, finally measured).

Invariant: telemetry reads host-side scalars and timestamps only — it
never changes what is mined, byte for byte, on or off
(tests/test_obs.py proves it across every planner engine).
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, NOOP_METRIC, NOOP_REGISTRY,
                               NoopRegistry)
from repro.obs.telemetry import (NOOP, RetraceTracker,  # noqa: F401
                                 Telemetry, default_hot_functions,
                                 jit_cache_size)
from repro.obs.trace import (NOOP_SPAN, NOOP_TRACER,  # noqa: F401
                             NoopTracer, Span, SpanTracer)

"""Telemetry session: one registry + one tracer + the retrace tracker.

A :class:`Telemetry` object is the unit the mining stack threads around:
``MiningSession`` builds one when ``MiningConfig.telemetry`` is set and
hands the *same* object to every layer it constructs (sharded service,
per-shard services, their stores and sketches), so a whole session's
counters land in one registry and its spans on one timeline.  Disabled
telemetry is the :data:`NOOP` singleton — same attribute surface, no
recording, no per-call allocation — so instrumented code never branches.

:class:`RetraceTracker` measures the invariant everything else only
promises: the streaming hot path retraces O(log) times (geometric
capacity growth in the store and sketch quantizes every jitted shape),
not per tick.  jax exposes compiled-variant counts per jitted callable
(``_cache_size``); the tracker samples their sum and yields deltas, so a
service can increment a ``jit.retraces`` counter with exactly the new
compilations each tick caused.  The hot-path jit caches are process-wide,
so a sharded service shares ONE tracker across its shards — per-shard
trackers would each see (and double-count) the same global delta.
"""
from __future__ import annotations

from repro.obs.metrics import MetricsRegistry, NOOP_REGISTRY
from repro.obs.trace import NOOP_TRACER, SpanTracer


def default_hot_functions() -> tuple:
    """The streaming ingest step's jitted callables (lazy import: obs
    must not import the stream package at module load)."""
    from repro.stream import counts as counts_lib
    from repro.stream import delta as delta_lib
    from repro.stream import store as store_lib

    return (store_lib._append_step, counts_lib.sketch_update,
            delta_lib.delta_mine_jnp)


def jit_cache_size(fns) -> int:
    """Total compiled-variant count over jitted callables (0 for any that
    predate / postdate the private ``_cache_size`` API)."""
    total = 0
    for fn in fns:
        size = getattr(fn, "_cache_size", None)
        if size is not None:
            try:
                total += int(size())
            except Exception:
                pass
    return total


class RetraceTracker:
    """Delta sampler over the hot-path jit caches.

    ``sample()`` returns compilations since the previous sample (clamped
    at zero: caches can be cleared externally) — call it once per tick
    and feed the delta to a counter.  The baseline is taken at
    construction, so compilations from *before* this service existed are
    never charged to it.
    """

    def __init__(self, fns=None):
        self.fns = tuple(fns) if fns is not None else default_hot_functions()
        self._last = jit_cache_size(self.fns)

    def total(self) -> int:
        return jit_cache_size(self.fns)

    def sample(self) -> int:
        now = jit_cache_size(self.fns)
        delta = max(0, now - self._last)
        self._last = now
        return delta


class Telemetry:
    """One telemetry session: ``.metrics`` registry + ``.tracer`` spans.

    ``jax_annotations`` forwards to the tracer: spans additionally enter
    ``jax.profiler.TraceAnnotation`` so they interleave with XLA's
    timeline inside an active ``jax.profiler.trace`` capture."""

    enabled = True

    def __init__(self, jax_annotations: bool = False):
        self.metrics = MetricsRegistry()
        self.tracer = SpanTracer(jax_annotations=jax_annotations)

    def snapshot(self) -> dict:
        return self.metrics.snapshot()

    def reset(self) -> None:
        self.metrics.reset()
        self.tracer.reset()


class _NoopTelemetry:
    """Disabled telemetry: the same surface, nothing recorded."""

    __slots__ = ()
    enabled = False
    metrics = NOOP_REGISTRY
    tracer = NOOP_TRACER

    def snapshot(self) -> dict:
        return {}

    def reset(self) -> None:
        pass


NOOP = _NoopTelemetry()

"""CompressedBlockStore: encoded history blocks on disk + a JSON index.

The disk tier's substrate: one append-only segment file of
codec-encoded blocks plus a JSON index mapping patient key -> (offset,
byte size, crc32, event count, raw bytes).  Properties the tiers above
rely on:

  * **durability** — ``flush()`` writes the index atomically (tmp file +
    ``os.replace``), and a reopened store (``CompressedBlockStore(root)``
    on an existing directory) serves every flushed block; a crash between
    flushes loses index entries, never corrupts them;
  * **integrity** — ``get`` verifies the per-key crc32 recorded at
    ``put`` time, so a torn or bit-rotted block raises instead of
    silently decoding garbage;
  * **bounded garbage** — ``pop``/``discard`` only mark bytes dead; when
    dead bytes outgrow live bytes (and a floor), the segment compacts by
    rewriting live blocks to a fresh file (atomic replace), so a
    churning eviction workload cannot grow the segment unboundedly.

Insertion order is preserved across put/pop (``keys()`` yields it), which
is what the host tier's LRU demotion relies on.
"""
from __future__ import annotations

import json
import os
import tempfile
import zlib

import numpy as np

from repro.storage import codec as codec_lib

INDEX_NAME = "index.json"
DATA_NAME = "blocks.dat"

#: compaction triggers when dead bytes exceed live bytes AND this floor
COMPACT_FLOOR_BYTES = 1 << 16


class CompressedBlockStore:
    """Disk-persisted compressed patient-history blocks (see module doc)."""

    def __init__(self, root: str | None = None,
                 dictionary: codec_lib.CodeDictionary | None = None,
                 auto_flush: bool = True):
        if root is None:
            # owned tmp dir: lives (and is reclaimed) with this object
            self._tmp = tempfile.TemporaryDirectory(prefix="tspm_blocks_")
            root = self._tmp.name
        self.root = root
        self.auto_flush = auto_flush
        os.makedirs(root, exist_ok=True)
        self._data_path = os.path.join(root, DATA_NAME)
        self._index_path = os.path.join(root, INDEX_NAME)
        # key -> [offset, nbytes, crc32, n_events, raw_bytes]
        self._index: dict = {}
        self.dead_bytes = 0
        self.dictionary = dictionary
        if os.path.exists(self._index_path):
            self._load_index()
        elif dictionary is None:
            self.dictionary = None
        self._fh = open(self._data_path, "a+b")

    # --- persistence --------------------------------------------------------
    def _load_index(self) -> None:
        with open(self._index_path) as f:
            idx = json.load(f)
        if idx.get("version") != 1:
            raise ValueError(f"unknown blockstore index version in "
                             f"{self._index_path}")
        stored_dict = idx.get("dictionary")
        if stored_dict is not None:
            loaded = codec_lib.CodeDictionary.from_json(stored_dict)
            if self.dictionary is not None and self.dictionary != loaded:
                raise ValueError("blockstore was written with a different "
                                 "code dictionary")
            self.dictionary = loaded
        self._index = {codec_lib.decode_key(k): list(v)
                       for k, v in idx["entries"]}
        self.dead_bytes = int(idx.get("dead_bytes", 0))

    def flush(self) -> None:
        """Atomically persist the index (blocks are already on disk; the
        data file is flushed first so every indexed offset is durable)."""
        self._fh.flush()
        os.fsync(self._fh.fileno())
        payload = {
            "version": 1,
            "dictionary": (self.dictionary.to_json()
                           if self.dictionary is not None else None),
            "dead_bytes": self.dead_bytes,
            "entries": [[codec_lib.encode_key(k), v]
                        for k, v in self._index.items()],
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".index.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self._index_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def close(self) -> None:
        if self._fh is not None:
            self.flush()
            self._fh.close()
            self._fh = None

    # --- block API ----------------------------------------------------------
    def put(self, key, phenx, date) -> int:
        """Encode + append one history; returns the encoded byte size.
        Re-putting a key replaces it (the old block becomes dead bytes)."""
        blob = codec_lib.encode_block(phenx, date, self.dictionary)
        if key in self._index:
            # delete before re-insert: a re-put moves the key to the back of
            # the index, keeping insertion order a usable LRU for demotion
            self.dead_bytes += self._index.pop(key)[1]
        self._fh.seek(0, os.SEEK_END)
        offset = self._fh.tell()
        self._fh.write(blob)
        self._index[key] = [offset, len(blob), zlib.crc32(blob),
                            int(np.size(phenx)),
                            codec_lib.raw_bytes(np.size(phenx))]
        if self.auto_flush:
            self.flush()
        self._maybe_compact()
        return len(blob)

    def put_bytes(self, key, blob: bytes) -> int:
        """Append one opaque blob (no codec) under ``key``; same
        durability/crc/compaction guarantees as :meth:`put`.  The
        journal's hash-chained segments ride this: they are already
        self-describing byte streams, not patient histories.  Raw
        entries carry ``n_events = -1`` so :meth:`get` refuses to decode
        them as histories."""
        blob = bytes(blob)
        if key in self._index:
            self.dead_bytes += self._index.pop(key)[1]
        self._fh.seek(0, os.SEEK_END)
        offset = self._fh.tell()
        self._fh.write(blob)
        self._index[key] = [offset, len(blob), zlib.crc32(blob), -1,
                            len(blob)]
        if self.auto_flush:
            self.flush()
        self._maybe_compact()
        return len(blob)

    def get_bytes(self, key) -> bytes:
        """Fetch one raw blob (crc-verified); KeyError if absent,
        TypeError if the key holds an encoded history block."""
        if key not in self._index:
            raise KeyError(key)
        if self._index[key][3] != -1:
            raise TypeError(f"key {key!r} holds an encoded history block; "
                            "use get()")
        return self._read(key)

    def _read(self, key) -> bytes:
        offset, nbytes, crc, _, _ = self._index[key]
        self._fh.flush()
        self._fh.seek(offset)
        blob = self._fh.read(nbytes)
        if len(blob) != nbytes or zlib.crc32(blob) != crc:
            raise IOError(f"blockstore: checksum mismatch for key {key!r} "
                          f"(torn or corrupted block)")
        return blob

    def get(self, key) -> tuple[np.ndarray, np.ndarray]:
        """Decode one history (crc-verified); KeyError if absent."""
        if key not in self._index:
            raise KeyError(key)
        if self._index[key][3] == -1:
            raise TypeError(f"key {key!r} holds a raw blob; use get_bytes()")
        return codec_lib.decode_block(self._read(key), self.dictionary)

    def pop(self, key) -> tuple[np.ndarray, np.ndarray]:
        out = self.get(key)
        self.discard(key)
        return out

    def discard(self, key) -> None:
        entry = self._index.pop(key, None)
        if entry is not None:
            self.dead_bytes += entry[1]
            if self.auto_flush:
                self.flush()
            self._maybe_compact()

    # --- introspection ------------------------------------------------------
    def __contains__(self, key) -> bool:
        return key in self._index

    def __len__(self) -> int:
        return len(self._index)

    def keys(self):
        return list(self._index)

    def n_events(self, key) -> int:
        """Event count from the index alone — no block decode."""
        return self._index[key][3]

    def encoded_bytes(self, key) -> int:
        return self._index[key][1]

    @property
    def bytes_held(self) -> int:
        """Live encoded bytes (dead segment bytes excluded)."""
        return sum(v[1] for v in self._index.values())

    @property
    def raw_bytes_held(self) -> int:
        """What the live blocks would cost uncompressed on the host."""
        return sum(v[4] for v in self._index.values())

    def compression_ratio(self) -> float:
        enc = self.bytes_held
        return self.raw_bytes_held / enc if enc else 1.0

    # --- compaction ---------------------------------------------------------
    def _maybe_compact(self) -> None:
        if self.dead_bytes > max(self.bytes_held, COMPACT_FLOOR_BYTES):
            self.compact()

    def compact(self) -> None:
        """Rewrite live blocks to a fresh segment (atomic replace)."""
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".dat.tmp")
        new_index = {}
        try:
            with os.fdopen(fd, "wb") as out:
                for key, entry in self._index.items():
                    blob = self._read(key)
                    new_index[key] = [out.tell(), entry[1], entry[2],
                                      entry[3], entry[4]]
                    out.write(blob)
                out.flush()
                os.fsync(out.fileno())
            self._fh.close()
            os.replace(tmp, self._data_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            self._fh = open(self._data_path, "a+b")
            raise
        self._fh = open(self._data_path, "a+b")
        self._index = new_index
        self.dead_bytes = 0
        if self.auto_flush:
            self.flush()

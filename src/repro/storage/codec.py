"""Delta-of-timestamp + varint block codec for patient history blocks.

Clinical event streams are monotone timestamps over a small code
vocabulary — the shape vertical-list temporal-pattern representations
exploit — so a history ``(phenx, date)`` compresses hard under

  * **delta-of-timestamp**: dates are non-decreasing day integers, so
    consecutive differences are tiny (mostly 0-30) and varint-encode to
    one byte each where the raw plane spends four;
  * **zigzag varints**: LEB128 with the sign bit folded into bit 0, so
    the codec stays *exact for any int32 input* — unsorted dates,
    negative deltas, adversarial codes — not just the happy clinical
    shape.  Exact roundtrip is the invariant every tier above relies on
    (``decode_block(encode_block(p, d)) == (p, d)`` byte-for-byte);
  * an optional **small-vocab dictionary**: codes ranked by frequency map
    to dense indices (frequent code -> 1-byte varint); codes outside the
    dictionary escape to a side stream, so a dictionary built on one
    cohort slice never breaks encoding of the next.

Block layout (all varints LEB128, little-endian 7-bit groups)::

    u8 version | u8 flags | varint n
    varint len(date_stream)   | date_stream  (zigzag deltas, first from 0)
    varint len(code_stream)   | code_stream  (zigzag codes, or dict ranks)
    [flags&1] varint len(escape_stream) | escape_stream (zigzag raw codes)

Encoding and decoding are numpy-vectorized (byte matrices, no per-event
python loop), so the codec sustains disk-tier demotion and restore at
ingest rates, not pickle rates.
"""
from __future__ import annotations

import numpy as np

VERSION = 1
FLAG_DICT = 1

_SHIFTS = np.arange(5, dtype=np.uint64) * np.uint64(7)


def zigzag_encode(v: np.ndarray) -> np.ndarray:
    """int -> unsigned, small magnitudes (either sign) stay small."""
    v = np.asarray(v, np.int64)
    return ((v << 1) ^ (v >> 63)).astype(np.uint64)


def zigzag_decode(u: np.ndarray) -> np.ndarray:
    u = np.asarray(u, np.uint64)
    return ((u >> np.uint64(1)).astype(np.int64)
            ^ -(u & np.uint64(1)).astype(np.int64))


def varint_encode(values: np.ndarray) -> bytes:
    """LEB128-encode a uint array (each value < 2^35, enough for zigzagged
    int32) into one bytes blob; vectorized over a [n, 5] byte matrix."""
    v = np.asarray(values, np.uint64)
    if v.size == 0:
        return b""
    if v.size and int(v.max()) >> 35:
        raise ValueError("varint_encode: value exceeds 35-bit budget")
    groups = (v[:, None] >> _SHIFTS) & np.uint64(0x7F)
    groups = groups.astype(np.uint8)
    # bytes needed per value: index of the last non-zero 7-bit group
    used = np.maximum((groups != 0) * (np.arange(5) + 1), 1).max(axis=1)
    keep = np.arange(5)[None, :] < used[:, None]
    cont = np.arange(5)[None, :] < (used - 1)[:, None]   # continuation bit
    groups = np.where(cont, groups | 0x80, groups)
    return groups[keep].tobytes()


def varint_decode(buf, count: int) -> np.ndarray:
    """Decode ``count`` LEB128 varints from ``buf`` -> uint64 array."""
    if count == 0:
        return np.zeros(0, np.uint64)
    b = np.frombuffer(buf, np.uint8)
    ends = np.flatnonzero((b & 0x80) == 0)
    if len(ends) < count:
        raise ValueError("varint_decode: truncated stream")
    ends = ends[:count]
    starts = np.concatenate([[0], ends[:-1] + 1])
    if (ends - starts >= 5).any():
        raise ValueError("varint_decode: varint wider than 5 bytes")
    idx = starts[:, None] + np.arange(5)[None, :]
    valid = idx <= ends[:, None]
    groups = b[np.minimum(idx, len(b) - 1)].astype(np.uint64) & np.uint64(0x7F)
    return ((groups << _SHIFTS) * valid).sum(axis=1, dtype=np.uint64)


class CodeDictionary:
    """Frequency-ranked code -> dense-index map for the phenx stream.

    Built once per store (or per cohort) from observed code counts; a
    code outside the dictionary escapes to a side stream, so the map is
    an optimization, never a correctness dependency.  JSON-serializable
    (the blockstore index persists it next to the blocks).
    """

    def __init__(self, codes):
        self.codes = [int(c) for c in codes]          # rank -> code
        self.index = {c: i for i, c in enumerate(self.codes)}

    @classmethod
    def from_counts(cls, codes, counts, max_size: int = 4096
                    ) -> "CodeDictionary":
        order = np.argsort(np.asarray(counts))[::-1][:max_size]
        return cls(np.asarray(codes)[order])

    @classmethod
    def from_histories(cls, code_arrays, max_size: int = 4096
                       ) -> "CodeDictionary":
        flat = (np.concatenate([np.asarray(a).reshape(-1)
                                for a in code_arrays])
                if len(code_arrays) else np.zeros(0, np.int64))
        codes, counts = np.unique(flat, return_counts=True)
        return cls.from_counts(codes, counts, max_size)

    def to_json(self) -> list:
        return self.codes

    @classmethod
    def from_json(cls, obj) -> "CodeDictionary":
        return cls(obj)

    def __len__(self) -> int:
        return len(self.codes)

    def __eq__(self, other) -> bool:
        return isinstance(other, CodeDictionary) and self.codes == other.codes


def _rank_streams(phenx: np.ndarray, dictionary: CodeDictionary):
    """(rank stream, escape stream): rank r+1 for dictionary codes, 0 as
    the escape marker, escaped raw codes side-streamed in order."""
    ranks = np.asarray([dictionary.index.get(int(c), -1) for c in phenx],
                       np.int64)
    escaped = phenx[ranks < 0]
    return np.where(ranks >= 0, ranks + 1, 0).astype(np.uint64), escaped


def encode_block(phenx, date, dictionary: CodeDictionary | None = None
                 ) -> bytes:
    """Encode one patient history to a self-describing compressed block."""
    phenx = np.asarray(phenx, np.int64).reshape(-1)
    date = np.asarray(date, np.int64).reshape(-1)
    if len(phenx) != len(date):
        raise ValueError("phenx/date length mismatch")
    n = len(phenx)
    deltas = np.diff(date, prepend=0)
    date_stream = varint_encode(zigzag_encode(deltas))
    flags = 0
    parts = []
    if dictionary is not None and len(dictionary):
        flags |= FLAG_DICT
        ranks, escaped = _rank_streams(phenx, dictionary)
        code_stream = varint_encode(ranks)
        escape_stream = varint_encode(zigzag_encode(escaped))
        parts = [varint_encode([len(code_stream)]), code_stream,
                 varint_encode([len(escape_stream)]), escape_stream]
    else:
        code_stream = varint_encode(zigzag_encode(phenx))
        parts = [varint_encode([len(code_stream)]), code_stream]
    head = bytes([VERSION, flags]) + varint_encode([n]) \
        + varint_encode([len(date_stream)]) + date_stream
    return head + b"".join(parts)


class _Reader:
    __slots__ = ("buf", "pos")

    def __init__(self, buf):
        self.buf = memoryview(buf)
        self.pos = 0

    def varint(self) -> int:
        out = shift = 0
        while True:
            if self.pos >= len(self.buf):
                raise ValueError("decode_block: truncated header")
            byte = self.buf[self.pos]
            self.pos += 1
            out |= (byte & 0x7F) << shift
            if not byte & 0x80:
                return out
            shift += 7

    def take(self, n: int):
        out = self.buf[self.pos: self.pos + n]
        if len(out) != n:
            raise ValueError("decode_block: truncated stream")
        self.pos += n
        return out


def decode_block(blob, dictionary: CodeDictionary | None = None
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Exact inverse of :func:`encode_block` -> int32 ``(phenx, date)``."""
    r = _Reader(blob)
    version = r.buf[r.pos]
    r.pos += 1
    if version != VERSION:
        raise ValueError(f"unknown block version {version}")
    flags = r.buf[r.pos]
    r.pos += 1
    n = r.varint()
    deltas = zigzag_decode(varint_decode(r.take(r.varint()), n))
    date = np.cumsum(deltas, dtype=np.int64)
    if flags & FLAG_DICT:
        if dictionary is None:
            raise ValueError("block was dictionary-encoded; pass the "
                             "dictionary it was written with")
        ranks = varint_decode(r.take(r.varint()), n).astype(np.int64)
        n_escaped = int((ranks == 0).sum())
        escaped = zigzag_decode(
            varint_decode(r.take(r.varint()), n_escaped))
        lut = np.asarray(dictionary.codes + [0], np.int64)
        phenx = lut[np.where(ranks > 0, ranks - 1, len(dictionary))]
        phenx[ranks == 0] = escaped
    else:
        phenx = zigzag_decode(varint_decode(r.take(r.varint()), n))
    return phenx.astype(np.int32), date.astype(np.int32)


def raw_bytes(n_events: int) -> int:
    """Uncompressed host footprint of a history: two int32 planes."""
    return 8 * int(n_events)


# --- patient-key serialization ---------------------------------------------
# Checkpoints and the blockstore index are JSON; python dict keys there
# must round-trip *typed* (an int key decoded as str would silently fork a
# patient).  Keys are tagged s-expressions: int / str / tuples thereof.

def encode_key(key) -> list:
    if isinstance(key, (bool,)):   # bool is an int subclass; reject early
        raise TypeError("bool patient keys are not serializable")
    if isinstance(key, (int, np.integer)):
        return ["i", int(key)]
    if isinstance(key, str):
        return ["s", key]
    if isinstance(key, tuple):
        return ["t", [encode_key(k) for k in key]]
    raise TypeError(f"patient key {key!r} ({type(key).__name__}) is not "
                    "serializable; use int, str, or tuples thereof")


def decode_key(obj):
    tag, val = obj
    if tag == "i":
        return int(val)
    if tag == "s":
        return val
    if tag == "t":
        return tuple(decode_key(v) for v in val)
    raise ValueError(f"unknown key tag {tag!r}")

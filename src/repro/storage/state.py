"""Checkpoint state trees: JSON structure + numpy leaves, split apart.

``MiningSession.checkpoint`` captures a nested python structure (dicts,
lists, scalars) whose leaves include numpy arrays.  The on-disk layout
(training/checkpoint.py: ``arrays.npz`` + ``manifest.json``, atomic
tmp+rename) wants arrays and JSON separated, so:

  * :func:`pack_tree`   — walk the structure, pull every ndarray into a
    flat list, and leave an ``{"__ndarray__": i}`` placeholder behind;
  * :func:`unpack_tree` — the exact inverse (npz round-trips dtype and
    shape, so the reassembled tree is byte-identical).

Scalars must already be JSON-able; numpy scalar types are normalized to
python ints/floats so a manifest never depends on numpy repr.
"""
from __future__ import annotations

import numpy as np

_MARK = "__ndarray__"


def pack_tree(obj, arrays: list | None = None):
    """-> (json_obj, arrays): ndarrays replaced by indexed placeholders."""
    if arrays is None:
        arrays = []

    def walk(x):
        if isinstance(x, np.ndarray):
            arrays.append(x)
            return {_MARK: len(arrays) - 1}
        if isinstance(x, dict):
            if _MARK in x:
                raise ValueError(f"state tree dict uses reserved key {_MARK}")
            return {k: walk(v) for k, v in x.items()}
        if isinstance(x, (list, tuple)):
            return [walk(v) for v in x]
        if isinstance(x, (np.integer,)):
            return int(x)
        if isinstance(x, (np.floating,)):
            return float(x)
        if isinstance(x, (np.bool_,)):
            return bool(x)
        if x is None or isinstance(x, (bool, int, float, str)):
            return x
        raise TypeError(f"state tree leaf {x!r} ({type(x).__name__}) is "
                        "not JSON-serializable")

    return walk(obj), arrays


def unpack_tree(json_obj, arrays):
    """Inverse of :func:`pack_tree` (tuples come back as lists)."""

    def walk(x):
        if isinstance(x, dict):
            if set(x) == {_MARK}:
                return np.asarray(arrays[x[_MARK]])
            return {k: walk(v) for k, v in x.items()}
        if isinstance(x, list):
            return [walk(v) for v in x]
        return x

    return walk(json_obj)

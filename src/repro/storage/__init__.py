"""Tiered compressed storage for patient histories + checkpoint plumbing.

The residency story below device RAM: :mod:`~repro.storage.codec`
(delta-of-timestamp + varint block codec, exact roundtrip for any int32
history), :mod:`~repro.storage.blockstore` (disk block files + JSON
index, crc-verified, atomically flushed), :mod:`~repro.storage.tiers`
(the ``ResidencyTier`` protocol with host and disk implementations the
:class:`~repro.stream.store.PatientStore` walks), and
:mod:`~repro.storage.state` (checkpoint state trees for
``MiningSession.checkpoint`` / ``restore``).
"""
from repro.storage.blockstore import CompressedBlockStore  # noqa: F401
from repro.storage.codec import (CodeDictionary, decode_block,  # noqa: F401
                                 decode_key, encode_block, encode_key)
from repro.storage.state import pack_tree, unpack_tree  # noqa: F401
from repro.storage.tiers import DiskTier, HostTier, ResidencyTier  # noqa: F401

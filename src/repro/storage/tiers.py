"""Residency tiers: one interface for every place a cold history can live.

The store's residency walk is device -> host -> disk; everything below
the device planes sits behind :class:`ResidencyTier` so the eviction /
restore / handoff paths are tier-agnostic policy, not special-cased
dicts.  A tier holds *withdrawn* histories in the host-spill format (1-D
int32 ``(phenx, date)`` arrays) keyed by patient key:

  * ``hold``     — take custody of a history (idempotent per key: a
    re-hold replaces);
  * ``restore``  — withdraw it (the promotion path; removes the entry);
  * ``peek``     — read without withdrawing (introspection, cost model);
  * ``drop``     — discard (patient extracted away);
  * ``keys()``   — insertion order, oldest first: the demotion walk pops
    from the front, so "least-recently-spilled" falls out of dict order
    with no extra clock.

:class:`HostTier` is the pre-refactor ``_spilled`` dict behind the
interface; :class:`DiskTier` persists blocks through
:class:`~repro.storage.blockstore.CompressedBlockStore` and reports both
encoded (actual disk) and raw (host-equivalent) bytes, plus
encode/decode latency histograms and a compression-ratio gauge on the
``storage.*`` metric namespace.
"""
from __future__ import annotations

import time
from typing import Protocol, runtime_checkable

import numpy as np

from repro import obs as obs_lib
from repro.storage.blockstore import CompressedBlockStore


@runtime_checkable
class ResidencyTier(Protocol):
    """What the store's policy walk needs from any tier."""

    name: str

    def hold(self, key, phenx, date) -> None: ...

    def restore(self, key) -> tuple[np.ndarray, np.ndarray]: ...

    def peek(self, key) -> tuple[np.ndarray, np.ndarray]: ...

    def drop(self, key) -> None: ...

    def bytes_held(self) -> int: ...

    def event_counts(self) -> dict: ...

    def keys(self) -> list: ...

    def __contains__(self, key) -> bool: ...

    def __len__(self) -> int: ...


class HostTier:
    """Host-RAM spill tier: uncompressed 1-D history copies (the former
    ``PatientStore._spilled`` dict, now behind the tier interface)."""

    name = "host"

    def __init__(self, telemetry=None, labels: dict | None = None):
        self._held: dict = {}
        self._bytes = 0                    # incremental: hot-path friendly
        obs = telemetry if telemetry is not None else obs_lib.NOOP
        lbl = dict(labels or {}, tier=self.name)
        self._m_patients = obs.metrics.gauge("storage.tier_patients", **lbl)
        self._m_bytes = obs.metrics.gauge("storage.tier_bytes", **lbl)
        self._m_restores = obs.metrics.counter("storage.restores", **lbl)

    def hold(self, key, phenx, date) -> None:
        self.drop(key)                     # re-hold moves to the back
        entry = (np.asarray(phenx, np.int32).reshape(-1),
                 np.asarray(date, np.int32).reshape(-1))
        self._held[key] = entry
        self._bytes += entry[0].nbytes + entry[1].nbytes
        self._sample()

    def restore(self, key) -> tuple[np.ndarray, np.ndarray]:
        out = self._held.pop(key)
        self._bytes -= out[0].nbytes + out[1].nbytes
        self._m_restores.inc()
        self._sample()
        return out

    def peek(self, key) -> tuple[np.ndarray, np.ndarray]:
        return self._held[key]

    def drop(self, key) -> None:
        out = self._held.pop(key, None)
        if out is not None:
            self._bytes -= out[0].nbytes + out[1].nbytes
            self._sample()

    def bytes_held(self) -> int:
        return self._bytes

    def event_counts(self) -> dict:
        return {k: len(p) for k, (p, _) in self._held.items()}

    def keys(self) -> list:
        return list(self._held)

    def __contains__(self, key) -> bool:
        return key in self._held

    def __len__(self) -> int:
        return len(self._held)

    def _sample(self) -> None:
        self._m_patients.set(len(self._held))


class DiskTier:
    """Compressed on-disk tier over :class:`CompressedBlockStore`.

    ``hold`` pays one encode + append; ``restore`` one crc-checked read +
    decode.  The blockstore is opened lazily against ``root`` (an owned
    tmp dir when None) and left unflushed between checkpoints —
    durability is the checkpoint layer's contract, latency is this
    tier's."""

    name = "disk"

    def __init__(self, root: str | None = None, dictionary=None,
                 telemetry=None, labels: dict | None = None):
        self.store = CompressedBlockStore(root, dictionary=dictionary,
                                          auto_flush=False)
        obs = telemetry if telemetry is not None else obs_lib.NOOP
        lbl = dict(labels or {}, tier=self.name)
        self._m_patients = obs.metrics.gauge("storage.tier_patients", **lbl)
        self._m_bytes = obs.metrics.gauge("storage.tier_bytes", **lbl)
        self._m_raw = obs.metrics.gauge("storage.tier_raw_bytes", **lbl)
        self._m_ratio = obs.metrics.gauge("storage.compression_ratio", **lbl)
        self._m_restores = obs.metrics.counter("storage.restores", **lbl)
        self._m_enc = obs.metrics.histogram("storage.encode_s", **(labels or {}))
        self._m_dec = obs.metrics.histogram("storage.decode_s", **(labels or {}))

    @property
    def root(self) -> str:
        return self.store.root

    def hold(self, key, phenx, date) -> None:
        t0 = time.perf_counter()
        self.store.put(key, phenx, date)
        self._m_enc.observe(time.perf_counter() - t0)
        self._sample()

    def restore(self, key) -> tuple[np.ndarray, np.ndarray]:
        t0 = time.perf_counter()
        out = self.store.pop(key)
        self._m_dec.observe(time.perf_counter() - t0)
        self._m_restores.inc()
        self._sample()
        return out

    def peek(self, key) -> tuple[np.ndarray, np.ndarray]:
        return self.store.get(key)

    def drop(self, key) -> None:
        self.store.discard(key)
        self._sample()

    def flush(self) -> None:
        self.store.flush()

    def bytes_held(self) -> int:
        return self.store.bytes_held

    def event_counts(self) -> dict:
        return {k: self.store.n_events(k) for k in self.store.keys()}

    def keys(self) -> list:
        return self.store.keys()

    def __contains__(self, key) -> bool:
        return key in self.store

    def __len__(self) -> int:
        return len(self.store)

    def _sample(self) -> None:
        self._m_patients.set(len(self.store))
        self._m_bytes.set(self.store.bytes_held)
        self._m_raw.set(self.store.raw_bytes_held)
        self._m_ratio.set(self.store.compression_ratio())

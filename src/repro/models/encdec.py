"""Encoder-decoder backbone (seamless-m4t-v2 text/speech transformer).

The modality frontend is a STUB per the assignment: ``src_embeds``
[B, S_src, d_model] arrive precomputed (speech frames / text embeddings);
the decoder is a standard causal transformer with cross-attention.
"24L" is interpreted as 24 encoder + 24 decoder layers (the published
large-v2 text stack); RoPE replaces the original relative positions
(DESIGN.md §9).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, fsdp_axis_for
from repro.models import attention, layers
from repro.models.layers import rmsnorm
from repro.models import runtime_flags


def enc_layer_init(rng, cfg, fsdp_axis):
    r = jax.random.split(rng, 2)
    dtype = layers.dt(cfg)
    p, s = {}, {}
    p["ln1"], s["ln1"] = layers.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], s["attn"] = attention.init(r[0], cfg, fsdp_axis)
    p["ln2"], s["ln2"] = layers.rmsnorm_init(cfg.d_model, dtype)
    p["mlp"], s["mlp"] = layers.mlp_init(r[1], cfg.d_model, cfg.d_ff, dtype,
                                         fsdp_axis, cfg.mlp_act)
    return p, s


def dec_layer_init(rng, cfg, fsdp_axis):
    r = jax.random.split(rng, 3)
    dtype = layers.dt(cfg)
    p, s = enc_layer_init(r[0], cfg, fsdp_axis)
    p["ln_x"], s["ln_x"] = layers.rmsnorm_init(cfg.d_model, dtype)
    p["xattn"], s["xattn"] = attention.init(r[1], cfg, fsdp_axis, cross=True)
    return p, s


def init(rng, cfg):
    fsdp_axis = fsdp_axis_for(cfg)
    r = jax.random.split(rng, 4)
    p, s = {}, {}
    p["embed"], s["embed"] = layers.embed_init(
        r[0], cfg.vocab_size, cfg.d_model, layers.dt(cfg), fsdp_axis)
    p["enc"], s["enc"] = layers.stack_inits(
        r[1], cfg.n_enc_layers,
        functools.partial(enc_layer_init, cfg=cfg, fsdp_axis=fsdp_axis))
    p["dec"], s["dec"] = layers.stack_inits(
        r[2], cfg.n_dec_layers,
        functools.partial(dec_layer_init, cfg=cfg, fsdp_axis=fsdp_axis))
    p["ln_enc"], s["ln_enc"] = layers.rmsnorm_init(cfg.d_model, layers.dt(cfg))
    p["ln_f"], s["ln_f"] = layers.rmsnorm_init(cfg.d_model, layers.dt(cfg))
    return p, s


def encode(p, src_embeds, cfg):
    x = src_embeds.astype(layers.dt(cfg))
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(x, ("batch", None, None))

    def body(x, lp):
        h, _ = attention.apply(lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps),
                               cfg, positions=positions, causal=False)
        x = x + h
        x = x + layers.mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps),
                           cfg.mlp_act)
        return constrain(x, ("batch", None, None)), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p["enc"], unroll=runtime_flags.scan_unroll())
    return rmsnorm(p["ln_enc"], x, cfg.norm_eps)


def _dec_layer(lp, x, memory, cfg, positions, cache=None):
    h, new_cache = attention.apply(
        lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, cache=cache)
    x = x + h
    hx, _ = attention.apply(lp["xattn"], rmsnorm(lp["ln_x"], x, cfg.norm_eps),
                            cfg, positions=positions, causal=False,
                            memory=memory)
    x = x + hx
    x = x + layers.mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps),
                       cfg.mlp_act)
    return constrain(x, ("batch", None, None)), new_cache


def apply(p, batch, cfg, *, mode="train", caches=None):
    """batch: src_embeds [B,Ss,D] (+ memory cached for decode),
    tgt tokens [B,St]."""
    with_cache = caches is not None
    if with_cache and mode == "decode":
        memory = caches["memory"]
    else:
        memory = encode(p, batch["src_embeds"], cfg)
    x = layers.embed_lookup(p["embed"], batch["tokens"], cfg.embed_scale)
    b, st = x.shape[:2]
    if mode == "decode":
        pos0 = caches["attn"]["pos"][0]
        positions = jnp.full((b, 1), pos0, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(st, dtype=jnp.int32), (b, st))

    def body(x, xs):
        lp, lc = xs if with_cache else (xs, None)
        x, nc = _dec_layer(lp, x, memory, cfg, positions, lc)
        return x, nc

    if cfg.remat != "none" and mode == "train":
        body = jax.checkpoint(body)
    xs = (p["dec"], caches["attn"]) if with_cache else p["dec"]
    x, new_caches = jax.lax.scan(body, x, xs,
                                 unroll=runtime_flags.scan_unroll())
    if mode == "prefill":
        x = x[:, -1:]
    logits = layers.embed_logits(
        p["embed"], rmsnorm(p["ln_f"], x, cfg.norm_eps), cfg.final_softcap)
    if with_cache:
        return logits, {"attn": new_caches, "memory": memory}
    return logits, jnp.zeros((), jnp.float32)


def init_caches(cfg, batch, max_len, src_len, dtype=None):
    one = attention.init_cache(cfg, batch, max_len, dtype)
    return {
        "attn": {
            "k": jnp.zeros((cfg.n_dec_layers,) + one["k"].shape, one["k"].dtype),
            "v": jnp.zeros((cfg.n_dec_layers,) + one["v"].shape, one["v"].dtype),
            "pos": jnp.zeros((cfg.n_dec_layers,), jnp.int32),
        },
        "memory": jnp.zeros((batch, src_len, cfg.d_model), dtype or
                            layers.dt(cfg)),
    }
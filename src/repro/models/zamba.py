"""Zamba2: Mamba2 backbone + weight-SHARED attention blocks.

Per the published architecture: every ``shared_attn_every`` Mamba2 layers,
a shared transformer block runs on concat(x, x_embed0) at width 2*d_model
(zamba2-2.7b: 32 heads x head_dim 160 = 5120 = 2*2560), followed by a
projection back to d_model added to the residual.  ``n_shared_attn_blocks``
(2) parameter sets alternate across invocations; each invocation keeps its
OWN kv cache (weights are shared, states are not).  Per-invocation LoRA
adapters of the original are omitted (DESIGN.md §9).

Scan structure: groups of (shared_attn_every Mamba layers + 1 shared-attn
invocation); Mamba params are stacked [n_groups, every, ...], shared-attn
params indexed by invocation parity via dynamic slicing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, fsdp_axis_for
from repro.models import attention, layers, mamba2
from repro.models.layers import linear, linear_init, rmsnorm
from repro.models import runtime_flags


def _shared_cfg(cfg):
    d2 = 2 * cfg.d_model
    return cfg.replace(d_model=d2, head_dim=d2 // cfg.n_heads,
                       attn_softcap=None, sliding_window=None)


def _groups(cfg):
    every = cfg.shared_attn_every or cfg.n_layers
    assert cfg.n_layers % every == 0
    return cfg.n_layers // every, every


def shared_block_init(rng, cfg, fsdp_axis):
    d = cfg.d_model
    scfg = _shared_cfg(cfg)
    r = jax.random.split(rng, 4)
    dtype = layers.dt(cfg)
    p, s = {}, {}
    p["ln1"], s["ln1"] = layers.rmsnorm_init(2 * d, dtype)
    p["attn"], s["attn"] = attention.init(r[0], scfg, fsdp_axis)
    p["ln2"], s["ln2"] = layers.rmsnorm_init(2 * d, dtype)
    p["mlp"], s["mlp"] = layers.mlp_init(r[1], 2 * d, cfg.d_ff, dtype,
                                         fsdp_axis, cfg.mlp_act)
    p["down"], s["down"] = linear_init(r[2], 2 * d, d, dtype,
                                       P("model", fsdp_axis))
    return p, s


def shared_block_apply(p, x, x0, cfg, *, positions, cache=None):
    scfg = _shared_cfg(cfg)
    h = jnp.concatenate([x, x0], axis=-1)
    a, new_cache = attention.apply(p["attn"], rmsnorm(p["ln1"], h,
                                                      cfg.norm_eps),
                                   scfg, positions=positions, cache=cache)
    h = h + a
    h = h + layers.mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps),
                       cfg.mlp_act)
    return x + linear(p["down"], h), new_cache


def init(rng, cfg):
    fsdp_axis = fsdp_axis_for(cfg)
    n_groups, every = _groups(cfg)
    r = jax.random.split(rng, 4 + cfg.n_shared_attn_blocks)
    p, s = {}, {}
    p["embed"], s["embed"] = layers.embed_init(
        r[0], cfg.vocab_size, cfg.d_model, layers.dt(cfg), fsdp_axis)

    def group_init(rg):
        return layers.stack_inits(
            rg, every, functools.partial(mamba2.init, cfg=cfg,
                                         fsdp_axis=fsdp_axis))

    p["mamba"], s["mamba"] = layers.stack_inits(r[1], n_groups, group_init)
    shared_ps, shared_ss = [], None
    for i in range(cfg.n_shared_attn_blocks):
        sp, ss = shared_block_init(r[2 + i], cfg, fsdp_axis)
        shared_ps.append(sp)
        shared_ss = ss
    p["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs), *shared_ps)
    s["shared"] = jax.tree.map(lambda sp: P(None, *sp), shared_ss,
                               is_leaf=lambda v: isinstance(v, P))
    p["ln_f"], s["ln_f"] = layers.rmsnorm_init(cfg.d_model, layers.dt(cfg))
    return p, s


def init_caches(cfg, batch, max_len, dtype=None):
    n_groups, every = _groups(cfg)
    scfg = _shared_cfg(cfg)
    mamba_states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_groups, every) + a.shape).copy(),
        mamba2.init_state(cfg, batch, dtype))
    attn_cache = attention.init_cache(scfg, batch, max_len, dtype)
    attn_caches = {
        "k": jnp.zeros((n_groups,) + attn_cache["k"].shape, attn_cache["k"].dtype),
        "v": jnp.zeros((n_groups,) + attn_cache["v"].shape, attn_cache["v"].dtype),
        "pos": jnp.zeros((n_groups,), jnp.int32),
    }
    return {"mamba": mamba_states, "attn": attn_caches}


def apply(p, batch, cfg, *, mode="train", caches=None):
    x = layers.embed_lookup(p["embed"], batch["tokens"], cfg.embed_scale)
    x = constrain(x, ("batch", None, None))
    x0 = x
    b, sq = x.shape[:2]
    n_groups, every = _groups(cfg)
    with_cache = caches is not None
    decode = mode == "decode"
    if decode:
        pos0 = caches["attn"]["pos"][0]
        positions = jnp.full((b, 1), pos0, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))

    def body(carry, xs):
        x, g = carry
        if with_cache:
            mp, ms, ac = xs
        else:
            mp, ms, ac = xs[0], None, None
        new_ms = []
        for i in range(every):
            lp = jax.tree.map(lambda a: a[i], mp)
            st = jax.tree.map(lambda a: a[i], ms) if with_cache else None
            if decode:
                x, ns = mamba2.decode(lp, x, cfg, st)
            else:
                x, ns = mamba2.apply(lp, x, cfg, st)
            if with_cache:
                new_ms.append(ns)
        sp = jax.tree.map(lambda a: a[g % cfg.n_shared_attn_blocks],
                          p["shared"])
        x, new_ac = shared_block_apply(sp, x, x0, cfg, positions=positions,
                                       cache=ac)
        out = None
        if with_cache:
            new_ms = jax.tree.map(lambda *ys: jnp.stack(ys), *new_ms)
            out = (new_ms, new_ac)
        return (x, g + 1), out

    if cfg.remat != "none" and mode == "train":
        body = jax.checkpoint(body)
    xs = (p["mamba"], caches["mamba"], caches["attn"]) if with_cache \
        else (p["mamba"],)
    (x, _), outs = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)), xs,
                                unroll=runtime_flags.scan_unroll())
    if mode == "prefill":
        x = x[:, -1:]
    logits = layers.embed_logits(
        p["embed"], rmsnorm(p["ln_f"], x, cfg.norm_eps), cfg.final_softcap)
    if with_cache:
        return logits, {"mamba": outs[0], "attn": outs[1]}
    return logits, jnp.zeros((), jnp.float32)
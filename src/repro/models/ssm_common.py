"""Chunked scalar-decay linear recurrence — shared by mLSTM and Mamba2 SSD.

Recurrence (per batch, head):   C_t = f_t * C_{t-1} + k_t v_t^T
                                n_t = f_t * n_{t-1} + k_t
                                y_t = q_t @ C_t     (+ optional normalizer)

with data-dependent scalar decay f_t in (0, 1] (log_f <= 0, so every
exponent below is <= 0 — no stabilizer state needed; DESIGN.md §9 notes
this bounded-gate deviation from exponential-gate xLSTM).

Chunked evaluation (chunk c): intra-chunk weights W(t,s) = exp(A_t - A_s)
for s <= t with A = cumsum(log_f), inter-chunk contribution exp(A_t) * C_in,
carry C_out = sum_s exp(A_end - A_s) k_s v_s^T + exp(A_end) * C_in — one
lax.scan over S/c chunks, O(S*c) work instead of O(S^2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from repro.models import runtime_flags


class ScanState(NamedTuple):
    C: jax.Array   # [B, H, dk, dv]
    n: jax.Array   # [B, H, dk]


def init_state(b, h, dk, dv, dtype=jnp.float32):
    return ScanState(jnp.zeros((b, h, dk, dv), dtype),
                     jnp.zeros((b, h, dk), dtype))


def chunked_scan(q, k, v, log_f, *, chunk: int = 64,
                 state: ScanState | None = None, normalize: bool = False):
    """q,k [B,S,H,dk]; v [B,S,H,dv]; log_f [B,S,H] (<=0).

    Returns (y [B,S,H,dv], qn [B,S,H] or None, final ScanState).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    if s % c:
        c = s
    nc = s // c
    if state is None:
        state = init_state(b, h, dk, dv)

    qc = q.reshape(b, nc, c, h, dk).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kc = k.reshape(b, nc, c, h, dk).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vc = v.reshape(b, nc, c, h, dv).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    fc = log_f.reshape(b, nc, c, h).transpose(1, 0, 3, 2).astype(jnp.float32)
    # per chunk: q/k/v [B,H,c,d*], f [B,H,c]

    tri = jnp.tril(jnp.ones((c, c), bool))

    def step(st, xs):
        qi, ki, vi, fi = xs
        A = jnp.cumsum(fi, axis=-1)                     # [B,H,c]
        w = jnp.exp(A[..., :, None] - A[..., None, :])  # [B,H,c,c] (<=1 on tril)
        w = jnp.where(tri, w, 0.0)
        scores = jnp.einsum("bhtd,bhsd->bhts", qi, ki) * w
        y = jnp.einsum("bhts,bhsv->bhtv", scores, vi)
        decay_in = jnp.exp(A)[..., None]                # [B,H,c,1]
        y += jnp.einsum("bhtd,bhdv->bhtv", qi * decay_in, st.C)
        qn = None
        if normalize:
            qn = scores.sum(-1) + jnp.einsum("bhtd,bhd->bht", qi * decay_in,
                                             st.n)
        w_end = jnp.exp(A[..., -1:] - A)                # [B,H,c]
        C_new = jnp.einsum("bhs,bhsd,bhsv->bhdv", w_end, ki, vi) + \
            st.C * jnp.exp(A[..., -1])[..., None, None]
        n_new = jnp.einsum("bhs,bhsd->bhd", w_end, ki) + \
            st.n * jnp.exp(A[..., -1])[..., None]
        return ScanState(C_new, n_new), (y, qn if normalize else jnp.zeros(
            (b, h, c), jnp.float32))

    final, (ys, qns) = jax.lax.scan(step, state, (qc, kc, vc, fc),
                                    unroll=runtime_flags.scan_unroll())
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dv)
    qn = qns.transpose(1, 0, 3, 2).reshape(b, s, h) if normalize else None
    return y, qn, final


def decode_step(q, k, v, log_f, state: ScanState, normalize: bool = False):
    """One-token update. q,k [B,H,dk]; v [B,H,dv]; log_f [B,H]."""
    f = jnp.exp(log_f.astype(jnp.float32))[..., None]
    C = state.C * f[..., None] + jnp.einsum(
        "bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    n = state.n * f + k.astype(jnp.float32)
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), C)
    qn = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n) if normalize else None
    return y, qn, ScanState(C, n)


def causal_conv1d(x, w, b=None):
    """Depthwise causal conv: x [B,S,C], w [K,C] -> [B,S,C] (shift-and-add)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, j:j + x.shape[1]] * w[j][None, None] for j in range(k))
    if b is not None:
        y = y + b[None, None]
    return y


def conv_decode_step(x_t, conv_state, w, b=None):
    """x_t [B,C]; conv_state [B,K-1,C] (previous inputs, oldest first)."""
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        y = y + b[None]
    return y, window[:, 1:]
"""xLSTM LM: mLSTM (matrix memory, chunked-parallel) + sLSTM blocks.

mLSTM uses the shared chunked scalar-decay recurrence (ssm_common) with the
xLSTM normalizer h = (q C) / max(|q n|, 1); gates are bounded
(sigmoid input / sigmoid forget) instead of exponential-with-stabilizer —
DESIGN.md §9 records the deviation.  O(1)-state decode => long_500k runs.

sLSTM is inherently sequential (the xLSTM paper says so) and is evaluated
with lax.scan over time, with per-head block-diagonal recurrent weights and
the stabilized exponential-gate formulation.

d_ff = 0 per the assignment: blocks carry their own expansion
(ssm_expand) and gating; there is no separate FFN.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed.sharding import constrain, current_rules, fsdp_axis_for
from repro.models import layers, ssm_common
from repro.models.layers import linear, linear_init, rmsnorm
from repro.models import runtime_flags


def _dims(cfg):
    di = cfg.d_model * cfg.ssm_expand
    return di, cfg.n_heads, di // cfg.n_heads


# --- mLSTM block -------------------------------------------------------------
def mlstm_init(rng, cfg, fsdp_axis):
    d = cfg.d_model
    di, h, dh = _dims(cfg)
    r = jax.random.split(rng, 6)
    dtype = layers.dt(cfg)
    # tp_internals=False: pure DP/FSDP — a 125M model over-distributed on a
    # 16-way TP axis spends everything on per-chunk state all-reduces
    # (EXPERIMENTS.md §Perf iteration 2)
    tp = "model" if cfg.tp_internals else None
    p, s = {}, {}
    p["ln"], s["ln"] = layers.rmsnorm_init(d, dtype)
    for i, nm in enumerate(("wq", "wk", "wv", "wz")):
        p[nm], s[nm] = linear_init(r[i], d, di, dtype, P(fsdp_axis, tp))
    p["wg"], s["wg"] = linear_init(r[4], d, 2 * h, dtype, P(fsdp_axis, tp))
    p["wo"], s["wo"] = linear_init(r[5], di, d, dtype, P(tp, fsdp_axis))
    p["hn"], s["hn"] = layers.rmsnorm_init(di, dtype)
    return p, s


def _mlstm_qkv(p, xn, cfg):
    """Returns (q, i-scaled k, v, log_f); bounded gates (sigmoid i / f)."""
    di, h, dh = _dims(cfg)
    b, sq = xn.shape[:2]
    q = linear(p["wq"], xn).reshape(b, sq, h, dh) * dh ** -0.5
    k = linear(p["wk"], xn).reshape(b, sq, h, dh) * dh ** -0.5
    v = linear(p["wv"], xn).reshape(b, sq, h, dh)
    g = linear(p["wg"], xn).reshape(b, sq, h, 2).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(g[..., 0])
    i = jax.nn.sigmoid(g[..., 1])
    return q, k * i[..., None].astype(k.dtype), v, log_f


def _mlstm_out(p, x, xn, y, qn, cfg):
    b, sq = xn.shape[:2]
    di = _dims(cfg)[0]
    y = y / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    y = y.reshape(b, sq, di).astype(x.dtype)
    y = rmsnorm(p["hn"], y, cfg.norm_eps) * jax.nn.silu(linear(p["wz"], xn))
    return x + linear(p["wo"], y)


def mlstm_apply(p, x, cfg, state=None):
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    q, k, v, log_f = _mlstm_qkv(p, xn, cfg)
    y, qn, new_state = ssm_common.chunked_scan(
        q, k, v, log_f, chunk=cfg.ssm_chunk, state=state, normalize=True)
    return _mlstm_out(p, x, xn, y, qn, cfg), new_state


def mlstm_decode(p, x, cfg, state):
    """x [B, 1, D]."""
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    q, k, v, log_f = _mlstm_qkv(p, xn, cfg)
    y, qn, new_state = ssm_common.decode_step(
        q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], state, normalize=True)
    return _mlstm_out(p, x, xn, y[:, None], qn[:, None], cfg), new_state


def mlstm_state(cfg, batch):
    di, h, dh = _dims(cfg)
    return ssm_common.init_state(batch, h, dh, dh)


# --- sLSTM block -------------------------------------------------------------
def slstm_init(rng, cfg, fsdp_axis):
    d = cfg.d_model
    di, h, dh = _dims(cfg)
    r = jax.random.split(rng, 7)
    dtype = layers.dt(cfg)
    p, s = {}, {}
    tp = "model" if cfg.tp_internals else None
    p["ln"], s["ln"] = layers.rmsnorm_init(d, dtype)
    p["wx"], s["wx"] = linear_init(r[0], d, 4 * di, dtype, P(fsdp_axis, tp))
    p["r"] = layers.truncnorm(r[1], (4, h, dh, dh), dh ** -0.5, dtype)
    s["r"] = P(None, tp, None, None)
    p["wo"], s["wo"] = linear_init(r[2], di, d, dtype, P(tp, fsdp_axis))
    p["hn"], s["hn"] = layers.rmsnorm_init(di, dtype)
    return p, s


def _slstm_cell(gates_x, r, h_prev, c, n, m):
    """One step.  gates_x [B,4,H,dh]; r [4,H,dh,dh]; states [B,H,dh]."""
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, r.astype(jnp.float32))
    zi, ii, fi, oi = [gates_x[:, g].astype(jnp.float32) + rec[:, g]
                      for g in range(4)]
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + m, ii)
    i_g = jnp.exp(ii - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(zi)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(oi) * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def slstm_state(cfg, batch):
    di, h, dh = _dims(cfg)
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z - 10.0}


def _slstm_scan(gx, r, state):
    """The sequential cell, shard-local.  gx [B,S,4,H,dh]."""

    def step(st, g_t):
        hn, cn, nn, mn = _slstm_cell(g_t, r, st["h"], st["c"],
                                     st["n"], st["m"])
        return {"h": hn, "c": cn, "n": nn, "m": mn}, hn

    return jax.lax.scan(step, state, jnp.moveaxis(gx, 1, 0),
                        unroll=runtime_flags.scan_unroll())


def slstm_apply(p, x, cfg, state=None):
    b, sq, d = x.shape
    di, h, dh = _dims(cfg)
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    gx = linear(p["wx"], xn).reshape(b, sq, 4, h, dh)
    if state is None:
        state = slstm_state(cfg, b)

    ctx = current_rules()
    if ctx is not None and sq > 1:
        # Manual SPMD around the sequential cell: under plain GSPMD the
        # recurrent-weight gradient dR is all-reduced EVERY time step
        # (4096x per layer!); inside shard_map the accumulation stays
        # shard-local and autodiff inserts ONE psum at the boundary
        # (EXPERIMENTS.md §Perf, xlstm iteration 2b).
        mesh, rules = ctx
        ba = rules.get("batch")

        def bspec(nd, batch_dim=0):
            spec = [None] * nd
            spec[batch_dim] = ba
            return P(*spec)

        state_specs = {k: bspec(3) for k in state}
        # check_vma=False: with VMA tracking on, the replicated-weight
        # cotangent is converted varying->invariant (psum) at every scan
        # step; classic semantics psums once at the shard_map exit.
        new_state, hs = compat.shard_map(
            _slstm_scan, mesh=mesh,
            in_specs=(bspec(5), P(None, None, None, None), state_specs),
            out_specs=(state_specs, bspec(4, batch_dim=1)),
            check_vma=False,
        )(gx, p["r"], state)
    else:
        new_state, hs = _slstm_scan(gx, p["r"], state)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, sq, di).astype(x.dtype)
    y = rmsnorm(p["hn"], y, cfg.norm_eps)
    return x + linear(p["wo"], y), new_state


def slstm_decode(p, x, cfg, state):
    out, new_state = slstm_apply(p, x, cfg, state)
    return out, new_state


# --- full LM ----------------------------------------------------------------
def pattern_of(cfg) -> tuple[str, ...]:
    k = cfg.slstm_every
    if k:
        return ("m",) * (k - 1) + ("s",)
    return ("m",)


def init(rng, cfg):
    fsdp_axis = fsdp_axis_for(cfg)
    pattern = pattern_of(cfg)
    assert cfg.n_layers % len(pattern) == 0
    n_rep = cfg.n_layers // len(pattern)
    r = jax.random.split(rng, len(pattern) + 2)
    p, s = {}, {}
    # embed keeps vocab x 'model' sharding regardless of block TP (the
    # fsdp tuple would collide with the vocab axis)
    p["embed"], s["embed"] = layers.embed_init(
        r[0], cfg.vocab_size, cfg.d_model, layers.dt(cfg),
        "data" if cfg.fsdp else None)
    for i, kind in enumerate(pattern):
        fn = mlstm_init if kind == "m" else slstm_init
        p[f"blk{i}"], s[f"blk{i}"] = layers.stack_inits(
            r[1 + i], n_rep,
            functools.partial(fn, cfg=cfg, fsdp_axis=fsdp_axis))
    p["ln_f"], s["ln_f"] = layers.rmsnorm_init(cfg.d_model, layers.dt(cfg))
    return p, s


def init_caches(cfg, batch, max_len=None, dtype=None):
    pattern = pattern_of(cfg)
    n_rep = cfg.n_layers // len(pattern)

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a, (n_rep,) + a.shape).copy(), tree)

    caches = []
    for kind in pattern:
        one = (mlstm_state(cfg, batch) if kind == "m"
               else slstm_state(cfg, batch))
        caches.append(stack(one))
    return tuple(caches)  # tuple: matches the scan's output structure


def apply(p, batch, cfg, *, mode="train", caches=None):
    x = layers.embed_lookup(p["embed"], batch["tokens"], cfg.embed_scale)
    x = constrain(x, ("batch", None, None))
    pattern = pattern_of(cfg)
    stacked = tuple(p[f"blk{i}"] for i in range(len(pattern)))
    decode = mode == "decode"
    with_cache = caches is not None

    def body(carry, xs):
        x = carry
        lp = xs[: len(pattern)]
        lc = xs[len(pattern):] if with_cache else [None] * len(pattern)
        new_states = []
        for i, kind in enumerate(pattern):
            if kind == "m":
                fn = mlstm_decode if decode else mlstm_apply
            else:
                fn = slstm_decode if decode else slstm_apply
            x, st = fn(lp[i], x, cfg, lc[i])
            new_states.append(st)
        return x, tuple(new_states) if with_cache else None

    if cfg.remat != "none" and mode == "train":
        body = jax.checkpoint(body)
    xs = stacked + (tuple(caches) if with_cache else ())
    x, new_caches = jax.lax.scan(body, x, xs,
                                 unroll=runtime_flags.scan_unroll())
    if mode == "prefill":
        x = x[:, -1:]
    logits = layers.embed_logits(
        p["embed"], rmsnorm(p["ln_f"], x, cfg.norm_eps), cfg.final_softcap)
    if with_cache:
        return logits, new_caches
    return logits, jnp.zeros((), jnp.float32)
"""Unified model registry: family -> (init, apply, init_caches)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.models import encdec, transformer, xlstm, zamba


class Model(NamedTuple):
    cfg: Any
    init: Callable            # rng -> (params, pspecs)
    apply: Callable            # (params, batch, mode=..., caches=...) -> ...
    init_caches: Callable      # (batch, max_len, src_len=None) -> caches


def build(cfg) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg,
            lambda rng: transformer.init(rng, cfg),
            lambda p, b, **kw: transformer.apply(p, b, cfg, **kw),
            lambda batch, max_len, src_len=None:
                transformer.init_caches(cfg, batch, max_len),
        )
    if fam == "xlstm":
        return Model(
            cfg,
            lambda rng: xlstm.init(rng, cfg),
            lambda p, b, **kw: xlstm.apply(p, b, cfg, **kw),
            lambda batch, max_len=None, src_len=None:
                xlstm.init_caches(cfg, batch),
        )
    if fam == "hybrid":
        return Model(
            cfg,
            lambda rng: zamba.init(rng, cfg),
            lambda p, b, **kw: zamba.apply(p, b, cfg, **kw),
            lambda batch, max_len, src_len=None:
                zamba.init_caches(cfg, batch, max_len),
        )
    if fam == "encdec":
        return Model(
            cfg,
            lambda rng: encdec.init(rng, cfg),
            lambda p, b, **kw: encdec.apply(p, b, cfg, **kw),
            lambda batch, max_len, src_len=None:
                encdec.init_caches(cfg, batch, max_len, src_len or max_len),
        )
    raise ValueError(f"unknown family {fam!r}")


def param_count(params) -> int:
    import jax

    return sum(x.size for x in jax.tree.leaves(params))


def abstract_init(mdl: Model):
    """(param ShapeDtypeStructs, pspecs) without allocating anything.
    eval_shape traces init; the spec tree (plain Python) rides a side
    channel since eval_shape outputs must be arrays."""
    import jax

    holder = {}

    def f():
        params, specs = mdl.init(jax.random.PRNGKey(0))
        holder["specs"] = specs
        return params

    params_struct = jax.eval_shape(f)
    return params_struct, holder["specs"]

"""Functional building blocks: params are nested dicts, every init returns
``(params, pspecs)`` — a param tree and a mirrored PartitionSpec tree.

Sharding convention (DESIGN.md §7): 'model' is the TP/EP axis; when
``fsdp_axis`` is set (usually 'data'), the other big dimension of each
weight is sharded over it (ZeRO-3-style 2-D sharding).  Stacked (scanned)
layer params get a leading None axis in their spec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def dt(cfg):
    return jnp.dtype(cfg.dtype)


def truncnorm(rng, shape, scale, dtype):
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def linear_init(rng, d_in, d_out, dtype, spec, bias=False, scale=None):
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": truncnorm(rng, (d_in, d_out), scale, dtype)}
    s = {"w": spec}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = P(spec[-1]) if spec != P() else P()
    return p, s


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}, {"scale": P(None)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def embed_init(rng, vocab, d, dtype, fsdp_axis):
    p = {"table": truncnorm(rng, (vocab, d), 1.0, dtype)}
    return p, {"table": P("model", fsdp_axis)}


def embed_lookup(p, tokens, scale=False):
    t = p["table"]
    y = jnp.take(t, tokens, axis=0)
    if scale:
        y = y * jnp.asarray(t.shape[1] ** 0.5, y.dtype)
    return y


def embed_logits(p, x, softcap=None):
    logits = jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# --- rotary embeddings ------------------------------------------------------
def rope_angles(positions, hd, fraction=1.0, theta=10_000.0):
    """cos/sin tables [..., hd_rot/2] for the rotated fraction of hd."""
    rot = int(hd * fraction) // 2 * 2
    freqs = theta ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, fraction=1.0):
    """x [..., S, H, hd]; cos/sin [..., S, rot/2] broadcast over heads."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1) if rot < hd else yr


# --- MLP ---------------------------------------------------------------------
def mlp_init(rng, d, ff, dtype, fsdp_axis, act="silu"):
    r1, r2, r3 = jax.random.split(rng, 3)
    p, s = {}, {}
    p["gate"], s["gate"] = linear_init(r1, d, ff, dtype, P(fsdp_axis, "model"))
    p["up"], s["up"] = linear_init(r2, d, ff, dtype, P(fsdp_axis, "model"))
    p["down"], s["down"] = linear_init(r3, ff, d, dtype, P("model", fsdp_axis))
    return p, s


def mlp(p, x, act="silu"):
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    return linear(p["down"], a(linear(p["gate"], x)) * linear(p["up"], x))


def stack_inits(rng, n, init_fn):
    """vmap an init over a leading layer axis; specs get a leading None."""
    rngs = jax.random.split(rng, n)
    p0, s0 = init_fn(rngs[0])
    stacked = jax.vmap(lambda r: init_fn(r)[0])(rngs)
    specs = jax.tree.map(lambda sp: P(None, *sp), s0,
                         is_leaf=lambda v: isinstance(v, P))
    return stacked, specs

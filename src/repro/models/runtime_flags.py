"""Global execution flags for analysis passes.

UNROLL_SCANS: when True, every lax.scan in the model zoo fully unrolls.
Used by the cost-model validation tests — XLA's cost analysis counts a
while-loop body ONCE regardless of trip count, so only unrolled HLO gives
ground-truth FLOPs.  Never enabled at real scale (HLO would explode).
"""
UNROLL_SCANS = False


def scan_unroll():
    return True if UNROLL_SCANS else 1

"""GQA attention: train/prefill (blocked, flash-style) + KV-cache decode.

Three implementations:
  * 'flash'  — the Pallas kernel (kernels/flash_attention) on TPU;
  * 'xla'    — blocked lax.scan over query chunks with an in-chunk softmax:
               never materializes the [Sq, Skv] score matrix, so the 32k
               prefill cells compile with bounded HBM (the pure-jnp flash);
  * decode   — one-position einsum over the cache (linear, no blocking).

GQA is computed grouped ('b h g q d' x 'b h k d') — no KV head repeat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import linear, linear_init
from repro.models import runtime_flags

NEG_INF = -1e30


def init(rng, cfg, fsdp_axis, cross: bool = False):
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    r = jax.random.split(rng, 4)
    dtype = layers.dt(cfg)
    p, s = {}, {}
    p["wq"], s["wq"] = linear_init(r[0], d, h * hd, dtype, P(fsdp_axis, "model"),
                                   bias=cfg.qkv_bias)
    p["wk"], s["wk"] = linear_init(r[1], d, hk * hd, dtype, P(fsdp_axis, "model"),
                                   bias=cfg.qkv_bias)
    p["wv"], s["wv"] = linear_init(r[2], d, hk * hd, dtype, P(fsdp_axis, "model"),
                                   bias=cfg.qkv_bias)
    p["wo"], s["wo"] = linear_init(r[3], h * hd, d, dtype, P("model", fsdp_axis))
    return p, s


def _split_heads(x, n):
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _sdpa_chunk(q, k, v, *, scale, softcap, causal, window, q_start, kv_len):
    """q [B,Hkv,G,Cq,hd]; k/v [B,Hkv,Skv,hd] -> out [B,Hkv,G,Cq,hd] (f32)."""
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    cq, skv = q.shape[3], k.shape[2]
    qi = q_start + jnp.arange(cq, dtype=jnp.int32)[:, None]
    kj = jnp.arange(skv, dtype=jnp.int32)[None, :]
    mask = kj < kv_len
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None, None], p, 0.0)
    return jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)


def blocked_sdpa(q, k, v, *, causal=True, window=None, softcap=None,
                 scale=None, q_chunk=512, kv_len=None):
    """q [B,Sq,H,hd], k/v [B,Skv,Hkv,hd] -> [B,Sq,H,hd] without S^2 HBM."""
    b, sq, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    g = h // hk
    if scale is None:
        scale = hd ** -0.5
    if kv_len is None:
        kv_len = skv
    kt = jnp.swapaxes(k, 1, 2)                       # [B,Hkv,Skv,hd]
    vt = jnp.swapaxes(v, 1, 2)
    qt = q.reshape(b, sq, hk, g, hd).transpose(0, 2, 3, 1, 4)  # [B,Hkv,G,Sq,hd]

    c = min(q_chunk, sq)
    if sq % c:
        c = sq  # irregular small inputs: single chunk
    n_chunks = sq // c

    def step(_, i):
        qc = jax.lax.dynamic_slice_in_dim(qt, i * c, c, axis=3)
        oc = _sdpa_chunk(qc, kt, vt, scale=scale, softcap=softcap,
                         causal=causal, window=window, q_start=i * c,
                         kv_len=kv_len)
        return None, oc

    if n_chunks == 1:
        _, o = step(None, jnp.int32(0))
        o = o[None]
    else:
        _, o = jax.lax.scan(step, None, jnp.arange(n_chunks, dtype=jnp.int32),
                            unroll=runtime_flags.scan_unroll())
    # o [n, B,Hkv,G,c,hd] -> [B,Sq,H,hd]
    o = jnp.moveaxis(o, 0, 3).reshape(b, hk, g, sq, hd)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def full_attention(q, k, v, cfg, *, causal, window, impl=None, kv_len=None):
    impl = impl or cfg.attn_impl
    if impl == "auto":
        impl = "flash" if jax.default_backend() == "tpu" else "xla"
    if impl == "flash" and q.shape[1] > 1:
        from repro.kernels.flash_attention import ops as flash_ops

        o = flash_ops.attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=causal, window=window, softcap=cfg.attn_softcap,
            impl="flash")
        return jnp.swapaxes(o, 1, 2)
    return blocked_sdpa(q, k, v, causal=causal, window=window,
                        softcap=cfg.attn_softcap, kv_len=kv_len)


def apply(p, x, cfg, *, positions, causal=True, window=None, cache=None,
          memory=None, impl=None):
    """Self- or cross-attention.

    cache: None (full-seq) or dict {k, v [B,Smax,Hkv,hd], pos scalar} for
    one-step decode (x is [B, 1, D]).  memory: encoder output for cross
    attention (keys/values come from it; no rope, no causal mask).
    """
    hk, hd = cfg.n_kv_heads, cfg.hd
    q = _split_heads(linear(p["wq"], x), cfg.n_heads)
    src = memory if memory is not None else x
    k = _split_heads(linear(p["wk"], src), hk)
    v = _split_heads(linear(p["wv"], src), hk)

    if memory is None:  # rope only for self-attention
        cos, sin = layers.rope_angles(positions, hd, cfg.rope_fraction,
                                      cfg.rope_theta)
        q = layers.apply_rope(q, cos, sin, cfg.rope_fraction)
        k = layers.apply_rope(k, cos, sin, cfg.rope_fraction)

    if cache is not None:
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), pos, axis=1)
        if x.shape[1] == 1:  # one-step decode
            new_cache = {"k": ck, "v": cv, "pos": pos + 1}
            o = decode_attention(q, ck, cv, cfg, pos=pos, window=window)
        else:                # prefill: bulk-fill cache, full causal attention
            new_cache = {"k": ck, "v": cv, "pos": pos + x.shape[1]}
            o = full_attention(q, k, v, cfg, causal=causal, window=window,
                               impl=impl)
        return linear(p["wo"], o.reshape(*x.shape[:2], -1)), new_cache

    o = full_attention(q, k, v, cfg, causal=causal, window=window, impl=impl)
    return linear(p["wo"], o.reshape(*x.shape[:2], -1)), None


def decode_attention(q, k, v, cfg, *, pos, window=None):
    """q [B,1,H,hd] vs cache k/v [B,Smax,Hkv,hd]; linear in Smax."""
    b, _, h, hd = q.shape
    smax, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, 1, hk, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    if cfg.attn_softcap is not None:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    kj = jnp.arange(smax, dtype=jnp.int32)
    mask = kj <= pos
    if window is not None:
        mask &= (pos - kj) < window
    s = jnp.where(mask[None, None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return o.reshape(b, 1, h, hd)


def init_cache(cfg, batch, max_len, dtype=None, n_kv=None):
    hk = n_kv or cfg.n_kv_heads
    dtype = dtype or layers.dt(cfg)
    return {
        "k": jnp.zeros((batch, max_len, hk, cfg.hd), dtype),
        "v": jnp.zeros((batch, max_len, hk, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
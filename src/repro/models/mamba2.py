"""Mamba2 (SSD) block on the shared chunked scalar-decay recurrence.

Mapping onto ssm_common.chunked_scan (per head h, state [N, P]):
    decay   f_t = exp(dt_t * A_h)          (A_h = -exp(A_log_h) < 0)
    k_t     = B_t * dt_t                    (dt folded into the input)
    v_t     = x_t (head slice)              q_t = C_t
    y_t     = q_t @ S_t + D_h * v_t
B/C are shared across heads (single group), x/B/C pass through a causal
depthwise conv (kernel 4) + silu, output is gated-RMSNormed and projected.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers, ssm_common
from repro.models.layers import linear, linear_init, rmsnorm


def _dims(cfg):
    di = cfg.d_model * cfg.ssm_expand
    h = cfg.ssm_heads or max(1, di // 64)
    return di, h, di // h, cfg.ssm_state


def init(rng, cfg, fsdp_axis):
    d = cfg.d_model
    di, h, pdim, n = _dims(cfg)
    conv_dim = di + 2 * n
    r = jax.random.split(rng, 4)
    dtype = layers.dt(cfg)
    p, s = {}, {}
    p["ln"], s["ln"] = layers.rmsnorm_init(d, dtype)
    p["in_proj"], s["in_proj"] = linear_init(
        r[0], d, 2 * di + 2 * n + h, dtype, P(fsdp_axis, "model"))
    p["conv_w"] = layers.truncnorm(r[1], (cfg.ssm_conv, conv_dim),
                                   cfg.ssm_conv ** -0.5, dtype)
    s["conv_w"] = P(None, "model")
    p["conv_b"] = jnp.zeros((conv_dim,), dtype)
    s["conv_b"] = P("model")
    p["a_log"] = jnp.zeros((h,), jnp.float32)
    s["a_log"] = P("model")
    p["d_skip"] = jnp.ones((h,), jnp.float32)
    s["d_skip"] = P("model")
    p["dt_bias"] = jnp.zeros((h,), jnp.float32)
    s["dt_bias"] = P("model")
    p["hn"], s["hn"] = layers.rmsnorm_init(di, dtype)
    p["out_proj"], s["out_proj"] = linear_init(r[2], di, d, dtype,
                                               P("model", fsdp_axis))
    return p, s


def _split(p, xn, cfg):
    di, h, pdim, n = _dims(cfg)
    z, xbc, dt = jnp.split(linear(p["in_proj"], xn), [di, 2 * di + 2 * n], -1)
    return z, xbc, dt


def _ssm_inputs(p, xbc, dt, cfg):
    """xbc [B,S,di+2N] (post conv+silu); dt [B,S,H] -> q,k,v,log_f."""
    di, h, pdim, n = _dims(cfg)
    b, sq = xbc.shape[:2]
    xc, bmat, cmat = jnp.split(xbc, [di, di + n], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [B,S,H]
    a = -jnp.exp(p["a_log"])
    log_f = dt * a                                                   # <= 0
    v = xc.reshape(b, sq, h, pdim)
    k = bmat[:, :, None, :] * dt[..., None].astype(bmat.dtype)       # [B,S,H,N]
    q = jnp.broadcast_to(cmat[:, :, None, :], k.shape)
    return q, k, v, log_f


def _out(p, x, y, v, z, cfg):
    di, h, pdim, n = _dims(cfg)
    b, sq = z.shape[:2]
    y = y + p["d_skip"][None, None, :, None] * v.astype(jnp.float32)
    y = y.reshape(b, sq, di).astype(x.dtype)
    y = rmsnorm(p["hn"], y, cfg.norm_eps) * jax.nn.silu(z)
    return x + linear(p["out_proj"], y)


def apply(p, x, cfg, state=None):
    """state: None (train) or (conv_state [B,K-1,conv], ScanState) for
    prefill — the returned state continues with decode()."""
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    z, xbc_pre, dt = _split(p, xn, cfg)
    xbc = jax.nn.silu(
        ssm_common.causal_conv1d(xbc_pre, p["conv_w"], p["conv_b"]))
    q, k, v, log_f = _ssm_inputs(p, xbc, dt, cfg)
    ssm_state = state[1] if state is not None else None
    y, _, new_ssm = ssm_common.chunked_scan(q, k, v, log_f,
                                            chunk=cfg.ssm_chunk,
                                            state=ssm_state)
    out = _out(p, x, y, v, z, cfg)
    if state is None:
        return out, None
    # conv state = last K-1 pre-conv inputs (prefill -> decode handoff)
    k1 = cfg.ssm_conv - 1
    padded = jnp.pad(xbc_pre, ((0, 0), (k1, 0), (0, 0)))
    return out, (padded[:, -k1:], new_ssm)


def decode(p, x, cfg, state):
    """x [B,1,D]; state = (conv_state, ScanState)."""
    conv_state, ssm_state = state
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    z, xbc, dt = _split(p, xn, cfg)
    y_c, new_conv = ssm_common.conv_decode_step(
        xbc[:, 0], conv_state, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(y_c)[:, None]
    q, k, v, log_f = _ssm_inputs(p, xbc, dt, cfg)
    y, _, new_ssm = ssm_common.decode_step(
        q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], ssm_state)
    out = _out(p, x, y[:, None], v, z, cfg)
    return out, (new_conv, new_ssm)


def init_state(cfg, batch, dtype=None):
    di, h, pdim, n = _dims(cfg)
    conv_dim = di + 2 * n
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype or layers.dt(cfg)),
        ssm_common.init_state(batch, h, n, pdim),
    )

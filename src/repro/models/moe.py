"""Mixture-of-Experts with sort-based capacity dispatch (EP over 'model').

Routing reuses the paper's own idiom — sort once, then operate on
contiguous runs (tSPM+ screens sequences exactly this way): token->expert
assignments are argsorted by expert id, each token's slot is its rank
within the expert's run, tokens beyond capacity drop (standard
token-choice).  The dense [tokens, E, capacity] one-hot dispatch tensor of
the classic einsum formulation never materializes.

Covers deepseek-moe (2 shared + 64 routed, top-6, fine-grained) and
llama4-maverick (1 shared + 128 routed, top-1).  Experts are sharded over
the 'model' axis (EP); shared experts are a plain TP MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed.sharding import current_rules
from repro.models import layers
from repro.models.layers import truncnorm


def init(rng, cfg, fsdp_axis):
    d, e, ffe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    r = jax.random.split(rng, 5)
    dtype = layers.dt(cfg)
    p = {"router": truncnorm(r[0], (d, e), d ** -0.5, jnp.float32)}
    s = {"router": P(fsdp_axis, "model")}
    p["w_gate"] = truncnorm(r[1], (e, d, ffe), d ** -0.5, dtype)
    p["w_up"] = truncnorm(r[2], (e, d, ffe), d ** -0.5, dtype)
    p["w_down"] = truncnorm(r[3], (e, ffe, d), ffe ** -0.5, dtype)
    s["w_gate"] = P("model", fsdp_axis, None)
    s["w_up"] = P("model", fsdp_axis, None)
    s["w_down"] = P("model", None, fsdp_axis)
    if cfg.n_shared_experts:
        p["shared"], s["shared"] = layers.mlp_init(
            r[4], d, cfg.n_shared_experts * ffe, dtype, fsdp_axis, cfg.mlp_act)
    return p, s


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / max(cfg.n_experts, 1))
    return max(8, -(-c // 8) * 8)


def _local_expert_ffn(xf, gate, eid, w_gate, w_up, w_down, cfg, e_base,
                      e_loc, c):
    """Sort-dispatch xf's tokens to the LOCAL expert slab [e_loc, ...].

    Same machinery as apply(), restricted to experts in
    [e_base, e_base + e_loc); non-local assignments drop out of the sort.
    Returns the partial output (zeros where tokens went elsewhere)."""
    n, d = xf.shape
    k = eid.shape[-1]
    flat_e = eid.reshape(-1).astype(jnp.int32)
    local = (flat_e >= e_base) & (flat_e < e_base + e_loc)
    key = jnp.where(local, flat_e - e_base, e_loc)       # non-local last
    order = jnp.argsort(key, stable=True)
    sorted_e = key[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(n * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = (sorted_e < e_loc) & (rank < c)
    slot = jnp.where(keep, sorted_e * c + rank, e_loc * c)
    token = (order // k).astype(jnp.int32)

    buf = jnp.zeros((e_loc * c + 1, d), xf.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xf[token], 0))
    h = buf[: e_loc * c].reshape(e_loc, c, d)
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    hg = act(jnp.einsum("ecd,edf->ecf", h, w_gate.astype(xf.dtype)))
    hu = jnp.einsum("ecd,edf->ecf", h, w_up.astype(xf.dtype))
    ho = jnp.einsum("ecf,efd->ecd", hg * hu, w_down.astype(xf.dtype))
    ho_flat = jnp.concatenate([ho.reshape(e_loc * c, d),
                               jnp.zeros((1, d), xf.dtype)], 0)
    contrib = ho_flat[slot] * gate.reshape(-1)[order][:, None].astype(xf.dtype)
    return jnp.zeros((n, d), xf.dtype).at[token].add(
        jnp.where(keep[:, None], contrib, 0))


def apply_shard_map(p, x, cfg):
    """Replicated-routing expert parallelism (manual SPMD).

    Under plain GSPMD the sort-based dispatch scatters data-sharded tokens
    into a model-sharded buffer — XLA materializes TB-scale all-reduces
    (EXPERIMENTS.md §Perf, deepseek baseline).  Here every 'model' rank
    routes its data-shard's tokens locally (router matmul is redundant
    across ranks but tiny), keeps only assignments for its OWN expert slab
    — dispatch is a local slice, the paper's sort-then-scan idiom per
    shard — and one psum over 'model' combines partial outputs.  Expert
    weights enter pre-sliced (EP), so their gradients stay local."""
    mesh, rules = current_rules()
    ma = rules["model"]
    ba = rules["batch"]
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    m_size = mesh.shape[ma]
    e_loc = e // m_size
    n = b * s

    def block(xb, router, wg, wu, wd):
        xf = xb.reshape(-1, d)
        n_loc = xf.shape[0]
        logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eid = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        c = max(8, -(-int(n_loc * k * cfg.capacity_factor / e) // 8) * 8)
        r = jax.lax.axis_index(ma)
        y_part = _local_expert_ffn(xf, gate, eid, wg, wu, wd, cfg,
                                   r * e_loc, e_loc, c)
        # combine in the activation dtype (bf16 halves the psum bytes)
        y = jax.lax.psum(y_part.astype(xb.dtype), ma)
        me = jax.lax.pmean(probs.mean(0), ba)
        fe = jax.lax.pmean(
            jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32).mean(0), ba)
        aux = cfg.router_aux_coef * e * jnp.sum(me * fe)
        return y.reshape(xb.shape), aux

    from jax.sharding import PartitionSpec as P

    y, aux = compat.shard_map(
        block, mesh=mesh,
        in_specs=(P(ba, None, None), P(None, None),
                  P(ma, None, None), P(ma, None, None), P(ma, None, None)),
        out_specs=(P(ba, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if cfg.n_shared_experts:
        y = y + layers.mlp(p["shared"], x.reshape(-1, d),
                           cfg.mlp_act).reshape(x.shape)
    return y, aux


def apply(p, x, cfg):
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    if cfg.moe_dispatch == "shard_map_ep" and current_rules() is not None \
            and cfg.n_experts and x.shape[1] > 1:
        ctx = current_rules()
        m_size = ctx[0].shape[ctx[1]["model"]] if ctx[1]["model"] else 1
        if m_size > 1 and cfg.n_experts % m_size == 0:
            return apply_shard_map(p, x, cfg)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    n = b * s
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # [N, E]
    gate, eid = jax.lax.top_k(probs, k)                        # [N, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch (the tSPM+ sort-then-scan idiom) ---
    c = _capacity(n, cfg)
    flat_e = eid.reshape(-1).astype(jnp.int32)                 # [N*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(n * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = rank < c
    slot = jnp.where(keep, sorted_e * c + rank, e * c)         # sentinel row
    token = (order // k).astype(jnp.int32)

    buf = jnp.zeros((e * c + 1, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xf[token], 0))
    h = buf[: e * c].reshape(e, c, d)

    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    hg = act(jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(x.dtype)))
    hu = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(x.dtype))
    ho = jnp.einsum("ecf,efd->ecd", hg * hu, p["w_down"].astype(x.dtype))

    ho_flat = jnp.concatenate([ho.reshape(e * c, d),
                               jnp.zeros((1, d), x.dtype)], 0)
    contrib = ho_flat[slot] * gate.reshape(-1)[order][:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[token].add(
        jnp.where(keep[:, None], contrib, 0))

    if cfg.n_shared_experts:
        y = y + layers.mlp(p["shared"], xf, cfg.mlp_act)

    # Switch-style load-balance aux loss
    me = probs.mean(0)                                          # [E]
    one_hot_top1 = jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32)
    fe = one_hot_top1.mean(0)
    aux = cfg.router_aux_coef * e * jnp.sum(me * fe)
    return y.reshape(b, s, d), aux

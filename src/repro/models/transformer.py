"""Decoder-only transformer LM: dense, MoE and VLM families.

Layers are stacked per *pattern position* and iterated with lax.scan, so
HLO size is O(pattern length), not O(n_layers).  The pattern unit captures
heterogeneous stacks statically:

  dense uniform        -> ('dense',)
  gemma2 local/global  -> ('local', 'global')
  MoE every layer      -> ('moe',)
  MoE interleave k     -> ('dense', ..., 'moe')

pixtral (family 'vlm') is this same decoder with a projected patch-embed
prefix (the ViT frontend is a stub per the assignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, fsdp_axis_for
from repro.models import attention, layers, moe
from repro.models.layers import rmsnorm
from repro.models import runtime_flags


def pattern_of(cfg) -> tuple[str, ...]:
    if cfg.local_global:
        return ("local", "global")
    if cfg.n_experts:
        if cfg.moe_interleave > 1:
            return ("dense",) * (cfg.moe_interleave - 1) + ("moe",)
        return ("moe",)
    return ("dense",)


def layer_init(rng, cfg, kind, fsdp_axis):
    r = jax.random.split(rng, 4)
    dtype = layers.dt(cfg)
    p, s = {}, {}
    p["ln1"], s["ln1"] = layers.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], s["attn"] = attention.init(r[0], cfg, fsdp_axis)
    p["ln2"], s["ln2"] = layers.rmsnorm_init(cfg.d_model, dtype)
    if kind == "moe":
        p["ffn"], s["ffn"] = moe.init(r[1], cfg, fsdp_axis)
    else:
        p["ffn"], s["ffn"] = layers.mlp_init(r[1], cfg.d_model, cfg.d_ff,
                                             dtype, fsdp_axis, cfg.mlp_act)
    if cfg.post_norms:
        p["ln1b"], s["ln1b"] = layers.rmsnorm_init(cfg.d_model, dtype)
        p["ln2b"], s["ln2b"] = layers.rmsnorm_init(cfg.d_model, dtype)
    return p, s


def layer_apply(p, x, cfg, kind, *, positions, cache=None, impl=None):
    window = cfg.sliding_window if kind == "local" else None
    h, new_cache = attention.apply(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, window=window, cache=cache, impl=impl)
    if cfg.post_norms:
        h = rmsnorm(p["ln1b"], h, cfg.norm_eps)
    x = x + h
    # sp_residual: 'seq_res' -> 'model' shards the residual stream on the
    # sequence dim between blocks (Megatron-SP): the per-block all-reduce
    # becomes reduce-scatter + all-gather at half the volume and the norms
    # run on 1/TP of the tokens.
    x = constrain(x, ("batch", "seq_res", None))
    f = rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        f, aux = moe.apply(p["ffn"], f, cfg)
    else:
        f = layers.mlp(p["ffn"], f, cfg.mlp_act)
    if cfg.post_norms:
        f = rmsnorm(p["ln2b"], f, cfg.norm_eps)
    x = x + f
    return constrain(x, ("batch", "seq_res", None)), new_cache, aux


def init(rng, cfg):
    fsdp_axis = fsdp_axis_for(cfg)
    pattern = pattern_of(cfg)
    assert cfg.n_layers % len(pattern) == 0, (cfg.n_layers, pattern)
    n_rep = cfg.n_layers // len(pattern)
    r = jax.random.split(rng, len(pattern) + 3)
    p, s = {}, {}
    p["embed"], s["embed"] = layers.embed_init(
        r[0], cfg.vocab_size, cfg.d_model, layers.dt(cfg), fsdp_axis)
    for i, kind in enumerate(pattern):
        p[f"blk{i}"], s[f"blk{i}"] = layers.stack_inits(
            r[1 + i], n_rep,
            functools.partial(layer_init, cfg=cfg, kind=kind,
                              fsdp_axis=fsdp_axis))
    p["ln_f"], s["ln_f"] = layers.rmsnorm_init(cfg.d_model, layers.dt(cfg))
    if not cfg.tie_embeddings:
        p["head"], s["head"] = layers.linear_init(
            r[-1], cfg.d_model, cfg.vocab_size, layers.dt(cfg),
            jax.sharding.PartitionSpec(fsdp_axis, "model"))
    if cfg.family == "vlm":
        p["patch_proj"], s["patch_proj"] = layers.linear_init(
            r[-2], cfg.frontend_dim, cfg.d_model, layers.dt(cfg),
            jax.sharding.PartitionSpec(None, fsdp_axis))
    return p, s


def _embed_inputs(p, batch, cfg):
    x = layers.embed_lookup(p["embed"], batch["tokens"], cfg.embed_scale)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = layers.linear(p["patch_proj"],
                                batch["patch_embeds"].astype(x.dtype))
        x = jnp.concatenate([patches, x], axis=1)
    return x


def _logits(p, x, cfg):
    x = rmsnorm(p["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return layers.embed_logits(p["embed"], x, cfg.final_softcap)
    logits = layers.linear(p["head"], x)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def _scan_layers(p, x, cfg, *, positions, caches=None, impl=None):
    pattern = pattern_of(cfg)
    n_rep = cfg.n_layers // len(pattern)
    stacked = tuple(p[f"blk{i}"] for i in range(len(pattern)))

    def body(carry, xs):
        x, aux = carry
        lp = xs[: len(pattern)]
        lc = xs[len(pattern):] if caches is not None else [None] * len(pattern)
        new_cs = []
        for i, kind in enumerate(pattern):
            x, nc, a = layer_apply(lp[i], x, cfg, kind, positions=positions,
                                   cache=lc[i], impl=impl)
            aux = aux + a
            new_cs.append(nc)
        out = tuple(new_cs) if caches is not None else None
        return (x, aux), out

    if cfg.remat != "none" and caches is None:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    xs = stacked + tuple(caches) if caches is not None else stacked
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        xs, unroll=runtime_flags.scan_unroll())
    return x, aux, new_caches


def apply(p, batch, cfg, *, mode="train", caches=None):
    """mode 'train': full-sequence (logits, aux).
    mode 'prefill': caches required (empty) -> (logits, new_caches).
    mode 'decode': batch['tokens'] is [B, 1], caches -> (logits, new_caches).
    """
    x = _embed_inputs(p, batch, cfg)
    b, s = x.shape[:2]
    if mode == "decode":
        pos = caches[0]["pos"][0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        x, _, new_caches = _scan_layers(p, x, cfg, positions=positions,
                                        caches=caches)
        return _logits(p, x, cfg), new_caches
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(x, ("batch", None, None))
    if mode == "prefill":
        x, _, new_caches = _scan_layers(p, x, cfg, positions=positions,
                                        caches=caches)
        # serving prefill only needs next-token logits (saves a [B,S,V])
        return _logits(p, x[:, -1:], cfg), new_caches
    x, aux, _ = _scan_layers(p, x, cfg, positions=positions)
    return _logits(p, x, cfg), aux


def init_caches(cfg, batch, max_len, dtype=None):
    """Per pattern position: stacked [n_rep, ...] cache trees (scan xs)."""
    pattern = pattern_of(cfg)
    n_rep = cfg.n_layers // len(pattern)
    caches = []
    for _ in pattern:
        one = attention.init_cache(cfg, batch, max_len, dtype)
        caches.append({
            "k": jnp.zeros((n_rep,) + one["k"].shape, one["k"].dtype),
            "v": jnp.zeros((n_rep,) + one["v"].shape, one["v"].dtype),
            "pos": jnp.zeros((n_rep,), jnp.int32),
        })
    return tuple(caches)  # tuple: matches the scan's output structure
"""repro — tSPM+ (transitive sequential pattern mining) as a JAX/TPU framework.

The paper's sequence ids are 64-bit packed integers, so the whole package
runs with x64 enabled.  All model / kernel code specifies dtypes explicitly
(bf16 / f32 / i32) and is unaffected by the default-width change.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"

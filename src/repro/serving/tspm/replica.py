"""Snapshot-isolated read replicas of mining state.

A :class:`ReadReplica` sits between a :class:`~repro.api.session.MiningSession`
and the query path.  At every tick boundary (a typed ``TickCompleted``
subscription on the service — see :mod:`repro.stream.events`) it
*publishes* a fresh :class:`ReplicaView` — an immutable bundle of
the snapshot frame, its ``snapshot_version``, its tick count, and the
feature-store presence matrix folded at the same boundary — and swaps it in
as the front view with one reference assignment.  Double buffering falls
out of that discipline: the next view is assembled off to the side while
readers keep using the current one, so

  * queries never block ``submit``/``tick`` (they only ever *read* the
    front reference and the immutable arrays behind it), and
  * queries never observe a half-applied tick (the hook runs after
    ``tick_finish`` has fully appended the wave, and ``snapshot()`` gathers
    into fresh arrays that later ticks never touch).

A view also lazily materializes the padded *evaluation columns* the batched
wave kernel consumes — per-row start/end phenX, duration, and the screen
statistic (exact support or hash-bucket count, matching the frame's screen
mode) — padded to a power-of-two row count so heterogeneous snapshots reuse
compiled kernel shapes, the same geometric-shape discipline the streaming
store uses to bound retraces.
"""
from __future__ import annotations

import threading
from typing import NamedTuple

import numpy as np

from repro.core import queries, sparsity


def _pow2(n: int, floor: int = 1024) -> int:
    """Smallest power of two >= n (>= floor) — quantizes kernel shapes."""
    out = floor
    while out < n:
        out *= 2
    return out


class EvalColumns(NamedTuple):
    """Padded per-row predicate inputs for the batched kernel."""

    start: np.ndarray   # [Npad] int32 start phenX (fuse-aware)
    end: np.ndarray     # [Npad] int32 end phenX
    dur: np.ndarray     # [Npad] int32 duration
    screen: np.ndarray  # [Npad] int32 support or bucket count (per mode)
    valid: np.ndarray   # [Npad] bool, False on padding rows
    n_rows: int         # real (unpadded) row count


class ReplicaView:
    """One published, immutable snapshot of mining state.

    ``frame`` is a plain :class:`SequenceFrame` over the snapshot corpus —
    the conformance oracle *and* the host evaluator for barrier ops;
    ``version``/``tick`` identify the publication (the result-cache key and
    the staleness basis); ``feature_x`` is the feature store's presence
    matrix as of this tick (point-in-time consistent with the corpus).
    """

    __slots__ = ("frame", "version", "tick", "feature_x", "_cols", "_lock",
                 "pred_cache")

    def __init__(self, frame, version: int, tick: int, feature_x=None):
        self.frame = frame
        self.version = version
        self.tick = tick
        self.feature_x = feature_x
        self._cols: EvalColumns | None = None
        self._lock = threading.Lock()
        # (kind, arg) -> [Npad] bool predicate row, filled by the server's
        # wave kernel.  Lock-free: rows are deterministic functions of the
        # immutable columns, so a racing double-compute stores equal bytes
        self.pred_cache: dict[tuple, np.ndarray] = {}

    @property
    def n_rows(self) -> int:
        return len(self.frame._corpus)

    def columns(self) -> EvalColumns:
        """The padded evaluation columns, built once per view (thread-safe:
        concurrent query waves double-check under the view lock)."""
        if self._cols is None:
            with self._lock:
                if self._cols is None:
                    self._cols = self._build_columns()
        return self._cols

    def _build_columns(self) -> EvalColumns:
        fr = self.frame
        c = fr._corpus
        n = len(c)
        npad = _pow2(max(n, 1))
        s, e = queries.unpack_seq(c.seq, fr.codec, fused=fr.fuse_duration)
        if fr.screen_mode in ("hash", "fused"):
            # same statistic the frame's screen op reads: the shared
            # bucket-count table, gathered per row
            h = np.asarray(sparsity.hash_bucket(c.seq, c.n_buckets_log2))
            scr = np.asarray(c.counts())[h].astype(np.int32)
        else:
            scr = c.support()

        def pad(a, dtype):
            out = np.zeros(npad, dtype)
            out[:n] = np.asarray(a, dtype)
            return out

        valid = np.zeros(npad, bool)
        valid[:n] = True
        return EvalColumns(pad(s, np.int32), pad(e, np.int32),
                           pad(c.dur, np.int32), pad(scr, np.int32),
                           valid, n)


class ReadReplica:
    """Double-buffered front/back publication of session state.

    Writers (the ingest thread's tick hook, or an explicit ``publish()``)
    assemble the next view under ``_pub_lock`` — the back buffer — then
    install it as ``_front`` with a single reference store.  Readers call
    :meth:`view` with no lock at all.
    """

    def __init__(self, session, feature_store=None):
        self.session = session
        self.feature_store = feature_store
        self._front: ReplicaView | None = None
        self._pub_lock = threading.Lock()
        self.published = 0   # publication count (plain int; obs-agnostic)

    def view(self) -> ReplicaView:
        """The current front view (publishing one first if none exists)."""
        v = self._front
        if v is None:
            v = self.publish()
        return v

    def publish(self) -> ReplicaView:
        """Assemble and atomically install a fresh view of the session's
        current state.  Cheap at publish time: the frame's canonical
        lexsort and the kernel columns are lazy, paid by the first query
        against the view — off the ingest thread."""
        with self._pub_lock:
            svc = self.session.service
            frame = self.session.frame()
            version = svc.snapshot_version if svc is not None else 0
            tick = svc.n_ticks if svc is not None else 0
            fx = (self.feature_store.fold()
                  if self.feature_store is not None else None)
            view = ReplicaView(frame, version, tick, feature_x=fx)
            self.published += 1
            self._front = view
            return view

    def staleness_ticks(self) -> int:
        """Ticks the front view lags the live service (0 for batch/fresh)."""
        svc = self.session.service
        v = self._front
        if svc is None or v is None:
            return 0
        return max(0, svc.n_ticks - v.tick)


def uncompacted_rows(session) -> tuple[np.ndarray, np.ndarray]:
    """(seq, patient-key) rows for feature-store bootstrap.

    Live services hand back the *uncompacted* snapshot with pids translated
    to original integer keys — bootstrapping from a fused-compacted frame
    would silently drop rows of ids below today's threshold that later
    ticks push over it.  Batch sessions return the fitted frame's corpus
    (exact even when fused: a batch fit's counts are frozen, so its
    survivor set can never grow).  Non-integer patient keys are rejected —
    the presence matrix is indexed by key.
    """
    svc = session.service
    if svc is None:
        c = session.frame()._corpus
        return c.seq, c.patient.astype(np.int64)
    from repro.stream.shard import ShardedStreamService
    if isinstance(svc, ShardedStreamService):
        p2k = svc.pid_to_key()
    else:
        p2k = {pid: k for k, pid in svc.store.pids.items()}
    if not all(isinstance(k, (int, np.integer)) for k in p2k.values()):
        raise TypeError("the streaming feature store needs integer patient "
                        "keys (the presence matrix is indexed by key); "
                        "serve without feature_ids for keyed cohorts")
    snap = svc.snapshot()
    if not p2k:
        return snap.seq, np.asarray(snap.patient, np.int64)
    lut = np.full(max(p2k) + 1, -1, np.int64)
    for pid, key in p2k.items():
        lut[pid] = key
    return snap.seq, lut[np.asarray(snap.patient)]

"""LRU result cache keyed on (canonical plan, snapshot version).

Correctness comes entirely from the key: a keep mask is a pure function of
the canonical plan and the immutable snapshot it ran against, so an entry
can never serve stale data — a new publication simply stops matching.
:meth:`ResultCache.invalidate_below` is therefore garbage collection, not
a correctness mechanism: the server calls it at publication to drop
entries no future lookup can hit.

Counters are plain ints (the server mirrors them into the obs registry),
so hit-ratio accounting works with telemetry disabled.  Thread safety is a
single lock around the OrderedDict — lookups are dwarfed by evaluation.
"""
from __future__ import annotations

import threading
from collections import OrderedDict


class ResultCache:
    """Bounded LRU of ``(plan_key, version) -> keep mask``."""

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._od: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._od)

    def get(self, key):
        with self._lock:
            try:
                v = self._od.pop(key)
            except KeyError:
                self.misses += 1
                return None
            self._od[key] = v      # re-append: most recently used
            self.hits += 1
            return v

    def put(self, key, value) -> None:
        with self._lock:
            self._od.pop(key, None)
            self._od[key] = value
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)
                self.evictions += 1

    def invalidate_below(self, version: int) -> int:
        """Drop entries for snapshots older than ``version`` (called at
        publication; superseded views can never be queried again).
        Returns the number of entries dropped."""
        with self._lock:
            stale = [k for k in self._od if k[1] < version]
            for k in stale:
                del self._od[k]
            return len(stale)

    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

"""tSPM+ query-serving front end (the read path).

Layers (see each module's docstring):

  * :mod:`~repro.serving.tspm.plan`     — typed, canonicalized plan IR;
  * :mod:`~repro.serving.tspm.replica`  — snapshot-isolated read replicas,
    double-buffered at tick boundaries;
  * :mod:`~repro.serving.tspm.cache`    — LRU result cache keyed on
    (canonical plan, snapshot version);
  * :mod:`~repro.serving.tspm.features` — streaming per-patient feature
    store, point-in-time consistent with each view;
  * :mod:`~repro.serving.tspm.server`   — the batched QueryServer façade
    (``session.serve()``).
"""
from repro.serving.tspm.cache import ResultCache
from repro.serving.tspm.features import FeatureStore
from repro.serving.tspm.plan import BARRIER_OPS, VECTOR_OPS, QueryPlan, plan
from repro.serving.tspm.replica import (EvalColumns, ReadReplica,
                                        ReplicaView, uncompacted_rows)
from repro.serving.tspm.server import QueryResult, QueryServer, Ticket

__all__ = [
    "BARRIER_OPS", "VECTOR_OPS", "QueryPlan", "plan",
    "ReadReplica", "ReplicaView", "EvalColumns", "uncompacted_rows",
    "ResultCache", "FeatureStore",
    "QueryServer", "QueryResult", "Ticket",
]

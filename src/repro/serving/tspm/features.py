"""Streaming feature store: per-patient presence vectors, tick-consistent.

Maintains the ``SequenceFrame.to_features(feature_ids=...)`` presence
matrix *incrementally*: every tick's freshly-mined rows arrive through the
service's delta hook, are matched against the (sorted) feature-id list by
binary search, and staged; at publication the replica folds the staging
buffer into a copy-on-write boolean matrix that is captured *into* the
published view.  Queries against a view therefore see the features of
exactly that view's tick — point-in-time consistent with its corpus — and
the matrices handed to past views are never mutated again.

Exactness argument (property-tested in tests/test_serving.py):

  * presence is monotone — a mined (patient, seq) row never un-happens, so
    OR-ing delta hits into the matrix equals recomputing presence over the
    full corpus at every tick;
  * for ``screen='fused'`` frames the corpus is compacted to hash-screen
    survivors, but survival is per-*id* and determined solely by the
    bucket-count table, so presence over survivors equals raw presence
    with a per-feature column mask ``counts[hash(id)] >= threshold`` —
    applied at matrix build time against the view's own table.

Scope: the store tracks the full mined-row feed — rows mined by ticks
(the delta hook), the bootstrap snapshot taken when serving starts, and
rows arriving with migration-admitted patients (the ``Migrated`` event
with ``src=None`` carries the admitted state; ``on_admitted`` stages its
already-mined corpus rows, which never appear in any tick feed).
Patients extracted from a live service keep their accumulated features —
presence is append-only.  Internal shard-to-shard migrations need no
handling: their rows were already staged by past tick feeds.
"""
from __future__ import annotations

import threading

import jax.numpy as jnp
import numpy as np

from repro.core import msmr, sparsity
from repro.core.encoding import SENTINEL


class FeatureStore:
    """Incrementally-maintained patient x feature presence matrix.

    ``feature_ids`` must be sorted strictly increasing int64 (the same
    contract ``msmr.feature_matrix`` binary-searches against).  Rows are
    indexed by the *original integer patient key*, matching the patient
    column of session frames over int-keyed cohorts.
    """

    def __init__(self, feature_ids):
        ids = np.asarray(feature_ids, np.int64).reshape(-1)
        if len(ids) and np.any(np.diff(ids) <= 0):
            raise ValueError("feature_ids must be sorted strictly "
                             "increasing (msmr binary-search contract)")
        self.feature_ids = ids
        self._x = np.zeros((0, len(ids)), bool)
        self._staging: list[tuple[np.ndarray, np.ndarray]] = []
        self._lock = threading.Lock()

    # --- ingest side --------------------------------------------------------
    def stage_rows(self, patient_keys, seq) -> None:
        """Stage aligned (patient key, mined seq id) rows for the next fold
        (used for bootstrap and by the delta hook)."""
        k = self.feature_ids
        seq = np.asarray(seq, np.int64).reshape(-1)
        if len(k) == 0 or len(seq) == 0:
            return
        keys = np.asarray(patient_keys).reshape(-1)
        if not np.issubdtype(keys.dtype, np.integer):
            raise TypeError("feature store requires integer patient keys; "
                            f"got dtype {keys.dtype}")
        idx = np.clip(np.searchsorted(k, seq), 0, len(k) - 1)
        hit = k[idx] == seq
        if not hit.any():
            return
        with self._lock:
            self._staging.append((keys[hit].astype(np.int64), idx[hit]))

    def on_delta(self, keys, slot_idx, seq, dur) -> None:
        """StreamService delta subscriber: ``keys`` are the wave's patient
        keys, ``slot_idx`` maps each mined row to its wave slot."""
        if len(self.feature_ids) == 0 or len(seq) == 0:
            return
        keys = np.asarray(keys)
        if not np.issubdtype(keys.dtype, np.integer):
            raise TypeError("feature store requires integer patient keys; "
                            f"got dtype {keys.dtype}")
        self.stage_rows(keys[np.asarray(slot_idx)], seq)

    def on_admitted(self, state) -> None:
        """Migration-admit subscriber (``Migrated`` with ``src=None``):
        stage the admitted patient's already-mined corpus rows — they
        predate this cohort's ticks, so no tick feed will ever carry
        them."""
        seq = np.asarray(state.corpus_seq, np.int64).reshape(-1)
        if len(self.feature_ids) == 0 or len(seq) == 0:
            return
        self.stage_rows(np.full(len(seq), state.key), seq)

    def fold(self) -> np.ndarray:
        """Fold staged deltas into a fresh matrix and return it.

        Copy-on-write: the returned array is never mutated by later folds,
        so views capture it by reference.  Row capacity grows
        geometrically, like every other streaming plane."""
        with self._lock:
            staged, self._staging = self._staging, []
        if staged:
            rows = np.concatenate([r for r, _ in staged])
            cols = np.concatenate([c for _, c in staged])
            need = int(rows.max()) + 1
            x = self._x
            if need > len(x):
                cap = max(need, 2 * len(x), 64)
                grown = np.zeros((cap, x.shape[1]), bool)
                grown[:len(x)] = x
                x = grown
            else:
                x = x.copy()
            x[rows, cols] = True
            self._x = x
        return self._x

    # --- read side ----------------------------------------------------------
    def matrix(self, view) -> msmr.FeatureMatrix:
        """The feature matrix of a published view — byte-identical to
        ``view.frame.to_features(feature_ids=self.feature_ids)``.

        Fused frames get the per-feature survival column mask from the
        view's own bucket-count table (see module docstring); everything
        else is a float32 cast of the captured presence rows."""
        fr = view.frame
        k = self.feature_ids
        n_patients = fr.n_patients
        ids = jnp.asarray(k)
        if len(k) == 0 or n_patients == 0:
            return msmr.FeatureMatrix(
                jnp.zeros((n_patients, len(k)), jnp.float32),
                ids, jnp.asarray(len(k)))
        out = np.zeros((n_patients, len(k)), np.float32)
        x = view.feature_x
        if x is not None and len(x):
            m = min(n_patients, len(x))
            out[:m] = x[:m]
        if fr.screen_mode == "fused":
            h = np.asarray(sparsity.hash_bucket(k, fr._corpus.n_buckets_log2))
            col_keep = np.asarray(fr._corpus.counts())[h] >= fr.threshold
            out *= col_keep
        return msmr.FeatureMatrix(jnp.asarray(out), ids,
                                  jnp.sum(ids != SENTINEL))

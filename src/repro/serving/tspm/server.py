"""QueryServer: batched, cached query evaluation over read replicas.

The serving front end for mined corpora.  Clients hand in
:class:`~repro.serving.tspm.plan.QueryPlan` chains; the server evaluates
them against the replica's current immutable view in fixed-size *waves* —
the admission idiom of the LM wave scheduler in ``serving/engine.py``,
retargeted from token steps to mask programs:

  * every plan's canonical vectorizable prefix is compiled to a tiny
    opcode/argument program (SCREEN / STARTS / ENDS / MINDUR descriptors);
  * the wave's distinct descriptors not yet in the view's predicate-row
    cache are evaluated by ONE jitted, vmapped kernel dispatch (padded to
    the fixed batch size), and each plan's mask is the AND of its rows —
    at most one dispatch per wave instead of 2-4 per query, and zero once
    the view's working set of predicates is warm, which is where the
    batched p99 win comes from;
  * barrier suffixes (``transitive_ends_with`` / ``top_k``) are evaluated
    by injecting the batched prefix mask into a real ``SequenceFrame``
    chain on the view, so their semantics *cannot* drift from the frame's.

Results are keep masks cached in an LRU keyed on (canonical plan,
snapshot version) and wrapped in :class:`QueryResult` — a lazy frame over
the view the query actually ran against, so terminals (``collect``,
``decode``, ``to_features``) are point-in-time consistent even if the
live session has since ticked past the view.

Synchronous paths (``query`` / ``query_batch``) evaluate inline; the
background loop (``start`` / ``submit`` / ``stop``) drains a queue into
waves so concurrent clients share kernel dispatches.  All serving state
updates flow into ``serve.*`` metrics and ``serve.wait`` / ``serve.eval``
spans on the session's telemetry (no-ops when disabled).
"""
from __future__ import annotations

import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.tspm.cache import ResultCache
from repro.serving.tspm.features import FeatureStore
from repro.serving.tspm.plan import QueryPlan
from repro.serving.tspm.replica import (ReadReplica, _pow2,
                                        uncompacted_rows)
from repro.stream.events import Migrated, TickCompleted

# wave-program opcodes (0 rows are padding: keep passes through unchanged)
_OP_NOOP, _OP_SCREEN, _OP_STARTS, _OP_ENDS, _OP_MINDUR = range(5)
_OP_CODE = {"screen": _OP_SCREEN, "starts_with": _OP_STARTS,
            "ends_with": _OP_ENDS, "min_duration": _OP_MINDUR}


@jax.jit
def _pred_kernel(start, end, dur, screen, codes, args):
    """Evaluate [P] predicate descriptors over [N] corpus columns in one
    vmapped dispatch: row p is the boolean mask of descriptor
    ``(codes[p], args[p])``.  NOOP (padding) rows come back all-True.

    Shapes are padded (N and P to powers of two), so heterogeneous waves
    reuse a handful of compiled variants; the wave evaluator only runs
    this for descriptors missing from the view's predicate-row cache, so
    steady-state waves dispatch nothing at all.
    """
    def one(code, arg):
        return jnp.select(
            [code == _OP_SCREEN, code == _OP_STARTS,
             code == _OP_ENDS, code == _OP_MINDUR],
            [screen >= arg, start == arg, end == arg, dur >= arg],
            default=jnp.ones_like(start, bool))
    return jax.vmap(one)(codes, args)


_STOP = object()


class QueryResult:
    """One evaluated plan: the keep mask plus the view it ran against.

    ``frame`` lazily rebuilds a :class:`SequenceFrame` with the served
    mask injected, so every frame terminal works on the result —
    evaluated against the query's snapshot, not today's corpus.
    """

    __slots__ = ("view", "keep", "_frame")

    def __init__(self, view, keep: np.ndarray):
        self.view = view
        self.keep = keep
        self._frame = None

    @property
    def frame(self):
        if self._frame is None:
            keep = self.keep
            self._frame = self.view.frame._chain(
                ("served", lambda fr, k, keep=keep: k & keep))
        return self._frame

    @property
    def n_kept(self) -> int:
        return int(self.keep.sum())

    def collect(self):
        return self.frame.collect()

    def unique(self):
        return self.frame.unique()

    def decode(self, limit=None):
        return self.frame.decode(limit)

    def to_features(self, k=None, feature_ids=None):
        return self.frame.to_features(k, feature_ids=feature_ids)

    def __repr__(self):
        return (f"QueryResult({self.n_kept:,}/{self.view.n_rows:,} rows, "
                f"tick={self.view.tick})")


class Ticket:
    """A submitted query's future; ``result()`` blocks for the wave."""

    __slots__ = ("plan", "t_submit", "_event", "_result", "_error")

    def __init__(self, plan: QueryPlan):
        self.plan = plan
        self.t_submit = time.perf_counter()
        self._event = threading.Event()
        self._result = None
        self._error = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> QueryResult:
        if not self._event.wait(timeout):
            raise TimeoutError("query still queued; is the server running?")
        if self._error is not None:
            raise self._error
        return self._result


class QueryServer:
    """Serving façade over one :class:`MiningSession` (see module doc).

    Built by ``session.serve(...)``.  Construction wires the replica to
    the live service's tick hook (``auto_publish``) and, when
    ``feature_ids`` is given, bootstraps + subscribes the streaming
    feature store; do it from the ingest thread (no concurrent ticks).
    """

    def __init__(self, session, *, batch_size: int = 32,
                 cache_entries: int = 1024, feature_ids=None,
                 auto_publish: bool = True):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.session = session
        self.batch_size = int(batch_size)
        self.default_threshold = session.config.threshold

        tel = session.telemetry
        self._tracer = tel.tracer
        m = tel.metrics
        self._m_queries = m.counter("serve.queries")
        self._m_waves = m.counter("serve.waves")
        self._m_occupancy = m.histogram("serve.batch_occupancy")
        self._m_hits = m.counter("serve.cache.hits")
        self._m_misses = m.counter("serve.cache.misses")
        self._m_evictions = m.counter("serve.cache.evictions")
        self._m_hit_ratio = m.gauge("serve.cache.hit_ratio")
        self._m_staleness = m.gauge("serve.replica_staleness_ticks")
        self._m_wait = m.histogram("serve.wait_s")
        self._m_eval = m.histogram("serve.eval_s")

        self.cache = ResultCache(cache_entries)
        self._prev_hits = self._prev_misses = self._prev_evictions = 0
        self.feature_store = (FeatureStore(feature_ids)
                              if feature_ids is not None else None)
        self.replica = ReadReplica(session, feature_store=self.feature_store)
        self._auto_publish = bool(auto_publish)
        if self.feature_store is not None:
            seq, pkeys = uncompacted_rows(session)
            self.feature_store.stage_rows(pkeys, seq)
        svc = session.service
        if svc is not None:
            # one typed subscription covers both concerns: TickCompleted
            # carries the delta feed + publication boundary; Migrated
            # (src=None: external admit) carries already-mined rows that
            # never flow through any tick feed
            kinds = ([TickCompleted, Migrated]
                     if self.feature_store is not None
                     else [TickCompleted] if auto_publish else [])
            if kinds:
                svc.subscribe(self._on_event, kinds=tuple(kinds))
        self.replica.publish()

        self._eval_lock = threading.Lock()
        self._queue: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        self._running = False
        self._n_queries = 0
        self._n_waves = 0

    # --- publication --------------------------------------------------------
    def _on_event(self, ev) -> None:
        """Typed event subscriber (see :mod:`repro.stream.events`)."""
        if isinstance(ev, TickCompleted):
            if self.feature_store is not None:
                self.feature_store.on_delta(ev.keys, ev.slot_idx,
                                            ev.seq, ev.dur)
            if self._auto_publish:
                self.publish()
        elif isinstance(ev, Migrated) and ev.src is None \
                and ev.state is not None and self.feature_store is not None:
            self.feature_store.on_admitted(ev.state)

    def publish(self):
        """Publish a fresh view and garbage-collect superseded cache
        entries.  Called automatically at tick boundaries."""
        view = self.replica.publish()
        self.cache.invalidate_below(view.version)
        self._m_staleness.set(0)
        return view

    def view(self):
        return self.replica.view()

    # --- synchronous evaluation ---------------------------------------------
    def query(self, p: QueryPlan) -> QueryResult:
        return self.query_batch([p])[0]

    def query_batch(self, plans) -> list[QueryResult]:
        plans = [self._resolve(p) for p in plans]
        return self._eval_wave(self.replica.view(), plans)

    def _resolve(self, p) -> QueryPlan:
        if not isinstance(p, QueryPlan):
            raise TypeError(f"expected a QueryPlan, got {type(p).__name__}")
        return p.resolve(self.default_threshold)

    # --- wave evaluation ----------------------------------------------------
    def _eval_wave(self, view, plans) -> list[QueryResult]:
        t0 = time.perf_counter()
        sp = self._tracer.begin("serve.eval", cat="host", track="serve",
                                n=len(plans))
        with self._eval_lock:
            keys = [p.canonical() for p in plans]
            masks: dict[tuple, np.ndarray] = {}
            need: dict[tuple, QueryPlan] = {}
            for p, key in zip(plans, keys):
                if key in need:
                    continue       # intra-wave duplicate: evaluate once
                got = self.cache.get((key, view.version))
                if got is not None:
                    masks[key] = got
                else:
                    need[key] = p
            miss = list(need.items())
            for i0 in range(0, len(miss), self.batch_size):
                chunk = miss[i0:i0 + self.batch_size]
                self._m_occupancy.observe(len(chunk) / self.batch_size)
                self._n_waves += 1
                self._m_waves.inc()
                for key, keep in self._eval_chunk(view, chunk):
                    masks[key] = keep
                    self.cache.put((key, view.version), keep)
            out = [QueryResult(view, masks[k]) for k in keys]
        self._tracer.finish(sp)
        self._m_eval.observe(time.perf_counter() - t0)
        self._n_queries += len(plans)
        self._m_queries.inc(len(plans))
        self._m_staleness.set(self.replica.staleness_ticks())
        self._sync_cache_metrics()
        return out

    def _eval_chunk(self, view, chunk):
        """Evaluate up to ``batch_size`` distinct (key, plan) pairs.

        The wave's distinct predicate descriptors missing from the view's
        predicate-row cache go through ONE vmapped kernel dispatch (padded
        to the batch size); each plan's mask is then the AND of its cached
        rows — work scales with *new* predicates, not with the dense
        ``B x L x N`` the padded wave would cost.  Barrier suffixes run
        through real frame chaining."""
        cols = view.columns()
        n = cols.n_rows
        cache = view.pred_cache
        progs = [(key, *p.split_canonical()) for key, p in chunk]
        missing = list({d for _, vec, _ in progs for d in vec} - cache.keys())
        for i0 in range(0, len(missing), self.batch_size):
            batch = missing[i0:i0 + self.batch_size]
            codes = np.zeros(self.batch_size, np.int32)
            args = np.zeros(self.batch_size, np.int32)
            for i, (kind, arg) in enumerate(batch):
                codes[i] = _OP_CODE[kind]
                args[i] = arg
            rows = np.asarray(_pred_kernel(
                cols.start, cols.end, cols.dur, cols.screen, codes, args))
            for i, d in enumerate(batch):
                cache[d] = rows[i]
        out = []
        valid_n = cols.valid[:n]
        for key, vec, suffix in progs:
            if vec:
                keep = valid_n & np.logical_and.reduce(
                    [cache[d][:n] for d in vec])
            else:
                keep = None
            if suffix:
                keep = self._apply_suffix(view, keep, suffix)
            elif keep is None:
                keep = np.ones(n, bool)
            out.append((key, keep))
        return out

    def _apply_suffix(self, view, prefix_keep, suffix) -> np.ndarray:
        """Barrier ops run through the real frame chain — the batched
        prefix mask is injected as one AND op, then the frame's own
        transitive_ends_with / top_k do the rest (byte-identical by
        construction)."""
        fr = view.frame
        if prefix_keep is not None:
            pk = prefix_keep
            fr = fr._chain(("served_prefix", lambda f, k, pk=pk: k & pk))
        for kind, arg in suffix:
            fr = getattr(fr, kind)(arg)
        return fr.keep_mask()

    def _sync_cache_metrics(self) -> None:
        c = self.cache
        self._m_hits.inc(c.hits - self._prev_hits)
        self._m_misses.inc(c.misses - self._prev_misses)
        self._m_evictions.inc(c.evictions - self._prev_evictions)
        self._prev_hits, self._prev_misses = c.hits, c.misses
        self._prev_evictions = c.evictions
        self._m_hit_ratio.set(c.hit_ratio())

    # --- background serving loop --------------------------------------------
    def submit(self, p: QueryPlan) -> Ticket:
        """Queue a plan for the next wave; starts the loop on first use."""
        t = Ticket(self._resolve(p))
        if self._thread is None:
            self.start()
        self._queue.put(t)
        return t

    def start(self) -> "QueryServer":
        if self._thread is None:
            self._running = True
            self._thread = threading.Thread(
                target=self._serve_loop, name="tspm-query-server",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._running = False
        self._queue.put(_STOP)
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "QueryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _serve_loop(self) -> None:
        while True:
            sp = self._tracer.begin("serve.wait", cat="host", track="serve")
            try:
                first = self._queue.get(timeout=0.1)
            except queue.Empty:
                self._tracer.finish(sp)
                if not self._running:
                    return
                continue
            stop = first is _STOP
            wave = [] if stop else [first]
            while not stop and len(wave) < self.batch_size:
                try:
                    t = self._queue.get_nowait()
                except queue.Empty:
                    break
                if t is _STOP:
                    stop = True
                    break
                wave.append(t)
            self._tracer.finish(sp, n=len(wave))
            if wave:
                now = time.perf_counter()
                for t in wave:
                    self._m_wait.observe(now - t.t_submit)
                try:
                    res = self._eval_wave(self.replica.view(),
                                          [t.plan for t in wave])
                    for t, r in zip(wave, res):
                        t._result = r
                        t._event.set()
                except BaseException as ex:   # surface on every ticket
                    for t in wave:
                        t._error = ex
                        t._event.set()
            if stop:
                return

    # --- feature serving / introspection ------------------------------------
    def features(self):
        """The streaming feature matrix of the current view (byte-identical
        to ``view.frame.to_features(feature_ids=...)`` on the snapshot)."""
        if self.feature_store is None:
            raise RuntimeError("server built without feature_ids; pass "
                               "session.serve(feature_ids=[...]) to stream "
                               "features")
        return self.feature_store.matrix(self.replica.view())

    def stats(self) -> dict:
        """Plain-number serving stats (works with telemetry disabled)."""
        c = self.cache
        return {"queries": self._n_queries,
                "waves": self._n_waves,
                "cache_hits": c.hits,
                "cache_misses": c.misses,
                "cache_evictions": c.evictions,
                "cache_hit_ratio": c.hit_ratio(),
                "cache_entries": len(c),
                "views_published": self.replica.published,
                "staleness_ticks": self.replica.staleness_ticks()}

"""Typed query-plan IR: canonicalized, hashable mask chains.

A :class:`QueryPlan` is the serving-side mirror of a ``SequenceFrame``
op chain — the same screen / starts_with / ends_with / min_duration /
transitive_ends_with / top_k vocabulary, but as plain data: a tuple of
``(kind, arg)`` ops that can be hashed (the LRU cache key), batched
(the vmapped wave evaluator), and replayed against a frame (the
conformance oracle, :meth:`QueryPlan.apply`).

Canonicalization exploits the algebra of the ops.  The four *predicate*
ops (``VECTOR_OPS``) are pure per-row tests AND-ed into the keep mask —
``screen`` included: both the sorted-support and hash-bucket screens
compute their predicate from the corpus alone, never from the
accumulated keep — so within a run they commute and are idempotent.
``transitive_ends_with`` and ``top_k`` read the accumulated keep
(``BARRIER_OPS``), so they pin the runs around them in place.  Canonical
form sorts and dedups each predicate run between barriers, which makes
``.starts_with(x).min_duration(d)`` and ``.min_duration(d).starts_with(x)``
one cache entry and one batched program — provably the same mask.
"""
from __future__ import annotations

import dataclasses

#: keep-independent per-row predicates: vectorizable, commuting, idempotent
VECTOR_OPS = ("screen", "starts_with", "ends_with", "min_duration")
#: keep-dependent ops: evaluation order matters, evaluated per plan on host
BARRIER_OPS = ("transitive_ends_with", "top_k")

_KIND_RANK = {k: i for i, k in enumerate(VECTOR_OPS)}


def _sorted_run(run: list) -> list:
    """Canonical order of one commuting predicate run: dedup, then sort
    by (kind, arg) — any fixed total order works; this one is stable
    across processes (no hash randomization)."""
    return sorted(set(run), key=lambda op: (_KIND_RANK[op[0]], op[1]))


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Immutable chainable plan builder (mirrors the SequenceFrame API).

        plan().screen(5).starts_with(x).top_k(8)

    ``screen()`` without a threshold defers to the serving session's
    config default (resolved by the server before canonicalization).
    """

    ops: tuple[tuple[str, int | None], ...] = ()

    def _with(self, kind: str, arg) -> "QueryPlan":
        return QueryPlan(self.ops + ((kind, arg),))

    # --- builders (one per frame mask method) ------------------------------
    def screen(self, threshold: int | None = None) -> "QueryPlan":
        return self._with(
            "screen", None if threshold is None else int(threshold))

    def starts_with(self, phenx_id: int) -> "QueryPlan":
        return self._with("starts_with", int(phenx_id))

    def ends_with(self, phenx_id: int) -> "QueryPlan":
        return self._with("ends_with", int(phenx_id))

    def min_duration(self, days: int) -> "QueryPlan":
        return self._with("min_duration", int(days))

    def transitive_ends_with(self, start_phenx_id: int) -> "QueryPlan":
        return self._with("transitive_ends_with", int(start_phenx_id))

    def top_k(self, k: int) -> "QueryPlan":
        return self._with("top_k", int(k))

    # --- resolution / canonical form ---------------------------------------
    def resolve(self, default_threshold: int | None = None) -> "QueryPlan":
        """Fill deferred screen thresholds with the session default."""
        if not any(kind == "screen" and arg is None for kind, arg in self.ops):
            return self
        if default_threshold is None:
            raise ValueError(
                "plan screens without a threshold and the session config "
                "has none; pass screen(threshold) or set "
                "MiningConfig.threshold")
        return QueryPlan(tuple(
            (kind, default_threshold if kind == "screen" and arg is None
             else arg)
            for kind, arg in self.ops))

    def canonical(self) -> tuple:
        """Hashable canonical op tuple (the result-cache key).  Requires a
        resolved plan (no deferred thresholds)."""
        out: list = []
        run: list = []
        for kind, arg in self.ops:
            if arg is None:
                raise ValueError("canonical() needs a resolved plan; "
                                 "call resolve(default_threshold) first")
            if kind in _KIND_RANK:
                run.append((kind, arg))
            else:
                out.extend(_sorted_run(run))
                run = []
                out.append((kind, arg))
        out.extend(_sorted_run(run))
        return tuple(out)

    def split_canonical(self) -> tuple[tuple, tuple]:
        """(vectorizable predicate prefix, host-evaluated suffix) of the
        canonical form — the suffix starts at the first barrier op."""
        canon = self.canonical()
        for i, (kind, _) in enumerate(canon):
            if kind in BARRIER_OPS:
                return canon[:i], canon[i:]
        return canon, ()

    # --- oracle -------------------------------------------------------------
    def apply(self, frame):
        """Replay the plan, in its *original* (un-canonicalized) order,
        through SequenceFrame chaining — the conformance oracle the
        batched evaluator is property-tested against."""
        for kind, arg in self.ops:
            frame = getattr(frame, kind)(arg)
        return frame

    def __str__(self) -> str:
        return ".".join(f"{k}({'?' if a is None else a})"
                        for k, a in self.ops) or "(all)"


def plan() -> QueryPlan:
    """Start an empty chain: ``plan().screen(5).starts_with(x)``."""
    return QueryPlan()

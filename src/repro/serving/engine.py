"""Batched serving: jitted prefill + decode steps, wave scheduler.

Iteration-level continuous batching ("waves"): requests queue up, are
grouped into fixed-size padded batches, prefilled together, and decoded
until every slot emits EOS or hits its token budget; finished slots are
masked (their tokens frozen) so stragglers don't stall correctness, and
the next wave refills all slots.  Slot-level refill (per-sequence
admission) is a scheduler extension documented in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import queue

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampling import sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 32


class ServeEngine:
    def __init__(self, mdl, params, *, batch_size: int, max_len: int,
                 eos_id: int = 2, temperature: float = 0.0):
        self.mdl = mdl
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.eos = eos_id
        self.temperature = temperature
        self.queue: "queue.Queue[Request]" = queue.Queue()

        def prefill(params, tokens, caches):
            logits, caches = mdl.apply(params, {"tokens": tokens},
                                       mode="prefill", caches=caches)
            return logits[:, -1], caches

        def decode(params, tokens, caches, rng):
            logits, caches = mdl.apply(params, {"tokens": tokens},
                                       mode="decode", caches=caches)
            nxt = sample(logits[:, 0], rng, temperature)
            return nxt, caches

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def submit(self, req: Request):
        self.queue.put(req)

    def _next_wave(self) -> list[Request]:
        """Length-bucketed admission: a wave shares one prompt length, so
        no padding tokens ever enter attention (masks stay exact)."""
        wave: list[Request] = []
        deferred: list[Request] = []
        while len(wave) < self.b and not self.queue.empty():
            r = self.queue.get()
            if not wave or len(r.prompt) == len(wave[0].prompt):
                wave.append(r)
            else:
                deferred.append(r)
        for r in deferred:
            self.queue.put(r)
        return wave

    def run(self, rng=None) -> dict[int, np.ndarray]:
        """Drain the queue; returns rid -> generated tokens."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        results: dict[int, np.ndarray] = {}
        while not self.queue.empty():
            wave = self._next_wave()
            plen = len(wave[0].prompt)
            tokens = np.zeros((self.b, plen), np.int32)
            for i, r in enumerate(wave):
                tokens[i] = r.prompt
            budget = max(r.max_new_tokens for r in wave)

            caches = self.mdl.init_caches(self.b, self.max_len)
            last, caches = self._prefill(self.params, jnp.asarray(tokens),
                                         caches)
            nxt = sample(last, rng, self.temperature)
            out = [nxt]
            done = np.zeros(self.b, bool)
            for _ in range(budget - 1):
                rng, sub = jax.random.split(rng)
                nxt, caches = self._decode(self.params, nxt[:, None], caches,
                                           sub)
                out.append(nxt)
                done |= np.asarray(nxt) == self.eos
                if done[: len(wave)].all():
                    break
            gen = np.stack([np.asarray(t) for t in out], 1)  # [B, T]
            for i, r in enumerate(wave):
                toks = gen[i]
                stop = np.nonzero(toks == self.eos)[0]
                if len(stop):
                    toks = toks[: stop[0] + 1]
                results[r.rid] = toks[: r.max_new_tokens]
        return results

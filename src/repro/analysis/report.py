"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONs.

  PYTHONPATH=src python -m repro.analysis.report experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(out_dir: str, mesh: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n/2**30:.2f}GiB"


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | status | per-device temp | args | compile |",
        "|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "ok":
            mem = r["memory_analysis"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
                f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
                f"{r['t_compile_s']:.0f}s |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                         f"{reason} | | |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "useful | frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | |")
            continue
        rf = r["roofline"]
        note = _note(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.3g}s | "
            f"{rf['t_memory_s']:.3g}s | {rf['t_collective_s']:.3g}s | "
            f"{rf['dominant']} | {rf['useful_ratio']:.2f} | "
            f"{rf['roofline_fraction']:.3f} | {note} |")
    return "\n".join(lines)


def _note(r) -> str:
    rf = r["roofline"]
    bd = rf["coll_breakdown"]
    if rf["dominant"] == "collective" and bd:
        top = max(bd, key=bd.get)
        return f"{top} {bd[top]/2**30:.0f}GiB/dev dominates"
    if rf["dominant"] == "compute":
        return "compute-bound (good)"
    return "HBM-bound"


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    for mesh, title in (("pod16x16", "single pod (16x16 = 256 chips)"),
                        ("pod2x16x16", "multi-pod (2x16x16 = 512 chips)")):
        recs = load(out_dir, mesh)
        if not recs:
            continue
        print(f"\n### Dry-run — {title}\n")
        print(dryrun_table(recs))
        if mesh == "pod16x16":
            print(f"\n### Roofline — {title}\n")
            print(roofline_table(recs))
    ok = sum(1 for m in ("pod16x16", "pod2x16x16") for r in load(out_dir, m)
             if r["status"] == "ok")
    skip = sum(1 for m in ("pod16x16", "pod2x16x16") for r in load(out_dir, m)
               if r["status"] == "skipped-by-rule")
    fail = sum(1 for m in ("pod16x16", "pod2x16x16") for r in load(out_dir, m)
               if r["status"] == "FAILED")
    print(f"\ncells: ok={ok} skipped-by-rule={skip} failed={fail}")


if __name__ == "__main__":
    main()

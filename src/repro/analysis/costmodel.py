"""Analytic FLOP / HBM-byte model per (arch x shape).

Why analytic: XLA's cost analysis counts a while-loop body ONCE regardless
of trip count, and every production-size model here iterates layers (and
attention/SSM chunks) with lax.scan — compiled cost_analysis under-reports
FLOPs by O(n_layers x n_chunks).  Unrolling at 32k/500k scale is
infeasible, so the roofline uses this closed-form model instead, and
tests/test_costmodel.py validates it against *fully unrolled* compiled HLO
(runtime_flags.UNROLL_SCANS) at reduced scale for every family.

Conventions: counted FLOPs are the COMPUTED ones (the blocked attention
computes full S x Skv rectangles, masked lanes included — exactly what the
hardware executes).  Backward pass = 2x forward matmul FLOPs;
remat: 'full' recomputes the forward (+1x), 'dots' recomputes only
cheap ops (+epsilon, ignored).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

BP = {"float32": 4, "bfloat16": 2, "float16": 2}


def mm(m, n, k):
    return 2.0 * m * n * k


# --- per-layer forward FLOPs -------------------------------------------------
def _attn_flops(cfg, B, S, Skv, d_model=None, n_heads=None, n_kv=None,
                hd=None):
    d = d_model or cfg.d_model
    h = n_heads or cfg.n_heads
    hk = n_kv or cfg.n_kv_heads
    hd = hd or cfg.hd
    f = mm(B * S, h * hd, d) + 2 * mm(B * S, hk * hd, d)    # qkv proj
    f += 2 * mm(B * S, Skv, h * hd)                          # qk^T and pv
    f += mm(B * S, d, h * hd)                                # out proj
    return f


def _mlp_flops(cfg, B, S, d=None, ff=None):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    return 3 * mm(B * S, ff, d)


def _moe_flops(cfg, B, S):
    n = B * S
    cap = max(8, -(-int(n * cfg.experts_per_token * cfg.capacity_factor /
                        cfg.n_experts) // 8) * 8)
    f = mm(n, cfg.n_experts, cfg.d_model)                    # router
    f += 3 * mm(cfg.n_experts * cap, cfg.moe_d_ff, cfg.d_model)
    if cfg.n_shared_experts:
        f += 3 * mm(n, cfg.n_shared_experts * cfg.moe_d_ff, cfg.d_model)
    return f


def _linear_scan_flops(B, S, H, dk, dv, chunk):
    """chunked_scan: intra qk/y + inter + carry terms (ssm_common)."""
    c = min(chunk, S)
    f = 2 * B * H * S * c * dk          # intra scores (q k^T per chunk)
    f += 2 * B * H * S * c * dv         # intra y = scores @ v
    f += 2 * 2 * B * H * S * dk * dv    # carry outer products (C, and w_end)
    f += 2 * B * H * S * dk * dv        # inter y = q @ C_in
    f += 2 * B * H * S * dk             # normalizer terms
    return f


def _mlstm_flops(cfg, B, S):
    di = cfg.d_model * cfg.ssm_expand
    h = cfg.n_heads
    dh = di // h
    f = 4 * mm(B * S, di, cfg.d_model)                       # q k v z
    f += mm(B * S, 2 * h, cfg.d_model)                       # gates
    f += _linear_scan_flops(B, S, h, dh, dh, cfg.ssm_chunk)
    f += mm(B * S, cfg.d_model, di)                          # out proj
    return f


def _slstm_flops(cfg, B, S):
    di = cfg.d_model * cfg.ssm_expand
    h = cfg.n_heads
    dh = di // h
    f = mm(B * S, 4 * di, cfg.d_model)                       # x gates
    f += S * 4 * 2.0 * B * h * dh * dh                       # recurrent R h
    f += mm(B * S, cfg.d_model, di)                          # out proj
    return f


def _mamba_flops(cfg, B, S):
    di = cfg.d_model * cfg.ssm_expand
    h = cfg.ssm_heads or max(1, di // 64)
    p = di // h
    n = cfg.ssm_state
    conv_dim = di + 2 * n
    f = mm(B * S, 2 * di + 2 * n + h, cfg.d_model)           # in proj
    f += 2.0 * B * S * conv_dim * cfg.ssm_conv               # conv
    f += _linear_scan_flops(B, S, h, n, p, cfg.ssm_chunk)
    f += mm(B * S, cfg.d_model, di)                          # out proj
    return f


def _zamba_shared_flops(cfg, B, S, Skv):
    d2 = 2 * cfg.d_model
    f = _attn_flops(cfg, B, S, Skv, d_model=d2, hd=d2 // cfg.n_heads)
    f += _mlp_flops(cfg, B, S, d=d2, ff=cfg.d_ff)
    f += mm(B * S, cfg.d_model, d2)                          # down proj
    return f


def fwd_flops(cfg: ModelConfig, B: int, S: int, Skv: int | None = None) -> float:
    """Forward FLOPs for S new positions attending to Skv (decode: S=1)."""
    Skv = Skv or S
    fam = cfg.family
    f = 0.0
    if fam in ("dense", "moe", "vlm"):
        from repro.models.transformer import pattern_of

        pattern = pattern_of(cfg)
        n_rep = cfg.n_layers // len(pattern)
        for kind in pattern:
            # NOTE: the blocked implementation computes full S x Skv
            # rectangles (masked lanes included), so local layers cost the
            # same as global ones today — window-skipping is a recorded
            # §Perf optimization opportunity.
            f += n_rep * _attn_flops(cfg, B, S, Skv)
            f += n_rep * (_moe_flops(cfg, B, S) if kind == "moe"
                          else _mlp_flops(cfg, B, S))
        if fam == "vlm" and S > 1:
            f += mm(B * cfg.n_patches, cfg.d_model, cfg.frontend_dim)
    elif fam == "xlstm":
        from repro.models.xlstm import pattern_of as xp

        pattern = xp(cfg)
        n_rep = cfg.n_layers // len(pattern)
        for kind in pattern:
            f += n_rep * (_mlstm_flops(cfg, B, S) if kind == "m"
                          else _slstm_flops(cfg, B, S))
    elif fam == "hybrid":
        every = cfg.shared_attn_every or cfg.n_layers
        n_groups = cfg.n_layers // every
        f += cfg.n_layers * _mamba_flops(cfg, B, S)
        f += n_groups * _zamba_shared_flops(cfg, B, S, Skv)
    elif fam == "encdec":
        s_src = Skv if S == 1 else S  # encoder length
        if S > 1:  # encoder runs on train/prefill only
            for _ in range(cfg.n_enc_layers):
                f += _attn_flops(cfg, B, s_src, s_src)
                f += _mlp_flops(cfg, B, s_src)
        for _ in range(cfg.n_dec_layers):
            f += _attn_flops(cfg, B, S, Skv)        # self
            f += _attn_flops(cfg, B, S, s_src)      # cross
            f += _mlp_flops(cfg, B, S)
    else:
        raise ValueError(fam)
    f += mm(B * S, cfg.vocab_size, cfg.d_model)              # logits
    return f


def step_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "encdec":
            fwd = fwd_flops(cfg, B, S // 2, S // 2)
        elif cfg.family == "vlm":
            fwd = fwd_flops(cfg, B, S, S)  # patches + text ≈ S total
        else:
            fwd = fwd_flops(cfg, B, S, S)
        mult = 3.0 + (1.0 if cfg.remat == "full" else 0.0)
        return fwd * mult
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            # prefill computes last-position logits only
            return fwd_flops(cfg, B, S // 2, S // 2) \
                - mm(B * (S // 2 - 1), cfg.vocab_size, cfg.d_model)
        return fwd_flops(cfg, B, S, S) - mm(B * (S - 1), cfg.vocab_size,
                                            cfg.d_model)
    # decode: one token against a Skv cache
    return fwd_flops(cfg, B, 1, S)


# --- HBM traffic model -------------------------------------------------------
def step_bytes(cfg: ModelConfig, shape: ShapeConfig, n_params: int) -> float:
    """First-order HBM bytes per step (documented estimate, DESIGN.md §8):
    params (fwd read + bwd read + grad write + f32 Adam m/v read+write),
    residual-stream activation traffic, attention KV/cache traffic."""
    bp = BP.get(cfg.dtype, 2)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    if shape.kind == "train":
        param_traffic = n_params * (bp * 2 + 4 + 16 + bp)   # fwd+bwd, g, mv, w
        act_coeff = 14 if cfg.remat == "none" else 20        # incl. recompute
        act = L * B * S * d * bp * act_coeff
        return param_traffic + act
    if shape.kind == "prefill":
        cache = L * B * S * cfg.n_kv_heads * cfg.hd * 2 * bp
        act = L * B * S * d * bp * 8
        return n_params * bp + act + cache
    # decode: weights + full cache read + one-position write
    if cfg.family == "xlstm":
        di = d * cfg.ssm_expand
        dh = di // cfg.n_heads
        state = L * B * cfg.n_heads * (dh * dh + 2 * dh) * 4
        return n_params * bp + 2 * state
    if cfg.family == "hybrid":
        di = d * cfg.ssm_expand
        h = cfg.ssm_heads or di // 64
        p = di // h
        state = L * B * h * (cfg.ssm_state * p) * 4
        n_shared = cfg.n_layers // (cfg.shared_attn_every or cfg.n_layers)
        kv = n_shared * B * S * cfg.n_heads * (2 * d // cfg.n_heads) * 2 * bp
        return n_params * bp + 2 * state + kv
    kv_layers = cfg.n_dec_layers if cfg.family == "encdec" else L
    cache = kv_layers * B * S * cfg.n_kv_heads * cfg.hd * 2 * bp
    return n_params * bp + cache

"""Three-term roofline from the compiled dry-run artifact.

  compute    = HLO_FLOPs        / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes        / (chips * 819e9  B/s HBM)
  collective = collective_bytes / (chips * 50e9   B/s per ICI link)

cost_analysis() provides FLOPs/bytes; collective bytes come from parsing
the post-SPMD optimized HLO (compiled.as_text()) and summing operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.  MODEL_FLOPS (6*N*D train, 2*N*D inference; active
params for MoE) over HLO FLOPs measures useful-compute fraction.

This module also owns the *mining* cost model (constants shared with
``benchmarks/mining_roofline.py``) and :func:`mining_tile_plan`, the tile
selection the fused mine+screen kernel (``kernels/tspm_fused``) reads its
defaults from — analytic VMEM-fit by default, measured-sweep argmin when
the autotune rows from ``benchmarks/mining_fused.py`` are handed back in.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # TPU v5e bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

# --- mining cost model (tSPM+ pair enumeration) -----------------------------
# materializing pairgen traffic: two int32 phenx planes + int32 duration +
# bool mask + amortized id pack in the XLA consumer
MINING_BYTES_PER_PAIR = 17
MINING_OPS_PER_PAIR = 6      # shift/or pack, sub, 3 compares for the mask
# dense block working set on the corpus-free jnp fallback (mine_dense +
# row-sort dedup): mirrors chunking.BYTES_PER_PAIR — 8B seq + 4B dur +
# 1B mask, x2 sort scratch
FUSED_BLOCK_BYTES_PER_PAIR = 26
VMEM_BYTES = 16 << 20        # TPU v5e per-core VMEM


@dataclasses.dataclass(frozen=True)
class MiningTilePlan:
    """Tile choice for the fused mine+screen kernel (kernels/tspm_fused).

    ``pb x ti x tj`` is the pair-tile grid shared with tspm_pairgen /
    tspm_delta; ``bt`` the bucket-tile width of the VMEM-accumulated
    [2^H] table; ``block_patients`` the host-loop patient block bounding
    the corpus-free counting pass's working set."""

    pb: int
    ti: int
    tj: int
    bt: int
    block_patients: int
    vmem_bytes: int          # modeled per-grid-step VMEM working set
    source: str              # 'analytic' | 'measured'


def fused_kernel_vmem(pb: int, ti: int, tj: int, bt: int, max_events: int,
                      chunk_i: int = 4) -> int:
    """Modeled VMEM bytes of one fused-kernel grid step.

    Rows (full-width phenx for the dedup lookback), the i/j row tiles, the
    [Pb, T, E] dedup compare scratch, the pair-tile hash/flag planes, and
    the [Pb, chunk_i * Tj, bt] compare-and-reduce slab of the histogram
    accumulation loop.
    """
    e = max(ti, -(-max(int(max_events), 1) // ti) * ti)
    rows = pb * e * 4                     # full phenx row block
    tiles = pb * (ti + tj) * 4            # xi / xj row tiles
    dedup = pb * (ti + tj) * e            # eq_i / eq_j bool scratch
    pairs = pb * ti * tj * (4 + 4 + 1)    # hash, iota masks, first flags
    hist = pb * chunk_i * tj * bt         # bucket compare slab (bool)
    table = bt * 4                        # accumulator block
    return int(rows + tiles + dedup + pairs + hist + table)


def mining_tile_plan(max_events: int, n_buckets_log2: int, *,
                     vmem_bytes: int = VMEM_BYTES // 2,
                     block_bytes: int = 64 << 20,
                     rows: list[dict] | None = None) -> MiningTilePlan:
    """Pick (pb, ti, tj, bt, block_patients) for the fused kernel.

    Analytic mode: lane-native ``ti = tj = 128`` (matching the ops-layer
    padding), the largest power-of-two patient block whose modeled working
    set (:func:`fused_kernel_vmem`) fits ``vmem_bytes``, ``bt = min(2^H,
    512)`` (seq_hist's bucket-tile width), and a counting-pass patient
    block sized so the jnp-fallback dense planes stay under ``block_bytes``
    at ``FUSED_BLOCK_BYTES_PER_PAIR``.

    Measured mode: ``rows`` are autotune sweep records (dicts with ``pb``
    and ``wall_s``, optionally ``ti``/``tj``/``bt``, from
    ``benchmarks/mining_fused.py``); the fastest row that still fits
    ``vmem_bytes`` wins, falling back to the analytic choice when none fit.
    """
    B = 1 << n_buckets_log2
    ti = tj = 128
    bt = min(B, 512)
    chosen = None
    source = "analytic"
    if rows:
        fitting = [r for r in rows
                   if fused_kernel_vmem(int(r["pb"]), int(r.get("ti", ti)),
                                        int(r.get("tj", tj)),
                                        int(r.get("bt", bt)), max_events)
                   <= vmem_bytes]
        if fitting:
            best = min(fitting, key=lambda r: float(r["wall_s"]))
            chosen = (int(best["pb"]), int(best.get("ti", ti)),
                      int(best.get("tj", tj)), int(best.get("bt", bt)))
            source = "measured"
    if chosen is None:
        pb = 1
        for cand in (32, 16, 8, 4, 2, 1):
            if fused_kernel_vmem(cand, ti, tj, bt, max_events) <= vmem_bytes:
                pb = cand
                break
        chosen = (pb, ti, tj, bt)
    pb, ti, tj, bt = chosen
    e = max(ti, -(-max(int(max_events), 1) // ti) * ti)
    blk = max(pb, int(block_bytes // max(e * e * FUSED_BLOCK_BYTES_PER_PAIR, 1)))
    blk = min(-(-blk // pb) * pb, 4096)
    return MiningTilePlan(pb=pb, ti=ti, tj=tj, bt=bt, block_patients=blk,
                          vmem_bytes=fused_kernel_vmem(pb, ti, tj, bt,
                                                       max_events),
                          source=source)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# optimized HLO: `%name = <shape|tuple> <kind>[-start](%operand_refs), ...`
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_collective(line: str):
    """(kind, operand_bytes_per_device) for a collective op line, or None.

    Shapes in partitioned HLO are per-device; operand size is inferred from
    the output shape and the replica-group size:
      all-reduce / all-to-all / collective-permute: operand == output
      all-gather:     operand = output / group   (gathers g shards)
      reduce-scatter: operand = output * group
    """
    m = _OP_RE.search(line)
    if not m:
        return None
    out_shapes, kind = m.group(1), m.group(2)
    total = 0
    for sm in _SHAPE_RE.finditer(out_shapes):
        if sm.group(1) in _DTYPE_BYTES:
            total += shape_bytes(sm.group(1), sm.group(2))
    g = 1
    gm = _GROUPS_RE.search(line)
    if gm:
        g = int(gm.group(2))
    else:
        gm = _GROUPS_EXPL_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
    if kind == "all-gather" and g:
        total //= g
    elif kind == "reduce-scatter":
        total *= g
    return kind, total


def _computations(hlo_text: str):
    """Split optimized HLO text into (name -> list of op lines) using brace
    depth — headers can wrap across lines, so regexes on single lines miss
    them."""
    comps: dict[str, list[str]] = {}
    depth = 0
    header: list[str] = []
    current = None
    for line in hlo_text.splitlines():
        opens, closes = line.count("{"), line.count("}")
        if depth == 0:
            header.append(line)
            if opens > closes:  # computation body starts
                m = _NAME_RE.search(" ".join(header))
                current = m.group(1) if m else f"anon{len(comps)}"
                comps[current] = []
                header = []
        else:
            if current is not None:
                comps[current].append(line)
        depth += opens - closes
        if depth == 0:
            current = None
            header = []
    return comps


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind collective operand bytes (per device), EXACT loop scaling:
    XLA prints each while body once but annotates known_trip_count; we
    build the while-nesting graph and multiply collectives inside a body by
    the product of trip counts up the nesting chain."""
    comps = _computations(hlo_text)
    parent: dict[str, str] = {}
    trips: dict[str, int] = {}
    for cname, lines in comps.items():
        for line in lines:
            if _WHILE_RE.search(line):
                bm = _WHILE_BODY_RE.search(line)
                if not bm:
                    continue
                body = bm.group(1)
                tm = _TRIP_RE.search(line)
                parent[body] = cname
                trips[body] = int(tm.group(1)) if tm else 1

    def multiplier(cname: str) -> int:
        mult = 1
        seen = set()
        while cname in parent and cname not in seen:
            seen.add(cname)
            mult *= trips.get(cname, 1)
            cname = parent[cname]
        return mult

    out = {k: 0 for k in _COLLECTIVES}
    for cname, lines in comps.items():
        mult = multiplier(cname)
        for line in lines:
            got = _line_collective(line)
            if got is None:
                continue
            kind, nbytes = got
            out[kind] += nbytes * mult
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict
    model_flops: float
    bytes_per_device: float | None = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """model-useful compute time over the achievable step time
        (max of the three terms = the bound the step cannot beat)."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful / max(bound, 1e-12)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_breakdown": self.coll_breakdown,
            "bytes_per_device": self.bytes_per_device,
        }


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts via eval_shape (no allocation)."""
    import jax

    from repro.models import model as model_lib

    mdl = model_lib.build(cfg)
    shapes = jax.eval_shape(lambda: mdl.init(jax.random.PRNGKey(0))[0])
    total = sum(int(l.size) for l in jax.tree.leaves(shapes))
    active = total
    if cfg.n_experts:
        expert = 3 * cfg.d_model * cfg.moe_d_ff  # gate/up/down per expert
        n_moe_layers = cfg.n_layers // cfg.moe_interleave
        routed_all = n_moe_layers * cfg.n_experts * expert
        routed_active = n_moe_layers * cfg.experts_per_token * expert
        active = total - routed_all + routed_active
    return total, active


def model_flops(cfg, shape, active_params: int, embed_params: int = 0) -> float:
    """6*N*D for training; 2*N*D for prefill; 2*N*B for one decode step."""
    n = active_params - embed_params
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def format_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_coll | dominant | "
           "useful | roofline-frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(lines)

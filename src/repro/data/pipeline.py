"""Distributed input pipeline: patient-sharded mining + batch placement.

Mirrors the mesh of the model: patients are sharded over ('pod', 'data');
each shard mines its chunk locally (the OpenMP-thread analogue) and the
global sparsity screen is the single hash-psum (core/sparsity.screen_hash).

Straggler mitigation: mining chunks are adaptively sized (core/chunking) so
per-shard work is balanced by *pair count* rather than patient count — a
patient with 4x the events costs 16x the pairs, which is exactly the
imbalance the paper's per-patient OpenMP scheduling suffers from.  The
``ChunkScheduler`` below implements work-stealing over chunk queues for the
host-side (file-based) mode; on-device, balance comes from sorting patients
by event count before sharding (longest-processing-time-first heuristic).
"""
from __future__ import annotations

import threading
from typing import Callable

import jax
import numpy as np

from repro.core import chunking
from repro.data.dbmart import DBMart


def balance_buckets(nevents: np.ndarray, n_shards: int) -> list[list[int]]:
    """LPT assignment of patients to shards by pair-count cost.

    Bucket capacity rounds *up* (``ceil(P / n_shards)``): with a floor
    capacity, the ``P % n_shards`` remainder patients found every bucket
    "full" and all piled into shard 0."""
    cost = nevents.astype(np.int64) * (nevents.astype(np.int64) - 1) // 2
    order = np.argsort(-cost)
    loads = np.zeros(n_shards, np.int64)
    buckets: list[list[int]] = [[] for _ in range(n_shards)]
    per = -(-len(nevents) // n_shards)
    for p in order:
        k = int(np.argmin(np.where(
            np.asarray([len(b) for b in buckets]) < per, loads, np.iinfo(np.int64).max)))
        buckets[k].append(int(p))
        loads[k] += int(cost[p])
    return buckets


def balance_patients(nevents: np.ndarray, n_shards: int) -> np.ndarray:
    """Permutation such that contiguous equal slices of the permuted patient
    axis have near-equal total n(n-1)/2 cost (see :func:`balance_buckets`).

    Exact only when ``len(nevents) % n_shards == 0`` (equal slices then
    coincide with the buckets); with a remainder, bucket sizes differ by
    one and equal-slice cuts straddle bucket boundaries — slice by
    :func:`balance_buckets` sizes (or use the buckets directly) instead."""
    return np.concatenate([
        np.asarray(b, np.int64)
        for b in balance_buckets(nevents, n_shards)])


def shard_batch(batch: dict, mesh, batch_axes=("pod", "data")) -> dict:
    """Host batch -> device arrays sharded over the batch axes of the mesh."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    out = {}
    for k, v in batch.items():
        spec = jax.sharding.PartitionSpec(axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, jax.sharding.NamedSharding(mesh, spec))
    return out


class ChunkScheduler:
    """Work-stealing queue over mining chunks (host-side, file-based mode).

    Worker hosts pop chunks; a straggling host's remaining chunks are
    visible to idle peers because the queue is global.  Single-process here;
    at fleet scale the queue is any shared KV (the interface is the same).
    """

    def __init__(self, db: DBMart, budget_bytes: int):
        self.db = db
        self.chunks = chunking.plan_chunks(np.asarray(db.nevents), budget_bytes)
        self._lock = threading.Lock()
        self._next = 0
        self.completed: list[int] = []

    def steal(self) -> chunking.Chunk | None:
        with self._lock:
            if self._next >= len(self.chunks):
                return None
            c = self.chunks[self._next]
            self._next += 1
            return c

    def run(self, worker: Callable[[chunking.Chunk], object], n_workers: int = 1):
        results = []
        rlock = threading.Lock()

        def loop(wid: int):
            while True:
                c = self.steal()
                if c is None:
                    return
                r = worker(c)
                with rlock:
                    results.append(r)
                    self.completed.append(wid)

        threads = [threading.Thread(target=loop, args=(w,)) for w in range(n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return results

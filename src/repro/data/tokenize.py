"""Clinical event streams -> LM token corpora.

The bridge between the paper's mined world and the model zoo: each patient
becomes a document of interleaved phenX tokens and time-gap bucket tokens
(the tSPM+ duration dimension, kept in-band so the LM sees it), packed into
fixed-length training sequences.

Token map:  0 PAD | 1 BOS | 2 EOS | 3 SEP | 4..4+G gap buckets | G+4.. phenX
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.dbmart import DBMart

PAD, BOS, EOS, SEP = 0, 1, 2, 3
N_GAP_BUCKETS = 16
PHENX_OFFSET = 4 + N_GAP_BUCKETS


def gap_bucket(days: np.ndarray) -> np.ndarray:
    """log2-ish day-gap buckets: 0, 1, 2-3, 4-7, ... capped."""
    d = np.maximum(np.asarray(days, np.int64), 0)
    b = np.where(d == 0, 0, np.floor(np.log2(np.maximum(d, 1))).astype(np.int64) + 1)
    return np.minimum(b, N_GAP_BUCKETS - 1).astype(np.int32)


@dataclasses.dataclass
class Corpus:
    tokens: np.ndarray      # [n_seq, seq_len] int32
    loss_mask: np.ndarray   # [n_seq, seq_len] bool — False on PAD
    vocab_size: int


def patient_documents(db: DBMart) -> list[np.ndarray]:
    docs = []
    for p in range(db.n_patients):
        n = int(db.nevents[p])
        if n == 0:
            continue
        toks = [BOS, PHENX_OFFSET + int(db.phenx[p, 0])]
        for i in range(1, n):
            gap = int(db.date[p, i]) - int(db.date[p, i - 1])
            toks.append(4 + int(gap_bucket(gap)))
            toks.append(PHENX_OFFSET + int(db.phenx[p, i]))
        toks.append(EOS)
        docs.append(np.asarray(toks, np.int32))
    return docs


def pack_corpus(db: DBMart, seq_len: int, vocab_size: int | None = None) -> Corpus:
    """Greedy document packing into [n_seq, seq_len] with SEP boundaries."""
    docs = patient_documents(db)
    stream: list[np.ndarray] = []
    for d in docs:
        stream.append(d)
        stream.append(np.asarray([SEP], np.int32))
    flat = np.concatenate(stream) if stream else np.zeros(0, np.int32)
    n_seq = max(1, -(-len(flat) // seq_len))
    padded = np.full(n_seq * seq_len, PAD, np.int32)
    padded[: len(flat)] = flat
    tokens = padded.reshape(n_seq, seq_len)
    if vocab_size is None:
        vocab_size = PHENX_OFFSET + (db.vocab.n_phenx if db.vocab else int(db.phenx.max()) + 1)
    return Corpus(tokens, tokens != PAD, vocab_size)


def lm_batches(corpus: Corpus, batch_size: int, seed: int = 0):
    """Infinite shuffled batch iterator of (tokens, labels, mask).

    labels are next-token; last position predicts PAD and is masked out."""
    rng = np.random.default_rng(seed)
    n = corpus.tokens.shape[0]
    while True:
        idx = rng.integers(0, n, batch_size)
        t = corpus.tokens[idx]
        labels = np.concatenate([t[:, 1:], np.full((batch_size, 1), PAD, np.int32)], 1)
        mask = corpus.loss_mask[idx] & (labels != PAD)
        yield {"tokens": t, "labels": labels, "loss_mask": mask}

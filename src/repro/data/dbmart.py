"""The dbmart: MLHO-format clinical tables, padded patient-major tensors.

The paper's dbmart is a ``(patient_num, date, phenX)`` row table.  tSPM+
sorts it by (patient, date) so every patient is one contiguous chunk — the
precondition for its thread-per-patient mining.  On TPU the analogue is a
*padded patient-major* layout: ``phenx[P, E]``, ``date[P, E]``,
``nevents[P]`` — each row is one patient's time-sorted events, padded to E.
The (patient, date) sort happens once here, at ingest (numpy mergesort ≙
the paper's stable ips4o pass).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.encoding import Vocab, encode_rows


@dataclasses.dataclass
class DBMart:
    """Padded patient-major numeric dbmart (host-side numpy)."""

    phenx: np.ndarray     # [P, E] int32 (padding: 0 beyond nevents; masked)
    date: np.ndarray      # [P, E] int32 days; non-decreasing within a row
    nevents: np.ndarray   # [P]    int32
    vocab: Vocab | None = None

    @property
    def n_patients(self) -> int:
        return self.phenx.shape[0]

    @property
    def max_events(self) -> int:
        return self.phenx.shape[1]

    @property
    def total_events(self) -> int:
        return int(self.nevents.sum())

    def slice_patients(self, start: int, stop: int, max_events: int | None = None) -> "DBMart":
        e = int(self.nevents[start:stop].max(initial=0)) if max_events is None else max_events
        e = max(e, 1)
        return DBMart(
            self.phenx[start:stop, :e], self.date[start:stop, :e],
            self.nevents[start:stop], self.vocab,
        )

    def valid_mask(self) -> np.ndarray:
        return np.arange(self.max_events)[None, :] < self.nevents[:, None]


def from_rows(
    patients, dates, phenx, vocab: Vocab | None = None, pad_multiple: int = 8
) -> DBMart:
    """Row table -> padded DBMart.  Sorts by (patient, date), stable.

    ``pad_multiple`` rounds E up for TPU-friendly tiling.
    """
    pid, date, xid, vocab = encode_rows(patients, dates, phenx, vocab)
    order = np.lexsort((np.arange(len(pid)), date, pid))  # stable (patient, date)
    pid, date, xid = pid[order], date[order], xid[order]

    n_pat = int(pid.max()) + 1 if len(pid) else 0
    counts = np.bincount(pid, minlength=n_pat).astype(np.int32)
    e_max = int(counts.max(initial=1))
    e_max = -(-e_max // pad_multiple) * pad_multiple

    phenx_arr = np.zeros((n_pat, e_max), np.int32)
    date_arr = np.zeros((n_pat, e_max), np.int32)
    starts = np.zeros(n_pat + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    cols = np.arange(len(pid)) - starts[pid]
    phenx_arr[pid, cols] = xid
    date_arr[pid, cols] = date
    # pad dates with the row's last date so padded durations are 0, not huge
    last = date_arr[np.arange(n_pat), np.maximum(counts - 1, 0)]
    pad = np.arange(e_max)[None, :] >= counts[:, None]
    date_arr = np.where(pad, last[:, None], date_arr)
    return DBMart(phenx_arr, date_arr, counts, vocab)


def first_occurrence_filter(db: DBMart) -> DBMart:
    """Keep only the first occurrence of each phenX per patient.

    The paper's comparison benchmark applies this (protocol of the AD study)
    to bound the sequence count for the original tSPM.
    """
    P, E = db.phenx.shape
    phenx = np.zeros_like(db.phenx)
    date = np.zeros_like(db.date)
    nevents = np.zeros_like(db.nevents)
    for p in range(P):
        n = int(db.nevents[p])
        seen: set[int] = set()
        k = 0
        for i in range(n):
            x = int(db.phenx[p, i])
            if x not in seen:
                seen.add(x)
                phenx[p, k] = x
                date[p, k] = db.date[p, i]
                k += 1
        nevents[p] = k
        if k:
            date[p, k:] = date[p, k - 1]
    e_max = max(int(nevents.max(initial=1)), 1)
    e_max = -(-e_max // 8) * 8
    return DBMart(phenx[:, :e_max], date[:, :e_max], nevents, db.vocab)

"""Synthetic Synthea-style COVID cohort generator (paper's example data).

The paper ships a modified Synthea COVID-19 synthetic dbmart with its
R-package.  We generate an equivalent cohort programmatically, with ground
truth for the Post-COVID-19 (WHO definition) vignette:

  * every patient gets background noise events (labs, encounters, chronic
    condition codes) spread over ~3 years;
  * a fraction get COVID-19 at a random date;
  * "long covid" patients get 1-4 persistent symptoms recurring from ~1-4
    months post-infection over >= 2 months (WHO: ongoing >= 2 months);
  * control covid patients get transient symptoms (single occurrence or a
    short burst) and/or symptoms explained by a competing cause (e.g. an
    influenza episode immediately preceding the symptom run).

Returned ground truth: per-patient long-covid label + the symptom set.
"""
from __future__ import annotations

import dataclasses

import numpy as np

COVID = "COVID-19"
SYMPTOMS = [
    "Fatigue", "Dyspnea", "Brain fog", "Chest pain", "Anosmia",
    "Headache", "Joint pain", "Cough",
]
COMPETING = ["Influenza", "Pneumonia", "Asthma exacerbation"]
CHRONIC = ["Hypertension", "Type 2 diabetes", "Hyperlipidemia", "CKD stage 2"]
NOISE = [f"Lab panel {i}" for i in range(18)] + [
    "Office visit", "Telehealth visit", "Vaccination", "BMI measurement",
    "Blood pressure check", "Lipid screen",
]


@dataclasses.dataclass
class CohortTruth:
    long_covid: np.ndarray          # [P] bool
    symptom_sets: list[set[str]]    # per patient, ground-truth PCC symptoms
    covid_date: np.ndarray          # [P] int32, -1 if never infected


def generate_cohort(
    n_patients: int = 256,
    avg_events: int = 60,
    covid_frac: float = 0.6,
    long_covid_frac: float = 0.4,
    seed: int = 0,
):
    """Returns (patients, dates, phenx, truth) row lists + ground truth."""
    rng = np.random.default_rng(seed)
    patients: list[int] = []
    dates: list[int] = []
    phenx: list[str] = []
    truth_label = np.zeros(n_patients, bool)
    truth_date = np.full(n_patients, -1, np.int32)
    symptom_sets: list[set[str]] = []

    def add(p: int, d: int, x: str) -> None:
        patients.append(p)
        dates.append(int(max(d, 0)))
        phenx.append(x)

    for p in range(n_patients):
        horizon = 1095  # ~3 years of history
        n_noise = max(4, int(rng.poisson(avg_events)))
        for _ in range(n_noise):
            add(p, rng.integers(0, horizon), NOISE[rng.integers(len(NOISE))])
        for c in CHRONIC:
            if rng.random() < 0.25:
                d0 = rng.integers(0, horizon // 2)
                for k in range(rng.integers(1, 4)):
                    add(p, d0 + k * rng.integers(60, 180), c)

        symptoms: set[str] = set()
        if rng.random() < covid_frac:
            cd = int(rng.integers(120, horizon - 400))
            truth_date[p] = cd
            add(p, cd, COVID)
            if rng.random() < 0.5:  # acute-phase symptoms (resolve quickly)
                for s in rng.choice(SYMPTOMS, rng.integers(1, 3), replace=False):
                    add(p, cd + rng.integers(2, 14), str(s))
            if rng.random() < long_covid_frac:
                truth_label[p] = True
                for s in rng.choice(SYMPTOMS, rng.integers(1, 5), replace=False):
                    s = str(s)
                    symptoms.add(s)
                    onset = cd + int(rng.integers(30, 120))
                    # recurring for >= 2 months (WHO: ongoing two months)
                    for k in range(3 + int(rng.integers(0, 4))):
                        add(p, onset + k * int(rng.integers(28, 46)), s)
            else:
                # competing-cause symptom runs (must be excluded by pipeline)
                if rng.random() < 0.6:
                    cause = str(COMPETING[rng.integers(len(COMPETING))])
                    d0 = int(truth_date[p]) + int(rng.integers(150, 350))
                    add(p, d0, cause)
                    s = str(SYMPTOMS[rng.integers(len(SYMPTOMS))])
                    for k in range(3):
                        add(p, d0 + 3 + k * 30, s)
        symptom_sets.append(symptoms)

    return patients, dates, phenx, CohortTruth(truth_label, symptom_sets, truth_date)


def generate_benchmark_rows(n_patients: int, avg_events: int, seed: int = 0,
                            n_codes: int = 4000):
    """Flat numeric row generator for throughput benchmarks (paper Table 1/2
    scale: 4 985 patients x ~471 events; 35 000 x ~318).  Pure numpy, fast.
    """
    rng = np.random.default_rng(seed)
    counts = np.maximum(rng.poisson(avg_events, n_patients), 2)
    total = int(counts.sum())
    pid = np.repeat(np.arange(n_patients, dtype=np.int32), counts)
    date = rng.integers(0, 2000, total, dtype=np.int32)
    # zipfian-ish code popularity, like real EHR code frequency
    ranks = np.arange(1, n_codes + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    xid = rng.choice(n_codes, total, p=probs).astype(np.int32)
    return pid, date, xid, counts

"""JAX version-drift shims — one name per API that moved between releases.

Policy (ROADMAP §Streaming subsystem): code everywhere else in the repo
imports the *new* spelling from here and never version-checks inline, so a
toolchain bump is a one-file change.  Shims are resolved once at import
time by feature detection (``hasattr`` / signature inspection), never by
parsing ``jax.__version__``.

Current shims:

  * ``shard_map`` — top-level ``jax.shard_map`` only exists on jax >= 0.5;
    0.4.x ships it as ``jax.experimental.shard_map.shard_map`` and spells
    the replication-check kwarg ``check_rep`` instead of ``check_vma``.
  * ``cost_analysis`` / ``hlo_flops`` — ``Compiled.cost_analysis()``
    returns a flat dict on new jax but a list of per-module dicts (usually
    length 1) on 0.4.x, and may return ``None`` on backends without cost
    modeling.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """``jax.shard_map`` with the >= 0.5 calling convention on any jax.

    ``check_vma`` is translated to ``check_rep`` when the installed
    shard_map predates the rename (the semantics match: both gate the
    varying/replicated consistency check).
    """
    if check_vma is not None:
        key = "check_vma" if "check_vma" in _SHARD_MAP_PARAMS else "check_rep"
        kwargs[key] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as one flat dict on every jax version."""
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def hlo_flops(lowered) -> float:
    """Compiled-HLO FLOP count of a lowered computation (0.0 if unmodeled)."""
    return float(cost_analysis(lowered.compile()).get("flops", 0.0))

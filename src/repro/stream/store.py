"""Device-resident patient history store for streaming ingest.

The batch pipeline pads each cohort once (data/dbmart); a stream never
sees the whole cohort, so the store keeps *growable* padded planes

    phenx [P_cap, E_cap]   date [P_cap, E_cap]   nevents [P_cap]

with per-patient cursors (``nevents``) and a jitted scatter-append.  Rows
are physical slots; patients get a stable dense ``pid`` on first admission
(admission order), so corpus and sketch state survive eviction.

Capacity policy (the streaming analogue of core/chunking's adaptive
partitioning):

  * **regrowth** — event capacity rounds up to ``pad_multiple`` (tile
    friendly) and doubles geometrically; row capacity doubles.
  * **eviction** — when a byte budget is set, the resident working set is
    replanned with ``chunking.plan_chunks`` over patients in
    most-recently-touched-first order; everything past the first chunk
    (the maximal recent prefix that fits the budget under the same
    ``BYTES_PER_PAIR`` cost model as batch chunking) is spilled to the
    host tier; when a disk budget is set, the oldest host spills demote
    further into the compressed disk tier (storage/tiers) under the same
    cost model.  Re-admission restores the spilled history from
    whichever tier holds it, so delta mining is byte-budgeted but exact.
  * **handoff** — ``extract`` withdraws a patient entirely (shard
    migration), returning its history in the host-spill format;
    ``admit_state`` is the receiving end and lands the history in the
    spill slot, so a migrated-in patient restores lazily on first touch
    exactly like an evicted one.  Extracted pids are never reused
    (``_next_pid``): the sketch row at that pid stays addressable until
    its owner zeroes it.
  * **shrinking** — departures release capacity: ``shrink_to_fit`` trims
    the event axis to the resident high-water mark and the row axis to
    the highest occupied row, but only when half (or less) of a plane
    axis is live — the hysteresis mirrors geometric growth so a
    migrate/re-admit cycle cannot thrash recompiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_lib
from repro.core import chunking
from repro.storage import tiers as tiers_lib
from repro.storage.codec import decode_key, encode_key


@functools.partial(jax.jit, donate_argnums=())
def _append_step(phenx, date, nevents, rows, new_phenx, new_date, n_new):
    """Scatter a [B, D] delta at the per-row cursors (out-of-window drops)."""
    D = new_phenx.shape[1]
    E = phenx.shape[1]
    ar = jnp.arange(D, dtype=jnp.int32)[None, :]
    pos = nevents[rows][:, None] + ar
    valid = ar < n_new[:, None]
    pos = jnp.where(valid, pos, E)           # out of bounds -> mode="drop"
    phenx = phenx.at[rows[:, None], pos].set(new_phenx, mode="drop")
    date = date.at[rows[:, None], pos].set(new_date, mode="drop")
    nevents = nevents.at[rows].add(n_new)
    return phenx, date, nevents


class PatientStore:
    """Growable padded history planes with admission / eviction / regrowth.

    ``device`` pins the planes to one device (``jax.device_put`` once at
    construction): every derived array — pads, scatter-appends, the delta
    slabs mined from the planes — stays *committed* there, so a sharded
    service can hold one store per mesh position and tick them without the
    default-device serialization.  ``None`` keeps jax's default placement
    (single-process behavior, byte-identical results).
    """

    def __init__(self, pad_multiple: int = 8, budget_bytes: int | None = None,
                 init_patients: int = 8, init_events: int = 8, device=None,
                 telemetry=None, labels: dict | None = None,
                 disk_bytes: int | None = None, disk_dir: str | None = None,
                 dictionary=None):
        self.pad_multiple = pad_multiple
        self.budget_bytes = budget_bytes
        self.disk_bytes = disk_bytes
        self.device = device
        self.obs = telemetry if telemetry is not None else obs_lib.NOOP
        lbl = labels or {}
        m = self.obs.metrics
        self._m_admits = m.counter("store.admits", **lbl)
        self._m_restores = m.counter("store.restores", **lbl)
        self._m_evictions = m.counter("store.evictions", **lbl)
        self._m_growths = m.counter("store.plane_growths", **lbl)
        self._m_shrinks = m.counter("store.plane_shrinks", **lbl)
        self._m_resident = m.gauge("store.resident_rows", **lbl)
        self._m_spilled = m.gauge("store.spilled_patients", **lbl)
        self._m_plane_bytes = m.gauge("store.plane_bytes", **lbl)
        self._m_occupancy = m.gauge("store.plane_occupancy", **lbl)
        self._m_resident_cost = m.gauge("store.resident_pair_bytes", **lbl)
        self._m_budget = m.gauge("store.budget_bytes", **lbl)
        self._m_demotions = m.counter("storage.demotions", **lbl)
        self.phenx = jnp.zeros((init_patients, init_events), jnp.int32)
        self.date = jnp.zeros((init_patients, init_events), jnp.int32)
        self.nevents = jnp.zeros(init_patients, jnp.int32)
        if device is not None:
            self.phenx = jax.device_put(self.phenx, device)
            self.date = jax.device_put(self.date, device)
            self.nevents = jax.device_put(self.nevents, device)
        self.rows: dict = {}          # patient key -> physical row
        self.pids: dict = {}          # patient key -> stable dense pid
        self.row_key: dict = {}       # physical row -> patient key
        self._free: list[int] = list(range(init_patients - 1, -1, -1))
        self._touch = np.zeros(init_patients, np.int64)
        self._clock = 0
        self._next_pid = 0            # pids are never reused after extract
        # residency walk below the device planes: host, then (optional) disk
        self.host = tiers_lib.HostTier(self.obs, lbl)
        self.disk = (tiers_lib.DiskTier(disk_dir, dictionary=dictionary,
                                        telemetry=self.obs, labels=lbl)
                     if disk_bytes is not None or disk_dir is not None
                     else None)
        self._tiers: list = ([self.host, self.disk]
                             if self.disk is not None else [self.host])

    # --- capacity -----------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.phenx.shape[0]

    @property
    def max_events(self) -> int:
        return self.phenx.shape[1]

    @property
    def n_patients(self) -> int:
        """Distinct patients currently held (resident + spilled)."""
        return len(self.pids)

    @property
    def pid_capacity(self) -> int:
        """One past the largest pid ever assigned (pids outlive extraction,
        so tables indexed by pid must size by this, not ``n_patients``)."""
        return self._next_pid

    def _round(self, n: int) -> int:
        return -(-max(n, 1) // self.pad_multiple) * self.pad_multiple

    def ensure_event_capacity(self, min_events: int) -> None:
        need = self._round(min_events)
        if need <= self.max_events:
            return
        need = max(need, 2 * self.max_events)  # geometric: O(log) recompiles
        grow = need - self.max_events
        self.phenx = jnp.pad(self.phenx, ((0, 0), (0, grow)))
        self.date = jnp.pad(self.date, ((0, 0), (0, grow)))
        self._m_growths.inc()

    def _ensure_rows(self, n_more: int) -> None:
        if len(self._free) >= n_more:
            return
        old = self.n_rows
        new_rows = max(old, self._round(n_more))
        self.phenx = jnp.pad(self.phenx, ((0, new_rows), (0, 0)))
        self.date = jnp.pad(self.date, ((0, new_rows), (0, 0)))
        self.nevents = jnp.pad(self.nevents, (0, new_rows))
        self._touch = np.pad(self._touch, (0, new_rows))
        self._free.extend(range(old + new_rows - 1, old - 1, -1))
        self._m_growths.inc()

    # --- admission ----------------------------------------------------------
    def admit(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Rows (allocating / restoring as needed) + stable pids for keys.

        Keys must be distinct: cursors are read once per batch, so a
        repeated key would overwrite its own events (the service's wave
        admission defers repeats to the next tick)."""
        if len(set(keys)) != len(keys):
            raise ValueError("duplicate patient keys in one admit batch")
        missing = [k for k in keys if k not in self.rows]
        self._ensure_rows(len(missing))
        restored = []
        for k in missing:
            row = self._free.pop()
            self.rows[k] = row
            self.row_key[row] = k
            if k not in self.pids:
                self.pids[k] = self._next_pid
                self._next_pid += 1
            tier = self.tier_holding(k)
            if tier is not None:
                restored.append((row, *tier.restore(k)))
        if restored:
            d = max(len(ph) for _, ph, _ in restored)
            self.ensure_event_capacity(d)
            rows = np.asarray([r for r, _, _ in restored], np.int32)
            ph = np.zeros((len(restored), d), np.int32)
            dt = np.zeros((len(restored), d), np.int32)
            nn = np.zeros(len(restored), np.int32)
            for i, (_, p, t) in enumerate(restored):
                ph[i, : len(p)] = p
                dt[i, : len(p)] = t
                nn[i] = len(p)
            self.phenx, self.date, self.nevents = _append_step(
                self.phenx, self.date, self.nevents,
                jnp.asarray(rows), jnp.asarray(ph), jnp.asarray(dt),
                jnp.asarray(nn))
        self._clock += 1
        out_rows = np.asarray([self.rows[k] for k in keys], np.int32)
        self._touch[out_rows] = self._clock
        self._m_admits.inc(len(missing))
        self._m_restores.inc(len(restored))
        self._m_resident.set(len(self.rows))
        return out_rows, np.asarray([self.pids[k] for k in keys], np.int32)

    def append(self, rows, new_phenx, new_date, n_new) -> None:
        """Append padded [B, D] deltas at the cursors of ``rows`` (distinct)."""
        rows = np.asarray(rows, np.int32)
        if len(np.unique(rows)) != len(rows):
            raise ValueError("duplicate rows in one append batch")
        n_old = np.asarray(self.nevents)[rows]
        self.ensure_event_capacity(int((n_old + np.asarray(n_new)).max(initial=1)))
        self.phenx, self.date, self.nevents = _append_step(
            self.phenx, self.date, self.nevents, jnp.asarray(rows, jnp.int32),
            jnp.asarray(new_phenx, jnp.int32), jnp.asarray(new_date, jnp.int32),
            jnp.asarray(n_new, jnp.int32))

    # --- eviction -----------------------------------------------------------
    def evict_over_budget(self) -> tuple[list, list]:
        """Spill least-recently-touched patients until the *mining working
        set* (pair-slab cost, BYTES_PER_PAIR model) fits the budget.

        Reuses ``chunking.plan_chunks``: patients ordered most-recent-first,
        the first planned chunk is the resident set, the tail spills.  Note
        the budget bounds resident mining cost, not raw plane allocation:
        the padded planes grow monotonically and at least one patient
        always stays resident.  Returns ``(evicted, demoted)`` key lists
        (device -> host spills and the host -> disk demotions they
        triggered) — the payload of the ``Evicted`` session event.
        """
        if self.budget_bytes is None or not self.rows:
            return [], []
        resident = np.asarray(sorted(self.rows.values()), np.int64)
        order = resident[np.argsort(-self._touch[resident], kind="stable")]
        nev = np.asarray(self.nevents)[order]
        plan = chunking.plan_chunks(nev, self.budget_bytes,
                                    self.pad_multiple, layout="dense")
        victims = order[plan[0].stop:]
        if len(victims) == 0:
            return [], []
        # one host gather + one device scatter for the whole wave
        ph = np.asarray(self.phenx[victims])
        dt = np.asarray(self.date[victims])
        nn = nev[plan[0].stop:]
        evicted = []
        for i, row in enumerate(victims):
            key = self.row_key.pop(int(row))
            n = int(nn[i])
            self.host.hold(key, ph[i, :n], dt[i, :n])
            del self.rows[key]
            self._free.append(int(row))
            evicted.append(key)
        self.nevents = self.nevents.at[jnp.asarray(victims)].set(0)
        demoted = self._demote_over_budget()
        self._m_evictions.inc(len(evicted))
        self._m_resident.set(len(self.rows))
        self._m_spilled.set(self.spilled_count)
        return evicted, demoted

    def _demote_over_budget(self) -> list:
        """Walk the host tier oldest-spill-first, demoting histories to the
        compressed disk tier until the host spill working set fits
        ``disk_bytes`` — the same n^2 * BYTES_PER_PAIR cost model as the
        device budget, applied one boundary down.  No disk tier (or no
        budget) means the host tier is unbounded, the pre-tier behavior.
        Returns the demoted keys in demotion order."""
        if self.disk is None or self.disk_bytes is None:
            return []
        counts = self.host.event_counts()
        cost = sum(n * n for n in counts.values()) * chunking.BYTES_PER_PAIR
        demoted: list = []
        for key in self.host.keys():
            if cost <= self.disk_bytes:
                break
            ph, dt = self.host.peek(key)
            self.disk.hold(key, ph, dt)
            self.host.drop(key)
            cost -= counts[key] ** 2 * chunking.BYTES_PER_PAIR
            demoted.append(key)
        if demoted:
            self._m_demotions.inc(len(demoted))
        return demoted

    # --- migration handoff --------------------------------------------------
    def extract(self, key) -> tuple[int, np.ndarray, np.ndarray]:
        """Withdraw a patient entirely, returning ``(pid, phenx, date)``.

        The history comes back as 1-D host arrays — the spill format — so
        the receiving store's ``admit_state`` is exactly the spill-restore
        path.  The pid is retired, never reused; the freed row returns to
        the pool and ``shrink_to_fit`` reclaims plane capacity when the
        departing patient was a high-water mark.
        """
        if key not in self.pids:
            raise KeyError(key)
        if key in self.rows:
            row = self.rows.pop(key)
            del self.row_key[row]
            n = int(self.nevents[row])
            # full-row gather (stable shape), slice on host: an exact-n
            # device slice would compile one program per history length
            ph = np.asarray(self.phenx[row])[:n]
            dt = np.asarray(self.date[row])[:n]
            self.nevents = self.nevents.at[row].set(0)
            self._free.append(row)
        else:
            ph, dt = self.tier_holding(key).restore(key)
        pid = self.pids.pop(key)
        self.shrink_to_fit()
        return pid, ph, dt

    def admit_state(self, key, phenx, date) -> int:
        """Admit a migrated-in patient with pre-existing history; returns
        its fresh pid.  The history lands in the host-spill slot and
        restores on first touch, reusing the eviction machinery verbatim
        (no plane growth until the patient is actually mined again)."""
        if key in self.pids:
            raise ValueError(f"key {key!r} already admitted")
        pid = self._next_pid
        self._next_pid += 1
        self.pids[key] = pid
        self.host.hold(key, phenx, date)
        self._demote_over_budget()
        return pid

    def shrink_to_fit(self) -> None:
        """Release plane capacity after departures.  True hysteresis on
        both axes: shrink fires only when <= half the axis is live, and
        releases at most one doubling step per call — a high-water-mark
        patient bouncing out and back (rebalance ping-pong) costs O(log)
        reshape/retrace round trips, never one per migration."""
        hwm_e = self._round(int(np.asarray(self.nevents).max(initial=1)))
        if 2 * hwm_e <= self.max_events:
            need_e = max(hwm_e, self._round(self.max_events // 2))
            self.phenx = self.phenx[:, :need_e]
            self.date = self.date[:, :need_e]
            self._m_shrinks.inc()
        top = max(self.rows.values(), default=-1)
        hwm_r = self._round(top + 1)
        if 2 * hwm_r <= self.n_rows:
            need_r = max(hwm_r, self._round(self.n_rows // 2))
            self.phenx = self.phenx[:need_r]
            self.date = self.date[:need_r]
            self.nevents = self.nevents[:need_r]
            self._touch = self._touch[:need_r]
            self._free = [r for r in self._free if r < need_r]
            self._m_shrinks.inc()

    def sample_metrics(self) -> None:
        """Snapshot-time gauges: plane bytes/occupancy and the resident
        mining working set vs budget (the eviction signal), priced with
        the same BYTES_PER_PAIR model the planner and evictor use."""
        if not self.obs.enabled:
            return
        nev = np.asarray(self.nevents)
        self._m_plane_bytes.set(
            int(self.phenx.size + self.date.size + self.nevents.size) * 4)
        self._m_occupancy.set(
            float(nev.sum()) / max(self.n_rows * self.max_events, 1))
        self._m_resident_cost.set(
            int((nev.astype(np.int64) ** 2).sum()) * chunking.BYTES_PER_PAIR)
        self._m_budget.set(self.budget_bytes or 0)
        self._m_resident.set(len(self.rows))
        self._m_spilled.set(self.spilled_count)

    # --- introspection ------------------------------------------------------
    @property
    def spilled_count(self) -> int:
        """Patients held below the device planes (all tiers)."""
        return sum(len(t) for t in self._tiers)

    def tier_holding(self, key):
        """The residency tier currently holding ``key``, or None if the
        patient is device-resident (or unknown)."""
        for tier in self._tiers:
            if key in tier:
                return tier
        return None

    def tier_of(self, key) -> str | None:
        """'device' / 'host' / 'disk' for a held patient, None if unknown."""
        if key in self.rows:
            return "device"
        tier = self.tier_holding(key)
        return tier.name if tier is not None else None

    def held_keys(self) -> list:
        """Keys held below the device planes, promotion-order (host tier
        first, oldest spill first)."""
        return [k for tier in self._tiers for k in tier.keys()]

    def iter_held(self):
        """Yield ``(key, phenx, date)`` for every non-resident patient
        without promoting it (disk blocks are decoded, not withdrawn)."""
        for tier in self._tiers:
            for k in tier.keys():
                ph, dt = tier.peek(k)
                yield k, ph, dt

    def event_counts(self) -> dict:
        """Per-patient event counts across every tier — resident rows from
        the device cursors, host copies by length, disk blocks from the
        index alone (no decode): the shard cost model's one choke point."""
        nev = np.asarray(self.nevents)
        counts = {k: int(nev[r]) for k, r in self.rows.items()}
        for tier in self._tiers:
            counts.update(tier.event_counts())
        return counts

    def history(self, key) -> tuple[np.ndarray, np.ndarray]:
        """(phenx, date) events stored for a patient (resident or held)."""
        tier = self.tier_holding(key)
        if tier is not None:
            return tier.peek(key)
        row = self.rows[key]
        n = int(self.nevents[row])
        return np.asarray(self.phenx[row, :n]), np.asarray(self.date[row, :n])

    # --- checkpoint ---------------------------------------------------------
    def state_dict(self) -> dict:
        """Full residency state as a pack_tree-able tree.  Everything that
        makes continuation byte-identical is captured: plane contents *and
        shapes* (jit retrace stability), row assignments, the free-list
        order, LRU clocks, pid watermark, and every held history with its
        tier, so a restored store resumes the exact residency walk."""
        held = []
        for tier in self._tiers:
            for k in tier.keys():
                ph, dt = tier.peek(k)
                held.append({"key": encode_key(k), "tier": tier.name,
                             "phenx": np.asarray(ph), "date": np.asarray(dt)})
        return {
            "phenx": np.asarray(self.phenx),
            "date": np.asarray(self.date),
            "nevents": np.asarray(self.nevents),
            "touch": self._touch.copy(),
            "clock": self._clock,
            "next_pid": self._next_pid,
            "rows": [[encode_key(k), int(r)] for k, r in self.rows.items()],
            "pids": [[encode_key(k), int(p)] for k, p in self.pids.items()],
            "free": [int(r) for r in self._free],
            "held": held,
        }

    def load_state_dict(self, state: dict) -> None:
        """Inverse of :meth:`state_dict` (tier budgets/config come from the
        constructor, not the checkpoint)."""
        phenx = np.asarray(state["phenx"], np.int32)
        date = np.asarray(state["date"], np.int32)
        nevents = np.asarray(state["nevents"], np.int32)
        self.phenx = jnp.asarray(phenx)
        self.date = jnp.asarray(date)
        self.nevents = jnp.asarray(nevents)
        if self.device is not None:
            self.phenx = jax.device_put(self.phenx, self.device)
            self.date = jax.device_put(self.date, self.device)
            self.nevents = jax.device_put(self.nevents, self.device)
        self._touch = np.asarray(state["touch"], np.int64).copy()
        self._clock = int(state["clock"])
        self._next_pid = int(state["next_pid"])
        self.rows = {decode_key(k): int(r) for k, r in state["rows"]}
        self.pids = {decode_key(k): int(p) for k, p in state["pids"]}
        self.row_key = {r: k for k, r in self.rows.items()}
        self._free = [int(r) for r in state["free"]]
        for tier in self._tiers:
            for k in tier.keys():
                tier.drop(k)
        for entry in state["held"]:
            key = decode_key(entry["key"])
            tier = (self.disk
                    if entry["tier"] == "disk" and self.disk is not None
                    else self.host)
            tier.hold(key, entry["phenx"], entry["date"])
        self._m_resident.set(len(self.rows))
        self._m_spilled.set(self.spilled_count)

"""Micro-batching streaming ingest service + snapshot queries.

Modeled on serving/engine.py's wave scheduler: ``(patient, events)`` deltas
queue up, each tick admits up to ``tick_patients`` *patient slots* — a
patient's queued deltas coalesce chronologically into its slot, so one
flooding patient fills one slot with one big delta instead of deferring
the rest of its queue tick after tick — pads the slots to a ``[B, D]``
batch and runs one jitted ingest step:

    admit -> append at cursors -> delta-mine [B, E, D] slab
          -> online sketch update -> corpus log append

Shapes are bucketed (D and E round up to pad multiples, capacities grow
geometrically) so the jitted step retraces O(log) times, not per tick.

Snapshots expose the live corpus as flat (seq, dur, patient) arrays plus
the sketch's bucket table; ``starts_with`` / ``ends_with`` /
``min_duration`` masks come from core/queries and compose with the
hash-screen keep mask, exactly as on the batch path.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import NamedTuple

import numpy as np

from repro import obs as obs_lib
from repro.core import queries as queries_lib
from repro.core import sparsity
from repro.stream import counts as counts_lib
from repro.stream import delta as delta_lib
from repro.stream.events import DeltaSubmitted, Evicted, EventDispatcher, \
    Migrated, TickCompleted
from repro.stream.store import PatientStore
from repro.storage.codec import decode_key, encode_key


def _pow2_bucket(n: int, pad_multiple: int) -> int:
    """Smallest power-of-two multiple of ``pad_multiple`` >= n."""
    w = pad_multiple
    while w < n:
        w *= 2
    return w


@dataclasses.dataclass
class Delta:
    """One patient's new events (dates non-decreasing, and >= the dates
    already stored for the patient — streams arrive in time order)."""

    key: object
    dates: np.ndarray   # [d] int32
    phenx: np.ndarray   # [d] int32


class Snapshot(NamedTuple):
    """Flat live corpus + support table (masks all-true: only real pairs)."""

    seq: np.ndarray       # [N] int64
    dur: np.ndarray       # [N] int32
    patient: np.ndarray   # [N] int32 stable pids (admission order)
    counts: np.ndarray    # [2^H] int32 bucket support table
    n_buckets_log2: int


@dataclasses.dataclass
class TickStats:
    n_patients: int
    n_events: int
    n_pairs: int          # new pairs mined this tick (Delta * n work)
    wall_s: float         # begin-to-finish; concurrently-pending ticks on
                          # other shards overlap inside it, so summed
                          # per-shard walls exceed real elapsed time —
                          # sum dispatch_s + collect_s instead
    dispatch_s: float = 0.0   # host work in tick_begin (wave assembly +
                              # async enqueue); never overlaps (host-serial)
    collect_s: float = 0.0    # host work in tick_finish after the device
                              # completed; never overlaps (host-serial)
    device_s: float = 0.0     # dispatch-end -> completion-read of the
                              # tick's last enqueued device computation:
                              # the device-timed busy signal (an upper
                              # bound — a result collected late reads as
                              # busy through its idle tail)


@dataclasses.dataclass
class PendingTick:
    """A dispatched-but-uncollected tick: the mined slab and sketch fold
    are in flight on the service's device; ``tick_finish`` materializes
    them.  Lets a sharded tick enqueue every shard's mining before the
    first host-blocking read, so shards pinned to different devices
    overlap instead of running host-serial."""

    B: int
    pids: np.ndarray
    mined: object                 # Mined (device arrays, async)
    sketch_pending: object        # counts_lib._PendingSketchUpdate
    n_old: np.ndarray
    n_new: np.ndarray
    t0: float   # begin time; the resulting TickStats.wall_s spans
                # begin-to-finish, so concurrently-pending ticks on other
                # shards overlap inside it (sum != aggregate busy time)
    t_disp: float = 0.0           # dispatch-end time (tick_begin return)
    span_device: object = None    # open obs device span (dispatch->ready)
    keys: list = None             # wave patient keys, aligned with pids —
                                  # delta subscribers need keys, not pids


@dataclasses.dataclass
class PatientState:
    """Everything a patient owns on a shard — the migration payload.

    ``phenx``/``date`` are in the store's host-spill format, ``seq_ids``
    is the sketch's sorted distinct-sequence set, and the corpus arrays
    are the patient's already-mined (seq, dur) pairs; local pids stay
    behind (the destination assigns a fresh one)."""

    key: object
    phenx: np.ndarray        # [n] int32 event codes
    date: np.ndarray         # [n] int32 event dates
    seq_ids: np.ndarray      # [k] int64 sorted distinct sequence ids
    corpus_seq: np.ndarray   # [m] int64 mined pairs
    corpus_dur: np.ndarray   # [m] int32


class SnapshotQueries:
    """Snapshot query surface shared by the single- and sharded-shard
    services: core/queries masks over ``snapshot()`` composed with the
    ``screened_keep`` hash-screen mask, exactly as on the batch path.
    Hosts need ``snapshot()``, ``screened_keep(threshold, snap)``,
    ``self.codec`` and ``self.fuse_duration`` (fused snapshot ids carry
    the bucket in the low bits; unpacking them raw reads garbage)."""

    def _base(self, threshold: int | None) -> tuple[Snapshot, np.ndarray]:
        snap = self.snapshot()
        keep = (np.ones(len(snap.seq), bool) if threshold is None
                else self.screened_keep(threshold, snap))
        return snap, keep

    def query_starts_with(self, phenx_id: int, threshold: int | None = None):
        snap, keep = self._base(threshold)
        return np.asarray(queries_lib.starts_with(
            snap.seq, phenx_id, self.codec,
            fused=self.fuse_duration)) & keep

    def query_ends_with(self, phenx_id: int, threshold: int | None = None):
        snap, keep = self._base(threshold)
        return np.asarray(queries_lib.ends_with(
            snap.seq, phenx_id, self.codec,
            fused=self.fuse_duration)) & keep

    def query_min_duration(self, days: int, threshold: int | None = None):
        snap, keep = self._base(threshold)
        return np.asarray(queries_lib.min_duration(snap.dur, days)) & keep


class StreamService(SnapshotQueries):
    """Continuously-mined corpus: ingest deltas, query any time."""

    def __init__(self, tick_patients: int = 8, codec: str = "bit",
                 backend: str = "jnp", interpret: bool | None = None,
                 n_buckets_log2: int = 20, budget_bytes: int | None = None,
                 pad_multiple: int = 8, fuse_duration: bool = False,
                 bucket_days: int = 30, max_slot_events: int = 512,
                 device=None, telemetry=None, shard_tag: int | None = None,
                 retrace_tracker=None, disk_bytes: int | None = None,
                 disk_dir: str | None = None):
        self.tick_patients = tick_patients
        self.max_slot_events = max_slot_events
        self.codec = codec
        self.backend = backend
        self.interpret = interpret
        self.fuse_duration = fuse_duration
        self.bucket_days = bucket_days
        self.device = device
        self.obs = telemetry if telemetry is not None else obs_lib.NOOP
        self.shard_tag = shard_tag
        self.events = EventDispatcher(self.obs)
        self.track = "stream" if shard_tag is None else f"shard{shard_tag}"
        labels = {} if shard_tag is None else {"shard": shard_tag}
        if disk_dir is not None and shard_tag is not None:
            # one blockstore per shard: a shared segment file would
            # interleave two shards' appends
            disk_dir = os.path.join(disk_dir, f"shard{shard_tag}")
        self.store = PatientStore(pad_multiple=pad_multiple,
                                  budget_bytes=budget_bytes, device=device,
                                  telemetry=self.obs, labels=labels,
                                  disk_bytes=disk_bytes, disk_dir=disk_dir)
        self.sketch = counts_lib.OnlineSupportSketch(n_buckets_log2,
                                                     device=device,
                                                     telemetry=self.obs,
                                                     labels=labels)
        self.queue: deque[Delta] = deque()
        self._corpus: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        # snapshot cache keyed (implicitly) on ``snapshot_version``: any
        # corpus/sketch mutation — tick, migration admit/extract, restore —
        # bumps the version and drops the cached gather, so two same-tick
        # snapshot() calls return the identical arrays
        self._snap: Snapshot | None = None
        self._snap_version = 0
        self.stats: list[TickStats] = []
        self._ticks_restored = 0    # ticks before the checkpoint we resumed
        # a sharded service shares one tracker across shards (the jit
        # caches are process-global; per-shard trackers would each count
        # the same compilation)
        self._retrace = retrace_tracker if retrace_tracker is not None \
            else (obs_lib.RetraceTracker() if self.obs.enabled else None)
        # metric objects resolved once; per-tick cost is inc/observe only
        m = self.obs.metrics
        self._labels = labels
        self._m_ticks = m.counter("stream.ticks", **labels)
        self._m_events = m.counter("stream.events", **labels)
        self._m_pairs = m.counter("stream.pairs", **labels)
        self._m_retraces = m.counter("jit.retraces", **labels)
        self._m_dispatch = m.histogram("stream.tick.dispatch_s", **labels)
        self._m_collect = m.histogram("stream.tick.collect_s", **labels)
        self._m_device = m.histogram("stream.tick.device_s", **labels)
        self._m_queue = m.gauge("stream.queue_depth", **labels)

    # --- ingest -------------------------------------------------------------
    def submit(self, key, dates, phenx) -> None:
        dates = np.asarray(dates, np.int32).reshape(-1)
        phenx = np.asarray(phenx, np.int32).reshape(-1)
        if len(dates) == 0:
            return
        self.queue.append(Delta(key, dates, phenx))
        if self.events.wants(DeltaSubmitted):
            self.events.emit(DeltaSubmitted(key, dates, phenx,
                                            shard=self.shard_tag))

    def _next_wave(self) -> list[Delta]:
        """Slot-level admission: up to ``tick_patients`` patient slots, and
        queued deltas for an admitted patient coalesce into its slot
        (dates arrive in order, and the delta slab's triangular mask makes
        one concatenated delta mine the same pairs as its parts ticked
        separately).  A slot stops coalescing at ``max_slot_events`` —
        the wave's slab is padded to its *widest* slot, so an unbounded
        slot would multiply every other patient's slab row by the flood
        width — and once closed, the patient's remaining deltas defer in
        order.  A flood thus drains in O(total/max_slot_events) ticks
        (instead of one delta per tick), without inflating the batch."""
        slots: dict[object, list[Delta]] = {}
        width: dict[object, int] = {}
        closed: set = set()
        deferred: list[Delta] = []
        for _ in range(len(self.queue)):
            d = self.queue.popleft()
            held = slots.get(d.key)
            if d.key in closed:
                deferred.append(d)
            elif held is not None:
                if width[d.key] + len(d.dates) > self.max_slot_events:
                    closed.add(d.key)       # keep per-patient arrival order
                    deferred.append(d)
                else:
                    held.append(d)
                    width[d.key] += len(d.dates)
            elif len(slots) < self.tick_patients:
                slots[d.key] = [d]
                width[d.key] = len(d.dates)
            else:
                deferred.append(d)
        self.queue.extend(deferred)
        # one concat per slot, not per queued delta: a k-delta flood
        # coalesces in O(k), not O(k^2)
        return [ds[0] if len(ds) == 1 else Delta(
                    key, np.concatenate([d.dates for d in ds]),
                    np.concatenate([d.phenx for d in ds]))
                for key, ds in slots.items()]

    def tick(self) -> TickStats | None:
        """Ingest one padded wave; returns stats (None if queue empty)."""
        pending = self.tick_begin()
        return None if pending is None else self.tick_finish(pending)

    def tick_begin(self) -> PendingTick | None:
        """Assemble and *dispatch* one wave without collecting results.

        Everything device-side (append scatter, delta slab, sketch fold)
        is enqueued asynchronously; the only device sync is the cursor
        read, which waits on the previous tick's cheap scatters, never on
        mining.  A sharded service calls this on every shard first, so
        each device starts mining before any shard's results are pulled
        back; ``tick_finish`` must run before the next ``tick_begin`` on
        the *same* service (the corpus log and eviction are per-wave)."""
        wave = self._next_wave()
        if not wave:
            return None
        t0 = time.perf_counter()
        sp = self.obs.tracer.begin("tick.dispatch", cat="host",
                                   track=self.track)
        B = len(wave)
        pm = self.store.pad_multiple
        # slab widths bucket geometrically (powers of two over the pad
        # multiple), like the store planes: rounding to pad_multiple alone
        # yields a *linear* family of jit shapes as histories grow —
        # tests/test_obs.py's retrace budget measures the O(log) promise
        D = _pow2_bucket(max(len(d.dates) for d in wave), pm)
        new_phenx = np.zeros((B, D), np.int32)
        new_date = np.zeros((B, D), np.int32)
        n_new = np.zeros(B, np.int32)
        for i, d in enumerate(wave):
            n_new[i] = len(d.dates)
            new_phenx[i, : n_new[i]] = d.phenx
            new_date[i, : n_new[i]] = d.dates

        rows, pids = self.store.admit([d.key for d in wave])
        n_old = np.asarray(self.store.nevents)[rows].copy()
        self.store.append(rows, new_phenx, new_date, n_new)

        # slab i-axis only needs the wave's own history extent, not the
        # longest patient in the whole store; clamped to the plane width
        # (itself geometric) so the slice below stays in bounds
        Ew = min(_pow2_bucket(int((n_old + n_new).max(initial=1)), pm),
                 self.store.max_events)
        mined = delta_lib.delta_mine(
            self.store.phenx[rows, :Ew], self.store.date[rows, :Ew],
            n_old, n_new, new_phenx, new_date, codec=self.codec,
            fuse_duration=self.fuse_duration, bucket_days=self.bucket_days,
            backend=self.backend, interpret=self.interpret)
        sketch_pending = self.sketch.update_begin(pids, mined.seq, mined.mask)
        t_disp = time.perf_counter()
        self.obs.tracer.finish(sp, patients=B, events=int(n_new.sum()))
        # the device span stays open across the async gap; tick_finish
        # closes it at completion-read, so overlapped shards' device
        # spans visibly overlap in the exported trace
        sp_dev = self.obs.tracer.begin("tick.device", cat="device",
                                       track=self.track)
        return PendingTick(B, pids, mined, sketch_pending, n_old, n_new, t0,
                           t_disp, sp_dev, keys=[d.key for d in wave])

    def tick_finish(self, pending: PendingTick) -> TickStats:
        """Collect a dispatched wave: materialize the mined slab, finish
        the sketch's host bookkeeping, append the corpus log, evict."""
        B, mined, pids = pending.B, pending.mined, pending.pids
        # completion-read timing: block on the tick's *last* enqueued
        # device computation (the sketch fold depends on the mined slab),
        # so t_ready - t_disp times the dispatched chain itself, not the
        # host-serial collect work that follows
        novel = pending.sketch_pending.n_novel
        if hasattr(novel, "block_until_ready"):
            novel.block_until_ready()
        t_ready = time.perf_counter()
        if pending.span_device is not None:
            self.obs.tracer.finish(pending.span_device)
        sp = self.obs.tracer.begin("tick.collect", cat="host",
                                   track=self.track)
        self.sketch.update_finish(pending.sketch_pending)
        m = np.asarray(mined.mask).reshape(B, -1)
        seq = np.asarray(mined.seq).reshape(B, -1)
        dur = np.asarray(mined.dur).reshape(B, -1)
        pat = np.broadcast_to(pids[:, None], m.shape)
        seq_m, dur_m = seq[m], dur[m]
        self._corpus.append((seq_m, dur_m, pat[m]))
        self._invalidate_snapshot()
        tick_ev = None
        if self.events.wants(TickCompleted) and pending.keys is not None:
            # the tick's newly-mined rows, keyed by patient *key* (slot
            # index into ``keys``), for incremental consumers (the serving
            # feature store); migration admits are not re-delivered — the
            # rows were already mined (and delivered) on the source shard.
            # seq/dur are the corpus log's own arrays (one masked
            # selection per tick, not two) — subscribers must not mutate
            tick_ev = TickCompleted(
                tick=self.n_ticks + 1, service=self, keys=pending.keys,
                slot_idx=np.broadcast_to(np.arange(B)[:, None], m.shape)[m],
                seq=seq_m, dur=dur_m, shard=self.shard_tag)

        evicted, demoted = self.store.evict_over_budget()
        if (evicted or demoted) and self.events.wants(Evicted):
            self.events.emit(Evicted(tuple(evicted), tuple(demoted),
                                     shard=self.shard_tag))
        t_end = time.perf_counter()
        st = TickStats(
            n_patients=B, n_events=int(pending.n_new.sum()),
            n_pairs=int(delta_lib.count_delta_pairs(pending.n_old,
                                                    pending.n_new)),
            wall_s=t_end - pending.t0,
            dispatch_s=pending.t_disp - pending.t0,
            collect_s=t_end - t_ready,
            device_s=t_ready - pending.t_disp)
        self.stats.append(st)
        self.obs.tracer.finish(sp, pairs=st.n_pairs)
        self._m_ticks.inc()
        self._m_events.inc(st.n_events)
        self._m_pairs.inc(st.n_pairs)
        self._m_dispatch.observe(st.dispatch_s)
        self._m_collect.observe(st.collect_s)
        self._m_device.observe(st.device_s)
        self._m_queue.set(len(self.queue))
        if self._retrace is not None:
            self._m_retraces.inc(self._retrace.sample())
        if tick_ev is not None:
            self.events.emit(tick_ev)
        return st

    def run(self) -> list[TickStats]:
        """Drain the queue; returns per-tick stats."""
        out = []
        while self.queue:
            out.append(self.tick())
        return out

    @property
    def n_ticks(self) -> int:
        """Lifetime tick count, surviving checkpoint/restore (``stats``
        holds only the ticks since this process started)."""
        return self._ticks_restored + len(self.stats)

    # --- change feed --------------------------------------------------------
    @property
    def snapshot_version(self) -> int:
        """Monotone corpus/sketch state version: bumps on every mutation
        that would change ``snapshot()`` (tick, migration admit/extract,
        restore).  Two calls at the same version return the identical
        cached snapshot; serving replicas key their published views (and
        staleness gauges) on it."""
        return self._snap_version

    def _invalidate_snapshot(self) -> None:
        self._snap = None
        self._snap_version += 1

    def subscribe(self, fn, kinds=None, isolate: bool = True):
        """Register ``fn(event)`` on this service's typed event stream
        (see :mod:`repro.stream.events`); ``kinds`` filters to a
        SessionEvent subclass or iterable of them."""
        return self.events.subscribe(fn, kinds=kinds, isolate=isolate)

    def subscribe_delta(self, fn) -> None:
        """Deprecated shim over :meth:`subscribe`: ``fn(keys, slot_idx,
        seq, dur)`` per tick's newly-mined corpus rows (``slot_idx``
        indexes ``keys``).  New code should subscribe to
        :class:`~repro.stream.events.TickCompleted` directly."""
        self.events.subscribe(
            lambda ev: fn(ev.keys, ev.slot_idx, ev.seq, ev.dur),
            kinds=TickCompleted)

    def subscribe_tick(self, fn) -> None:
        """Deprecated shim over :meth:`subscribe`: ``fn(service)`` after
        every completed tick — the publication boundary for
        snapshot-isolated read replicas.  New code should subscribe to
        :class:`~repro.stream.events.TickCompleted` directly."""
        self.events.subscribe(lambda ev: fn(ev.service),
                              kinds=TickCompleted)

    def sample_metrics(self) -> None:
        """Set the snapshot-time gauges that are too costly per tick:
        plane occupancy / byte gauges (host ints) and the sketch bucket
        load factor (one device->host table copy).  Called by
        ``MiningSession.metrics()`` and the launcher dumps, never from
        the tick hot path."""
        if not self.obs.enabled:
            return
        self.store.sample_metrics()
        self.sketch.sample_metrics()
        self._m_queue.set(len(self.queue))

    # --- migration handoff --------------------------------------------------
    def extract_patient(self, key) -> PatientState:
        """Withdraw a patient's full state (store history, sketch row,
        mined corpus rows) for handoff to another service.  Queued deltas
        are the caller's responsibility (the sharded router moves them)."""
        pid, ph, dt = self.store.extract(key)
        ids = self.sketch.extract_row(pid)
        cseq, cdur = self._extract_corpus(pid)
        self._invalidate_snapshot()
        return PatientState(key, ph, dt, ids, cseq, cdur)

    def admit_patient(self, state: PatientState) -> int:
        """Install a migrated patient under a fresh local pid; the inverse
        of ``extract_patient`` (extract there + admit here is exact: the
        two sketch tables transfer by subtract/add, the corpus rows move
        verbatim)."""
        pid = self.store.admit_state(state.key, state.phenx, state.date)
        self.sketch.admit_row(pid, state.seq_ids)
        if len(state.corpus_seq):
            self._corpus.append((
                np.asarray(state.corpus_seq, np.int64),
                np.asarray(state.corpus_dur, np.int32),
                np.full(len(state.corpus_seq), pid, np.int32)))
        self._invalidate_snapshot()
        if self.events.wants(Migrated):
            # an external handoff (the sharded service journals its own
            # migrations and keeps this silent by not subscribing here)
            self.events.emit(Migrated(state.key, src=None,
                                      dst=self.shard_tag or 0, state=state))
        return pid

    def _extract_corpus(self, pid: int) -> tuple[np.ndarray, np.ndarray]:
        """Split the live corpus log: returns (and removes) pid's rows.

        Blocks without the patient are kept by reference, so a migration
        only rewrites the log blocks the patient actually appears in (not
        the whole log per move, which would make rebalancing O(corpus))."""
        out_seq: list[np.ndarray] = []
        out_dur: list[np.ndarray] = []
        kept = []
        for bseq, bdur, bpat in self._corpus:
            sel = bpat == pid
            if sel.any():
                out_seq.append(bseq[sel])
                out_dur.append(bdur[sel])
                kept.append((bseq[~sel], bdur[~sel], bpat[~sel]))
            else:
                kept.append((bseq, bdur, bpat))
        self._corpus = kept
        if not out_seq:
            return np.zeros(0, np.int64), np.zeros(0, np.int32)
        return np.concatenate(out_seq), np.concatenate(out_dur)

    # --- checkpoint ---------------------------------------------------------
    def state_dict(self) -> dict:
        """Everything a restarted service needs to continue byte-identically:
        store residency (planes, tiers, clocks), the sketch, queued deltas
        in arrival order, and the flat mined corpus (concatenated — block
        boundaries are an internal detail; flat order is what snapshots
        expose and compaction already collapses them)."""
        if self._corpus:
            seq = np.concatenate([c[0] for c in self._corpus])
            dur = np.concatenate([c[1] for c in self._corpus])
            pat = np.concatenate([c[2] for c in self._corpus]).astype(np.int32)
        else:
            seq = np.zeros(0, np.int64)
            dur = pat = np.zeros(0, np.int32)
        return {
            "store": self.store.state_dict(),
            "sketch": self.sketch.state_dict(),
            "queue": [{"key": encode_key(d.key), "dates": d.dates,
                       "phenx": d.phenx} for d in self.queue],
            "corpus": {"seq": seq, "dur": dur, "patient": pat},
            "n_ticks": self.n_ticks,
        }

    def load_state_dict(self, state: dict) -> None:
        self.store.load_state_dict(state["store"])
        self.sketch.load_state_dict(state["sketch"])
        self.queue = deque(
            Delta(decode_key(d["key"]),
                  np.asarray(d["dates"], np.int32),
                  np.asarray(d["phenx"], np.int32))
            for d in state["queue"])
        corpus = state["corpus"]
        seq = np.asarray(corpus["seq"], np.int64)
        self._corpus = ([(seq, np.asarray(corpus["dur"], np.int32),
                          np.asarray(corpus["patient"], np.int32))]
                        if len(seq) else [])
        # stats carry wall-clock timings, which are not state; only the
        # lifetime tick count survives a restore (checkpoint step numbering)
        self._ticks_restored = int(state.get("n_ticks", 0))
        self._invalidate_snapshot()

    # --- snapshot / queries -------------------------------------------------
    def snapshot(self) -> Snapshot:
        if self._snap is not None:
            return self._snap
        if self._corpus:
            seq = np.concatenate([c[0] for c in self._corpus])
            dur = np.concatenate([c[1] for c in self._corpus])
            pat = np.concatenate([c[2] for c in self._corpus]).astype(np.int32)
            self._corpus = [(seq, dur, pat)]   # compact: next tick appends
        else:
            seq = np.zeros(0, np.int64)
            dur = pat = np.zeros(0, np.int32)
        self._snap = Snapshot(seq, dur, pat, np.asarray(self.sketch.counts),
                              self.sketch.n_buckets_log2)
        return self._snap

    def screened_keep(self, threshold: int,
                      snap: Snapshot | None = None) -> np.ndarray:
        """Hash-screen keep mask over the live corpus (one-sided error)."""
        snap = snap if snap is not None else self.snapshot()
        return np.asarray(self.sketch.keep_mask(
            snap.seq, np.ones(len(snap.seq), bool), threshold))

    def merged_counts(self, batch_counts) -> np.ndarray:
        """Live table merged with batch-screen counts (cold + hot cohorts)."""
        return np.asarray(sparsity.merge_bucket_counts(
            self.sketch.counts, batch_counts))

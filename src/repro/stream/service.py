"""Micro-batching streaming ingest service + snapshot queries.

Modeled on serving/engine.py's wave scheduler: ``(patient, events)`` deltas
queue up, each tick admits up to ``tick_patients`` *distinct* patients
(a second delta for the same patient defers to the next tick, like the
engine's length-bucketed admission), pads the deltas to a ``[B, D]`` batch
and runs one jitted ingest step:

    admit -> append at cursors -> delta-mine [B, E, D] slab
          -> online sketch update -> corpus log append

Shapes are bucketed (D and E round up to pad multiples, capacities grow
geometrically) so the jitted step retraces O(log) times, not per tick.

Snapshots expose the live corpus as flat (seq, dur, patient) arrays plus
the sketch's bucket table; ``starts_with`` / ``ends_with`` /
``min_duration`` masks come from core/queries and compose with the
hash-screen keep mask, exactly as on the batch path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import NamedTuple

import numpy as np

from repro.core import queries as queries_lib
from repro.core import sparsity
from repro.stream import counts as counts_lib
from repro.stream import delta as delta_lib
from repro.stream.store import PatientStore


@dataclasses.dataclass
class Delta:
    """One patient's new events (dates non-decreasing, and >= the dates
    already stored for the patient — streams arrive in time order)."""

    key: object
    dates: np.ndarray   # [d] int32
    phenx: np.ndarray   # [d] int32


class Snapshot(NamedTuple):
    """Flat live corpus + support table (masks all-true: only real pairs)."""

    seq: np.ndarray       # [N] int64
    dur: np.ndarray       # [N] int32
    patient: np.ndarray   # [N] int32 stable pids (admission order)
    counts: np.ndarray    # [2^H] int32 bucket support table
    n_buckets_log2: int


@dataclasses.dataclass
class TickStats:
    n_patients: int
    n_events: int
    n_pairs: int          # new pairs mined this tick (Delta * n work)
    wall_s: float


class SnapshotQueries:
    """Snapshot query surface shared by the single- and sharded-shard
    services: core/queries masks over ``snapshot()`` composed with the
    ``screened_keep`` hash-screen mask, exactly as on the batch path.
    Hosts need ``snapshot()``, ``screened_keep(threshold, snap)`` and
    ``self.codec``."""

    def _base(self, threshold: int | None) -> tuple[Snapshot, np.ndarray]:
        snap = self.snapshot()
        keep = (np.ones(len(snap.seq), bool) if threshold is None
                else self.screened_keep(threshold, snap))
        return snap, keep

    def query_starts_with(self, phenx_id: int, threshold: int | None = None):
        snap, keep = self._base(threshold)
        return np.asarray(queries_lib.starts_with(
            snap.seq, phenx_id, self.codec)) & keep

    def query_ends_with(self, phenx_id: int, threshold: int | None = None):
        snap, keep = self._base(threshold)
        return np.asarray(queries_lib.ends_with(
            snap.seq, phenx_id, self.codec)) & keep

    def query_min_duration(self, days: int, threshold: int | None = None):
        snap, keep = self._base(threshold)
        return np.asarray(queries_lib.min_duration(snap.dur, days)) & keep


class StreamService(SnapshotQueries):
    """Continuously-mined corpus: ingest deltas, query any time."""

    def __init__(self, tick_patients: int = 8, codec: str = "bit",
                 backend: str = "jnp", interpret: bool | None = None,
                 n_buckets_log2: int = 20, budget_bytes: int | None = None,
                 pad_multiple: int = 8, fuse_duration: bool = False,
                 bucket_days: int = 30):
        self.tick_patients = tick_patients
        self.codec = codec
        self.backend = backend
        self.interpret = interpret
        self.fuse_duration = fuse_duration
        self.bucket_days = bucket_days
        self.store = PatientStore(pad_multiple=pad_multiple,
                                  budget_bytes=budget_bytes)
        self.sketch = counts_lib.OnlineSupportSketch(n_buckets_log2)
        self.queue: deque[Delta] = deque()
        self._corpus: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._snap: Snapshot | None = None   # cache, invalidated per tick
        self.stats: list[TickStats] = []

    # --- ingest -------------------------------------------------------------
    def submit(self, key, dates, phenx) -> None:
        dates = np.asarray(dates, np.int32).reshape(-1)
        phenx = np.asarray(phenx, np.int32).reshape(-1)
        if len(dates) == 0:
            return
        self.queue.append(Delta(key, dates, phenx))

    def _next_wave(self) -> list[Delta]:
        """Distinct-patient admission; repeat deltas defer (engine idiom)."""
        wave: list[Delta] = []
        deferred: list[Delta] = []
        seen: set = set()
        while self.queue and len(wave) < self.tick_patients:
            d = self.queue.popleft()
            if d.key in seen:
                deferred.append(d)
            else:
                seen.add(d.key)
                wave.append(d)
        self.queue.extendleft(reversed(deferred))
        return wave

    def tick(self) -> TickStats | None:
        """Ingest one padded wave; returns stats (None if queue empty)."""
        wave = self._next_wave()
        if not wave:
            return None
        t0 = time.perf_counter()
        B = len(wave)
        pm = self.store.pad_multiple
        D = -(-max(len(d.dates) for d in wave) // pm) * pm
        new_phenx = np.zeros((B, D), np.int32)
        new_date = np.zeros((B, D), np.int32)
        n_new = np.zeros(B, np.int32)
        for i, d in enumerate(wave):
            n_new[i] = len(d.dates)
            new_phenx[i, : n_new[i]] = d.phenx
            new_date[i, : n_new[i]] = d.dates

        rows, pids = self.store.admit([d.key for d in wave])
        n_old = np.asarray(self.store.nevents)[rows].copy()
        self.store.append(rows, new_phenx, new_date, n_new)

        # slab i-axis only needs the wave's own history extent, not the
        # longest patient in the whole store
        Ew = -(-int((n_old + n_new).max(initial=1)) // pm) * pm
        mined = delta_lib.delta_mine(
            self.store.phenx[rows, :Ew], self.store.date[rows, :Ew],
            n_old, n_new, new_phenx, new_date, codec=self.codec,
            fuse_duration=self.fuse_duration, bucket_days=self.bucket_days,
            backend=self.backend, interpret=self.interpret)
        self.sketch.update(pids, mined.seq, mined.mask)

        m = np.asarray(mined.mask).reshape(B, -1)
        seq = np.asarray(mined.seq).reshape(B, -1)
        dur = np.asarray(mined.dur).reshape(B, -1)
        pat = np.broadcast_to(pids[:, None], m.shape)
        self._corpus.append((seq[m], dur[m], pat[m]))
        self._snap = None

        self.store.evict_over_budget()
        st = TickStats(
            n_patients=B, n_events=int(n_new.sum()),
            n_pairs=int(delta_lib.count_delta_pairs(n_old, n_new)),
            wall_s=time.perf_counter() - t0)
        self.stats.append(st)
        return st

    def run(self) -> list[TickStats]:
        """Drain the queue; returns per-tick stats."""
        out = []
        while self.queue:
            out.append(self.tick())
        return out

    # --- snapshot / queries -------------------------------------------------
    def snapshot(self) -> Snapshot:
        if self._snap is not None:
            return self._snap
        if self._corpus:
            seq = np.concatenate([c[0] for c in self._corpus])
            dur = np.concatenate([c[1] for c in self._corpus])
            pat = np.concatenate([c[2] for c in self._corpus]).astype(np.int32)
            self._corpus = [(seq, dur, pat)]   # compact: next tick appends
        else:
            seq = np.zeros(0, np.int64)
            dur = pat = np.zeros(0, np.int32)
        self._snap = Snapshot(seq, dur, pat, np.asarray(self.sketch.counts),
                              self.sketch.n_buckets_log2)
        return self._snap

    def screened_keep(self, threshold: int,
                      snap: Snapshot | None = None) -> np.ndarray:
        """Hash-screen keep mask over the live corpus (one-sided error)."""
        snap = snap if snap is not None else self.snapshot()
        return np.asarray(self.sketch.keep_mask(
            snap.seq, np.ones(len(snap.seq), bool), threshold))

    def merged_counts(self, batch_counts) -> np.ndarray:
        """Live table merged with batch-screen counts (cold + hot cohorts)."""
        return np.asarray(sparsity.merge_bucket_counts(
            self.sketch.counts, batch_counts))

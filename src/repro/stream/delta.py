"""Delta mining: pair only *new* events against stored history.

The batch miner (core/mining) fills the dense ``[P, E, E]`` pair matrix;
after appending ``d`` events to an ``n``-event history only the last ``d``
columns are new, so the streaming hot loop computes the ``[P, E, D]`` slab

    seq[p, i, j] = pack(phenx[p, i], new_phenx[p, j])
    valid iff     i < n_old[p] + j   and   j < n_new[p]

where the i-axis spans the *updated* history planes (delta already written
at the cursors) — new-x-new pairs are the ``i >= n_old`` rows of the same
slab.  ``delta_mine`` dispatches between the pure-jnp reference below and
the Pallas kernel (kernels/tspm_delta), mirroring ``mining.mine``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.core.mining import Mined
from repro.kernels.tspm_delta.ref import delta_planes_ref


@functools.partial(jax.jit, static_argnames=("codec", "fuse_duration", "bucket_days"))
def delta_mine_jnp(
    phenx, date, n_old, n_new, new_phenx, new_date, codec: str = "bit",
    fuse_duration: bool = False, bucket_days: int = 30,
) -> Mined:
    """Pure-jnp reference delta mining to the dense [P, E, D] slab."""
    s, e, dur, mask = delta_planes_ref(
        phenx, date, n_old, n_new, new_phenx, new_date)
    seq = encoding.pack(jnp.maximum(s, 0), jnp.maximum(e, 0), codec)
    if fuse_duration:
        seq = encoding.fuse_duration(
            seq, encoding.bucket_duration(dur, bucket_days))
    return Mined(jnp.where(mask, seq, encoding.SENTINEL), dur, mask)


def delta_mine(
    phenx, date, n_old, n_new, new_phenx, new_date, codec: str = "bit",
    fuse_duration: bool = False, bucket_days: int = 30,
    backend: str = "auto", interpret: bool | None = None,
) -> Mined:
    """Mine the new-pair slab.  backend: 'kernel' | 'jnp' | 'auto'."""
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "jnp"
    if backend == "kernel":
        from repro.kernels.tspm_delta import ops as delta_ops

        return delta_ops.delta_pairgen(
            phenx, date, n_old, n_new, new_phenx, new_date, codec=codec,
            fuse_duration=fuse_duration, bucket_days=bucket_days,
            interpret=interpret,
        )
    return delta_mine_jnp(phenx, date, n_old, n_new, new_phenx, new_date,
                          codec, fuse_duration, bucket_days)


def count_delta_pairs(n_old, n_new) -> jax.Array:
    """Closed-form new-pair count: sum_p [ d*n_old + d(d-1)/2 ] — the
    O(delta * n) streaming cost (vs the batch n(n-1)/2 re-mine)."""
    n_old = jnp.asarray(n_old, jnp.int64)
    d = jnp.asarray(n_new, jnp.int64)
    return jnp.sum(d * n_old + d * (d - 1) // 2)

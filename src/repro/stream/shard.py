"""Mesh-sharded streaming mining: patient->shard router over per-shard services.

The batch pipeline scales by sharding patients over the ('pod', 'data')
mesh and merging per-shard screen tables with one psum
(data/pipeline + core/sparsity.screen_hash).  The streaming analogue keeps
one :class:`~repro.stream.service.StreamService` (PatientStore +
OnlineSupportSketch + delta miner) per shard and adds two pieces:

  * **router** — a patient key is *sticky until migrated*: it routes to
    one shard (its history planes and sketch rows live there) either by a
    stable hash (streaming default: keys arrive unannounced) or by a
    pinned LPT assignment from ``data/pipeline.balance_buckets`` when
    per-patient event counts are known up front (replays, backfills) —
    pair cost is quadratic in events, so hash-balance is not
    work-balance.  ``migrate`` re-pins the key (``ShardRouter.assign``),
    so submissions after a handoff land on the new home;
  * **global screen** — per-shard sketch tables count distinct
    (patient, sequence) pairs over disjoint patient sets, so the global
    table is their elementwise sum: one psum over the ('data',) mesh
    (``distributed.sharding.merge_sharded_counts``), exactly the
    collective of the batch hash screen.  Queries compose snapshot masks
    with the merged table, so every query sees the whole cohort;
  * **live migration** — ``migrate(key, dst)`` hands a patient between
    shards mid-stream, and ``rebalance`` triggers migrations whenever the
    hottest shard's resident pair cost (``chunking.BYTES_PER_PAIR``, the
    model batch chunking and the LPT router already use) exceeds
    ``imbalance_threshold`` x the mean — a hash-hot shard stops being hot.

Handoff invariants (property-tested in tests/test_stream_migration.py):

  * *sticky-until-migrated routing* — a key's queued deltas move with it
    in arrival order and the router override lands every later submit on
    the destination, so no delta is ever mined against a partial history;
  * *subtract/add sketch transfer* — the patient's sorted distinct-id set
    moves wholesale; bucket counts are decremented at the source and
    incremented at the destination, so each shard table remains exactly
    ``local_bucket_counts`` of its own patients and the psum-merged table
    is invariant under any migration schedule;
  * *spill-format compatibility* — the store handoff payload is the
    host-spill format (1-D phenx/date arrays), admitted into the
    destination's spill slot: a migrated patient restores on first touch
    exactly like an evicted one, and plane capacity freed at the source
    shrinks when the patient was the high-water mark.

Replaying a dbmart through the sharded service with any interleaving of
migrations and rebalances equals the single-shard service and batch
mine+screen on corpus, support counts, and query masks, for any shard
count, router, and per-shard eviction budget
(tests/test_stream_sharded.py + tests/test_stream_migration.py).
"""
from __future__ import annotations

import time
import zlib
from collections import deque

import numpy as np

from repro import obs as obs_lib
from repro.core import chunking, sparsity
from repro.data import pipeline
from repro.distributed.sharding import merge_sharded_counts
from repro.launch.mesh import shard_devices
from repro.stream.events import DeltaSubmitted, Evicted, EventDispatcher, \
    Migrated, Rebalanced, TickCompleted
from repro.stream.service import PatientState, Snapshot, SnapshotQueries, \
    StreamService, TickStats
from repro.storage.codec import decode_key, encode_key

PLACEMENTS = ("host", "devices")


def stable_shard_hash(key) -> int:
    """Process-stable key hash (python ``hash`` is salted for strings)."""
    if isinstance(key, (int, np.integer)):
        # splitmix64 finalizer: avalanches dense patient ids
        h = (int(key) + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return h ^ (h >> 31)
    return zlib.crc32(repr(key).encode())


class ShardRouter:
    """Patient key -> shard id; sticky *until migrated* (a pure function of
    the key, overridden by the pinned table — balanced placement and
    migration handoffs both write there)."""

    def __init__(self, n_shards: int, pinned: dict | None = None):
        self.n_shards = n_shards
        self.pinned = pinned or {}

    def route(self, key) -> int:
        s = self.pinned.get(key)
        if s is None:
            s = stable_shard_hash(key) % self.n_shards
        return s

    def assign(self, key, shard: int) -> None:
        """Re-pin a key (migration handoff); later routes land on ``shard``."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.n_shards})")
        self.pinned[key] = shard

    @classmethod
    def balanced(cls, keys, nevents, n_shards: int) -> "ShardRouter":
        """Pin known patients by pair-count LPT (``balance_buckets``); keys
        not in the table still hash — cold starts keep working."""
        buckets = pipeline.balance_buckets(
            np.asarray(nevents, np.int64), n_shards)
        pinned = {keys[p]: s for s, b in enumerate(buckets) for p in b}
        return cls(n_shards, pinned)


class ShardedStreamService(SnapshotQueries):
    """StreamService API over ``n_shards`` shard-local services.

    ``mesh`` (a ('data',)-axis mesh) routes the global-table merge through
    the shard_map psum; without one the merge is a local sum — results are
    identical, only the collective differs.  ``rebalance_every`` (ticks)
    turns on load-triggered rebalancing: whenever the hottest shard's
    resident pair cost exceeds ``imbalance_threshold`` x the mean, its
    largest patients migrate to the coldest shard (greedy LPT, same
    ``BYTES_PER_PAIR`` cost model as batch chunking).  Remaining kwargs
    configure each shard's StreamService (note ``budget_bytes`` is *per
    shard*: the eviction working set is a shard-local property, like the
    per-chunk byte budget of batch chunking).

    ``placement`` picks where shard state lives and how ticks dispatch:

      * ``'host'`` — every shard on jax's default device, ticks run
        shard-serial (the pre-device behavior, and the conformance
        reference);
      * ``'devices'`` — shard ``s``'s store planes and sketch table are
        pinned to mesh position ``s`` (``launch.mesh.shard_devices``;
        round-robin when shards outnumber devices), and ``tick`` runs in
        two passes: every shard's wave is *dispatched*
        (``StreamService.tick_begin``) before any shard's results are
        collected, so the per-device mining overlaps instead of
        host-serializing.  Results are byte-identical to ``'host'``
        (same programs on the same values, one psum for the screen).

    ``async_migration`` (default: on exactly for ``'devices'``) makes
    ``migrate`` two-phase: phase 1 snapshots the source patient's
    spill-format state and enqueues it for the destination; phase 2 admits
    it at the next tick boundary, after the *other* shards' waves are
    already dispatched — so handoff wall-clock overlaps mining instead of
    serializing inside ``tick``.  Any read that needs whole-cohort state
    (snapshot, global counts, load accounting) flushes pending admits
    first, so results are again schedule-invariant.
    """

    def __init__(self, n_shards: int = 1, router: ShardRouter | None = None,
                 mesh=None, rebalance_every: int | None = None,
                 imbalance_threshold: float = 1.5, min_gain: float = 0.05,
                 placement: str = "host", async_migration: bool | None = None,
                 telemetry=None, busy_weighted_rebalance: bool = False,
                 **service_kwargs):
        if router is not None and router.n_shards != n_shards:
            raise ValueError(f"router covers {router.n_shards} shards, "
                             f"service has {n_shards}")
        if placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {placement!r}; one of {PLACEMENTS}")
        self.router = router or ShardRouter(n_shards)
        self.mesh = mesh
        self.rebalance_every = rebalance_every
        self.imbalance_threshold = imbalance_threshold
        self.min_gain = min_gain
        self.busy_weighted_rebalance = busy_weighted_rebalance
        self.placement = placement
        self.async_migration = (placement == "devices"
                                if async_migration is None else async_migration)
        self.devices = (shard_devices(n_shards, mesh)
                        if placement == "devices" else [None] * n_shards)
        self.obs = telemetry if telemetry is not None else obs_lib.NOOP
        # one retrace tracker for the whole sharded service: the jitted
        # ingest functions (and their caches) are process-global, so
        # per-shard trackers would each bill the same compilation
        retrace = obs_lib.RetraceTracker() if self.obs.enabled else None
        self.shards = [StreamService(device=d, telemetry=self.obs,
                                     shard_tag=s, retrace_tracker=retrace,
                                     **service_kwargs)
                       for s, d in enumerate(self.devices)]
        m = self.obs.metrics
        self._m_migrations = m.counter("shard.migrations")
        self._m_rebalances = m.counter("shard.rebalances")
        self._m_pending = m.gauge("shard.pending_admits")
        self.codec = self.shards[0].codec
        self.fuse_duration = self.shards[0].fuse_duration
        self.n_buckets_log2 = self.shards[0].sketch.n_buckets_log2
        self.pids: dict = {}        # key -> global pid (first-submit order)
        self.migrations: list[tuple] = []   # (key, src, dst) history
        self.migration_wall_s = 0.0         # host time spent in handoffs
        self.admit_wall_s = 0.0     # phase-2 admits (overlaps mining)
        self._pending_admits: list[list] = [[] for _ in range(n_shards)]
        self._pending_keys: dict = {}       # key -> dst with state in flight
        self._tick_count = 0
        # whole-cohort snapshot + merged-counts caches, keyed (implicitly)
        # on ``snapshot_version`` — invalidated together on any mutation
        self._snap: Snapshot | None = None
        self._gcounts: np.ndarray | None = None
        self._snap_version = 0
        self.events = EventDispatcher(self.obs)
        # per-shard events buffered during a sharded tick, re-emitted at
        # the cohort boundary in *shard-index* order (dispatch order
        # depends on which shards have pending admits — not a property
        # consumers, least of all the journal, should observe)
        self._collected: list[list] = [[] for _ in range(n_shards)]
        self._collector_installed = False
        # device-timed busy window for shard_load(): per-shard completion
        # -timed seconds (TickStats.device_s) accumulated since the last
        # shard_load() poll — maintained unconditionally (plain float
        # adds), so the busy signal works with telemetry disabled
        self._busy_acc = [0.0] * n_shards
        self._busy_t0 = time.perf_counter()

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def stats(self) -> list[TickStats]:
        return [st for svc in self.shards for st in svc.stats]

    @property
    def n_ticks(self) -> int:
        """Sharded tick count (one per cohort-wide wave) — the publication
        clock for serving replicas, mirroring StreamService.n_ticks."""
        return self._tick_count

    @property
    def snapshot_version(self) -> int:
        """Monotone whole-cohort state version (see
        StreamService.snapshot_version); bumps on tick, migrate, pending
        flush, and restore."""
        return self._snap_version

    def _invalidate_snapshot(self) -> None:
        self._snap = None
        self._gcounts = None
        self._snap_version += 1

    def _ensure_collector(self) -> None:
        """Install the per-shard event collector on first subscription —
        a service nobody listens to pays nothing per tick (the shard
        dispatchers' ``wants`` stays False)."""
        if self._collector_installed:
            return
        self._collector_installed = True
        for svc in self.shards:
            svc.events.subscribe(
                lambda ev: self._collected[ev.shard].append(ev),
                kinds=(TickCompleted, Evicted), isolate=False)

    def subscribe(self, fn, kinds=None, isolate: bool = True):
        """Register ``fn(event)`` on the cohort-level typed event stream
        (see :mod:`repro.stream.events`): one ``TickCompleted`` per
        sharded tick with the per-shard delta feeds concatenated in
        shard-index order, ``Evicted`` per shard, ``Migrated`` /
        ``Rebalanced`` at migration time."""
        self._ensure_collector()
        return self.events.subscribe(fn, kinds=kinds, isolate=isolate)

    def subscribe_delta(self, fn) -> None:
        """Deprecated shim over :meth:`subscribe`: ``fn(keys, slot_idx,
        seq, dur)`` with the cohort's newly-mined rows once per sharded
        tick (rows are keyed by patient key, so migrations don't
        re-deliver)."""
        self.subscribe(lambda ev: fn(ev.keys, ev.slot_idx, ev.seq, ev.dur),
                       kinds=TickCompleted)

    def subscribe_tick(self, fn) -> None:
        """Deprecated shim over :meth:`subscribe`: ``fn(service)`` after
        every completed *sharded* tick (all shard waves collected,
        pending admits flushed) — the publication boundary for replicas.
        Fires *before* any auto-rebalance triggered by the tick: the
        journal needs the tick's record ahead of the migrations it
        triggers, and a pre-rebalance view is the same cohort content."""
        self.subscribe(lambda ev: fn(ev.service), kinds=TickCompleted)

    def _emit_tick_events(self) -> None:
        """Re-emit the tick's buffered per-shard events at the cohort
        boundary: evictions per shard, then one aggregated
        ``TickCompleted`` — all in shard-index order."""
        col, self._collected = \
            self._collected, [[] for _ in range(self.n_shards)]
        if not (self.events.wants(TickCompleted)
                or self.events.wants(Evicted)):
            return
        for evs in col:
            for ev in evs:
                if isinstance(ev, Evicted) and self.events.wants(Evicted):
                    self.events.emit(ev)
        if not self.events.wants(TickCompleted):
            return
        keys: list = []
        slots, seqs, durs = [], [], []
        for evs in col:
            for ev in evs:
                if isinstance(ev, TickCompleted):
                    slots.append(np.asarray(ev.slot_idx) + len(keys))
                    seqs.append(ev.seq)
                    durs.append(ev.dur)
                    keys.extend(ev.keys)
        self.events.emit(TickCompleted(
            tick=self._tick_count, service=self, keys=keys,
            slot_idx=(np.concatenate(slots) if slots
                      else np.zeros(0, np.int64)),
            seq=(np.concatenate(seqs) if seqs else np.zeros(0, np.int64)),
            dur=(np.concatenate(durs) if durs else np.zeros(0, np.int32)),
            shard=None))

    # --- ingest -------------------------------------------------------------
    def submit(self, key, dates, phenx) -> None:
        if len(np.asarray(dates).reshape(-1)) == 0:
            return
        if key not in self.pids:
            self.pids[key] = len(self.pids)
        shard = self.router.route(key)
        self.shards[shard].submit(key, dates, phenx)
        if self.events.wants(DeltaSubmitted):
            self.events.emit(DeltaSubmitted(
                key, np.asarray(dates, np.int32).reshape(-1),
                np.asarray(phenx, np.int32).reshape(-1), shard=shard))

    def tick(self) -> list[TickStats]:
        """One wave on every shard with queued work.  Empty list == all
        queues drained (and no migration state left in flight).

        ``'devices'`` placement dispatches every shard's wave before
        collecting any (each device mines while the host assembles the
        next shard's wave); ``'host'`` keeps the serial per-shard tick.
        Pending migration admits land here, at the tick boundary: shards
        with no admit dispatch first, so a destination's restore overlaps
        their mining instead of delaying it."""
        order = sorted(range(self.n_shards),
                       key=lambda s: bool(self._pending_admits[s]))
        sp = self.obs.tracer.begin("sharded.tick", cat="host")
        if self.placement == "devices":
            begun = []
            for s in order:
                self._flush_pending(s)
                svc = self.shards[s]
                if svc.queue:
                    p = svc.tick_begin()
                    if p is not None:
                        begun.append((s, svc, p))
            out = []
            for s, svc, p in begun:
                st = svc.tick_finish(p)
                self._busy_acc[s] += st.device_s
                out.append(st)
        else:
            out = []
            for s in order:
                self._flush_pending(s)
                svc = self.shards[s]
                if svc.queue:
                    st = svc.tick()
                    if st is not None:
                        self._busy_acc[s] += st.device_s
                        out.append(st)
        self.obs.tracer.finish(sp, shards=len(out))
        if out:
            self._invalidate_snapshot()
            self._tick_count += 1
            # cohort events fire *before* any auto-rebalance: the journal
            # must record the tick ahead of the migrations it triggers
            # (replay applies them in that order), and the pre-rebalance
            # view is the same cohort content
            self._emit_tick_events()
            if self.rebalance_every \
                    and self._tick_count % self.rebalance_every == 0:
                self.rebalance(busy_weights=self.shard_load()
                               if self.busy_weighted_rebalance else None)
        return out

    def run(self) -> list[TickStats]:
        out: list[TickStats] = []
        while any(svc.queue for svc in self.shards):
            out.extend(self.tick())
        # no queued work never means no parked work: a migrate() with
        # nothing left to mine would otherwise strand its patient in the
        # admit queue past the drain
        self._flush_pending()
        return out

    # --- migration / rebalancing --------------------------------------------
    def migrate(self, key, dst: int) -> None:
        """Hand a patient to shard ``dst``: queued deltas move in arrival
        order, then store history (spill format), sketch row (subtract/add)
        and mined corpus rows, and the router re-pins the key.  A no-op if
        the key already lives on ``dst``.

        With ``async_migration`` only phase 1 runs here — the source-side
        extract (host copies off the source device) — and the state parks
        in the destination's admit queue; the destination-side restore
        (plane growth, sketch scatter, the shape-change retrace) is paid
        at the next tick boundary, overlapped with the other shards'
        dispatched mining.  The router re-pins immediately, so submits
        after the handoff queue on the destination and mine only after its
        state has landed (the tick admits before assembling that shard's
        wave)."""
        if key not in self.pids:
            raise KeyError(f"unknown patient key {key!r}")
        if not 0 <= dst < self.n_shards:
            # before any mutation: a negative dst would otherwise index
            # shards[-1] and strand the state off-route
            raise ValueError(f"dst {dst} out of range [0, {self.n_shards})")
        if key in self._pending_keys:
            # the key's state is parked in an admit queue; land it so the
            # source below is a real shard, not the queue
            self._flush_pending()
        src = self.router.route(key)
        if src == dst:
            return
        t0 = time.perf_counter()
        sp = self.obs.tracer.begin("migrate", cat="migration",
                                   track=f"shard{src}", key=repr(key),
                                   src=src, dst=dst)
        src_svc, dst_svc = self.shards[src], self.shards[dst]
        queued = [d for d in src_svc.queue if d.key == key]
        if queued:
            src_svc.queue = deque(
                d for d in src_svc.queue if d.key != key)
            dst_svc.queue.extend(queued)
        state = None
        if key in src_svc.store.pids:
            state = src_svc.extract_patient(key)
            if self.async_migration:
                self._pending_admits[dst].append(state)
                self._pending_keys[key] = dst
            else:
                dst_svc.admit_patient(state)
        self.router.assign(key, dst)
        self.migrations.append((key, src, dst))
        if self.events.wants(Migrated):
            self.events.emit(Migrated(key, src=src, dst=dst, state=state))
        self.migration_wall_s += time.perf_counter() - t0
        self.obs.tracer.finish(sp)
        self._m_migrations.inc()
        self._invalidate_snapshot()

    def admit_patient(self, state: PatientState,
                      dst: int | None = None) -> int:
        """Admit an externally-extracted patient (cross-service handoff:
        ``extract_patient`` elsewhere, admit here).  Routes to ``dst``
        (or the router's home for the key), registers a global pid, pins
        the router, and emits :class:`Migrated` with ``src=None`` so
        feed consumers (the serving feature store) see the patient's
        already-mined rows arrive."""
        key = state.key
        if key in self.pids or key in self._pending_keys:
            raise ValueError(f"key {key!r} already admitted")
        dst = self.router.route(key) if dst is None else dst
        if not 0 <= dst < self.n_shards:
            raise ValueError(f"dst {dst} out of range [0, {self.n_shards})")
        self.pids[key] = len(self.pids)
        pid = self.shards[dst].admit_patient(state)
        self.router.assign(key, dst)
        self._invalidate_snapshot()
        if self.events.wants(Migrated):
            self.events.emit(Migrated(key, src=None, dst=dst, state=state))
        return pid

    def _flush_pending(self, shard: int | None = None) -> None:
        """Phase 2 of async migration: land parked patient states on their
        destination shard (all shards when ``shard`` is None).  Called per
        shard at the tick boundary, and by any whole-cohort read — a
        snapshot taken between migrate() and the next tick must already
        see the patient on its new home."""
        targets = range(self.n_shards) if shard is None else (shard,)
        for s in targets:
            pending = self._pending_admits[s]
            if not pending:
                continue
            t0 = time.perf_counter()
            sp = self.obs.tracer.begin("migration.admit", cat="migration",
                                       track=f"shard{s}", n=len(pending))
            for state in pending:
                self.shards[s].admit_patient(state)
                del self._pending_keys[state.key]
            pending.clear()
            self.admit_wall_s += time.perf_counter() - t0
            self.obs.tracer.finish(sp)
            self._invalidate_snapshot()
        self._m_pending.set(sum(len(p) for p in self._pending_admits))

    def _patient_costs(self, svc: StreamService) -> dict:
        """Per-patient mining cost on one shard: n^2 * BYTES_PER_PAIR over
        held patients (resident, host-spilled, or disk-demoted; disk
        counts come from the block index, no decode) — the dense
        pair-slab model of chunking / store eviction."""
        return {k: n ** 2 * chunking.BYTES_PER_PAIR
                for k, n in svc.store.event_counts().items()}

    def shard_loads(self) -> list[int]:
        """Resident pair-cost bytes per shard (the rebalance signal)."""
        self._flush_pending()
        return [sum(self._patient_costs(svc).values())
                for svc in self.shards]

    def shard_load(self) -> list[float]:
        """Device-timed busy fraction per shard over the window since the
        last poll (completion-read seconds / window elapsed, clamped to
        [0, 1]).  Unlike :meth:`shard_loads` this measures *observed* device
        occupancy, not the static pair-cost model: a shard whose device is
        slower, contended, or serving a pathological history mix reads hot
        even when its resident bytes look balanced.  The window resets on
        every call, so callers poll it like a rate counter; with nothing
        ticked since the last poll all fractions are 0."""
        now = time.perf_counter()
        window = max(now - self._busy_t0, 1e-9)
        fracs = [min(b / window, 1.0) for b in self._busy_acc]
        self._busy_acc = [0.0] * self.n_shards
        self._busy_t0 = now
        return fracs

    def rebalance(self, imbalance_threshold: float | None = None,
                  max_moves: int | None = None,
                  min_gain: float | None = None,
                  busy_weights: list[float] | None = None) -> list[tuple]:
        """Greedy LPT rebalancing: while the hottest shard's load exceeds
        ``imbalance_threshold`` x the mean, migrate its costliest patient
        that still lowers the maximum to the coldest shard.  Every move
        strictly decreases the load spread (sum of squares), so this
        terminates; returns the (key, src, dst) moves made.

        ``min_gain`` is the migration-cost hysteresis: a handoff pays host
        copies plus a shape-change retrace at the destination, so a move is
        only worth it when it lowers ``max(hot, cold)`` by more than
        ``min_gain`` x the mean load.  A borderline patient whose move
        would barely dent the imbalance stays put instead of ping-ponging
        between two near-equal shards on alternating rebalance passes.

        ``busy_weights`` (typically :meth:`shard_load` fractions) scales
        each shard's cost model by its observed device occupancy: weights
        are normalized to mean 1 and a patient's effective cost on shard
        ``s`` is ``bytes * w[s]`` — the same bytes cost more on a busy
        device, so patients drain toward shards that are measurably idle,
        not just byte-light.  All-zero weights (nothing ticked since the
        last poll) fall back to the unweighted model.  Weighted moves no
        longer strictly shrink the sum of squares (a patient's cost changes
        as it moves), so the loop carries an iteration safety cap."""
        thr = (self.imbalance_threshold if imbalance_threshold is None
               else imbalance_threshold)
        gain_floor = self.min_gain if min_gain is None else min_gain
        self._flush_pending()   # cost accounting needs every patient homed
        costs = [self._patient_costs(svc) for svc in self.shards]
        w = [1.0] * self.n_shards
        if busy_weights is not None:
            if len(busy_weights) != self.n_shards:
                raise ValueError(
                    f"busy_weights covers {len(busy_weights)} shards, "
                    f"service has {self.n_shards}")
            wmean = sum(busy_weights) / len(busy_weights)
            if wmean > 0:
                w = [bw / wmean for bw in busy_weights]
        loads = [sum(c.values()) * w[s] for s, c in enumerate(costs)]
        mean = sum(loads) / len(loads)
        moves: list[tuple] = []
        cap = 4 * sum(len(c) for c in costs) + 4  # weighted-cost safety cap
        while (max_moves is None or len(moves) < max_moves) \
                and len(moves) < cap:
            hot = max(range(len(loads)), key=loads.__getitem__)
            cold = min(range(len(loads)), key=loads.__getitem__)
            if loads[hot] <= thr * mean or loads[hot] == 0:
                break
            cands = [(c, k) for k, c in costs[hot].items()
                     if loads[cold] + c * w[cold] < loads[hot]
                     and loads[hot] - max(loads[hot] - c * w[hot],
                                          loads[cold] + c * w[cold])
                     > gain_floor * mean]
            if not cands:
                break
            c, key = max(cands, key=lambda t: t[0])
            self.migrate(key, cold)
            costs[cold][key] = costs[hot].pop(key)
            loads[hot] -= c * w[hot]
            loads[cold] += c * w[cold]
            moves.append((key, hot, cold))
        if moves:
            self._m_rebalances.inc()
            if self.events.wants(Rebalanced):
                self.events.emit(Rebalanced(tuple(moves)))
        return moves

    def sample_metrics(self) -> None:
        """Refresh snapshot-time gauges on every shard (store plane bytes /
        occupancy, sketch load factor) plus the sharded-level pending-admit
        queue depth.  Called by ``Telemetry``-aware snapshot paths, never
        per tick."""
        if not self.obs.enabled:
            return
        for svc in self.shards:
            svc.sample_metrics()
        self._m_pending.set(sum(len(p) for p in self._pending_admits))

    # --- checkpoint ---------------------------------------------------------
    def state_dict(self) -> dict:
        """Whole-sharded-service state: every shard's service state plus
        the cross-shard pieces a restored process needs to continue
        byte-identically — router pins (sticky-until-migrated homes),
        global pid table, *in-flight* migration payloads (pending admits
        are captured, not flushed: a checkpoint must not advance the
        schedule), migration history, and the tick counter that phases
        rebalancing."""
        def pack_patient(st: PatientState) -> dict:
            return {"key": encode_key(st.key),
                    "phenx": np.asarray(st.phenx),
                    "date": np.asarray(st.date),
                    "seq_ids": np.asarray(st.seq_ids),
                    "corpus_seq": np.asarray(st.corpus_seq),
                    "corpus_dur": np.asarray(st.corpus_dur)}
        return {
            "shards": [svc.state_dict() for svc in self.shards],
            "router_pinned": [[encode_key(k), int(s)]
                              for k, s in self.router.pinned.items()],
            "pids": [[encode_key(k), int(p)] for k, p in self.pids.items()],
            "pending_admits": [[pack_patient(st) for st in p]
                               for p in self._pending_admits],
            "migrations": [[encode_key(k), int(a), int(b)]
                           for k, a, b in self.migrations],
            "tick_count": self._tick_count,
        }

    def load_state_dict(self, state: dict) -> None:
        if len(state["shards"]) != self.n_shards:
            raise ValueError(f"checkpoint has {len(state['shards'])} shards, "
                             f"service has {self.n_shards}")
        for svc, st in zip(self.shards, state["shards"]):
            svc.load_state_dict(st)
        self.router.pinned = {decode_key(k): int(s)
                              for k, s in state["router_pinned"]}
        self.pids = {decode_key(k): int(p) for k, p in state["pids"]}
        self._pending_admits = [
            [PatientState(decode_key(d["key"]),
                          np.asarray(d["phenx"], np.int32),
                          np.asarray(d["date"], np.int32),
                          np.asarray(d["seq_ids"], np.int64),
                          np.asarray(d["corpus_seq"], np.int64),
                          np.asarray(d["corpus_dur"], np.int32))
             for d in p]
            for p in state["pending_admits"]]
        self._pending_keys = {st.key: s
                              for s, p in enumerate(self._pending_admits)
                              for st in p}
        self.migrations = [(decode_key(k), int(a), int(b))
                           for k, a, b in state["migrations"]]
        self._tick_count = int(state["tick_count"])
        self._invalidate_snapshot()

    # --- snapshot / queries -------------------------------------------------
    def _global_pids(self, svc: StreamService, local_pat: np.ndarray):
        """Translate one shard's local pids to global pids (via keys)."""
        if len(local_pat) == 0:
            return local_pat
        # pid_capacity, not n_patients: local pids are retired (never
        # reused) when a patient migrates out, so the dense range has holes
        lut = np.full(svc.store.pid_capacity, -1, np.int32)
        for key, lpid in svc.store.pids.items():
            lut[lpid] = self.pids[key]
        return lut[local_pat]

    def global_counts(self) -> np.ndarray:
        """The merged support table (one psum over the mesh when set),
        cached alongside the snapshot — repeated same-version reads pay
        the merge once."""
        self._flush_pending()   # an in-flight patient's ids are subtracted
        if self._gcounts is None:
            self._gcounts = np.asarray(merge_sharded_counts(
                [svc.sketch.counts for svc in self.shards], self.mesh))
        return self._gcounts

    def snapshot(self) -> Snapshot:
        """Whole-cohort corpus (global pids) + merged support table."""
        self._flush_pending()   # in-flight corpus rows belong to no shard
        if self._snap is not None:
            return self._snap
        snaps = [svc.snapshot() for svc in self.shards]
        self._snap = Snapshot(
            seq=np.concatenate([s.seq for s in snaps]),
            dur=np.concatenate([s.dur for s in snaps]),
            patient=np.concatenate([
                self._global_pids(svc, s.patient)
                for svc, s in zip(self.shards, snaps)]).astype(np.int32),
            counts=self.global_counts(),
            n_buckets_log2=self.n_buckets_log2)
        return self._snap

    def pid_to_key(self) -> dict:
        return {pid: k for k, pid in self.pids.items()}

    def screened_keep(self, threshold: int,
                      snap: Snapshot | None = None) -> np.ndarray:
        snap = snap if snap is not None else self.snapshot()
        return np.asarray(sparsity.screen_hash_from_counts(
            snap.seq, np.ones(len(snap.seq), bool), snap.counts, threshold,
            self.n_buckets_log2))

    def merged_counts(self, batch_counts) -> np.ndarray:
        """Global live table merged with batch-screen counts."""
        return np.asarray(sparsity.merge_bucket_counts(
            self.global_counts(), batch_counts))

"""Mesh-sharded streaming mining: patient->shard router over per-shard services.

The batch pipeline scales by sharding patients over the ('pod', 'data')
mesh and merging per-shard screen tables with one psum
(data/pipeline + core/sparsity.screen_hash).  The streaming analogue keeps
one :class:`~repro.stream.service.StreamService` (PatientStore +
OnlineSupportSketch + delta miner) per shard and adds two pieces:

  * **router** — a patient key is pinned to a shard for its lifetime (its
    history planes and sketch rows live there), either by a stable hash
    (streaming default: keys arrive unannounced) or by a pinned LPT
    assignment from ``data/pipeline.balance_buckets`` when per-patient
    event counts are known up front (replays, backfills) — pair cost is
    quadratic in events, so hash-balance is not work-balance;
  * **global screen** — per-shard sketch tables count distinct
    (patient, sequence) pairs over disjoint patient sets, so the global
    table is their elementwise sum: one psum over the ('data',) mesh
    (``distributed.sharding.merge_sharded_counts``), exactly the
    collective of the batch hash screen.  Queries compose snapshot masks
    with the merged table, so every query sees the whole cohort.

Invariant (property-tested in tests/test_stream_sharded.py): replaying a
dbmart through the sharded service equals the single-shard service and
batch mine+screen on corpus, support counts, and query masks, for any
shard count, router, and per-shard eviction budget.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.core import sparsity
from repro.data import pipeline
from repro.distributed.sharding import merge_sharded_counts
from repro.stream.service import Snapshot, SnapshotQueries, StreamService, \
    TickStats


def stable_shard_hash(key) -> int:
    """Process-stable key hash (python ``hash`` is salted for strings)."""
    if isinstance(key, (int, np.integer)):
        # splitmix64 finalizer: avalanches dense patient ids
        h = (int(key) + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return h ^ (h >> 31)
    return zlib.crc32(repr(key).encode())


class ShardRouter:
    """Patient key -> shard id; sticky by construction (pure function of the
    key, plus an optional pinned table for balanced placement)."""

    def __init__(self, n_shards: int, pinned: dict | None = None):
        self.n_shards = n_shards
        self.pinned = pinned or {}

    def route(self, key) -> int:
        s = self.pinned.get(key)
        if s is None:
            s = stable_shard_hash(key) % self.n_shards
        return s

    @classmethod
    def balanced(cls, keys, nevents, n_shards: int) -> "ShardRouter":
        """Pin known patients by pair-count LPT (``balance_buckets``); keys
        not in the table still hash — cold starts keep working."""
        buckets = pipeline.balance_buckets(
            np.asarray(nevents, np.int64), n_shards)
        pinned = {keys[p]: s for s, b in enumerate(buckets) for p in b}
        return cls(n_shards, pinned)


class ShardedStreamService(SnapshotQueries):
    """StreamService API over ``n_shards`` shard-local services.

    ``mesh`` (a ('data',)-axis mesh) routes the global-table merge through
    the shard_map psum; without one the merge is a local sum — results are
    identical, only the collective differs.  Remaining kwargs configure
    each shard's StreamService (note ``budget_bytes`` is *per shard*: the
    eviction working set is a shard-local property, like the per-chunk
    byte budget of batch chunking).
    """

    def __init__(self, n_shards: int = 1, router: ShardRouter | None = None,
                 mesh=None, **service_kwargs):
        if router is not None and router.n_shards != n_shards:
            raise ValueError(f"router covers {router.n_shards} shards, "
                             f"service has {n_shards}")
        self.router = router or ShardRouter(n_shards)
        self.mesh = mesh
        self.shards = [StreamService(**service_kwargs)
                       for _ in range(n_shards)]
        self.codec = self.shards[0].codec
        self.n_buckets_log2 = self.shards[0].sketch.n_buckets_log2
        self.pids: dict = {}        # key -> global pid (first-submit order)
        self._snap: Snapshot | None = None

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def stats(self) -> list[TickStats]:
        return [st for svc in self.shards for st in svc.stats]

    # --- ingest -------------------------------------------------------------
    def submit(self, key, dates, phenx) -> None:
        if len(np.asarray(dates).reshape(-1)) == 0:
            return
        if key not in self.pids:
            self.pids[key] = len(self.pids)
        self.shards[self.router.route(key)].submit(key, dates, phenx)

    def tick(self) -> list[TickStats]:
        """One wave on every shard with queued work (shard-parallel on a
        real mesh; host-serial here).  Empty list == all queues drained."""
        out = [st for svc in self.shards if svc.queue
               for st in [svc.tick()] if st is not None]
        if out:
            self._snap = None
        return out

    def run(self) -> list[TickStats]:
        out: list[TickStats] = []
        while any(svc.queue for svc in self.shards):
            out.extend(self.tick())
        return out

    # --- snapshot / queries -------------------------------------------------
    def _global_pids(self, svc: StreamService, local_pat: np.ndarray):
        """Translate one shard's local pids to global pids (via keys)."""
        if len(local_pat) == 0:
            return local_pat
        lut = np.full(svc.store.n_patients, -1, np.int32)
        for key, lpid in svc.store.pids.items():
            lut[lpid] = self.pids[key]
        return lut[local_pat]

    def global_counts(self) -> np.ndarray:
        """The merged support table (one psum over the mesh when set)."""
        return np.asarray(merge_sharded_counts(
            [svc.sketch.counts for svc in self.shards], self.mesh))

    def snapshot(self) -> Snapshot:
        """Whole-cohort corpus (global pids) + merged support table."""
        if self._snap is not None:
            return self._snap
        snaps = [svc.snapshot() for svc in self.shards]
        self._snap = Snapshot(
            seq=np.concatenate([s.seq for s in snaps]),
            dur=np.concatenate([s.dur for s in snaps]),
            patient=np.concatenate([
                self._global_pids(svc, s.patient)
                for svc, s in zip(self.shards, snaps)]).astype(np.int32),
            counts=self.global_counts(),
            n_buckets_log2=self.n_buckets_log2)
        return self._snap

    def pid_to_key(self) -> dict:
        return {pid: k for k, pid in self.pids.items()}

    def screened_keep(self, threshold: int,
                      snap: Snapshot | None = None) -> np.ndarray:
        snap = snap if snap is not None else self.snapshot()
        return np.asarray(sparsity.screen_hash_from_counts(
            snap.seq, np.ones(len(snap.seq), bool), snap.counts, threshold,
            self.n_buckets_log2))

    def merged_counts(self, batch_counts) -> np.ndarray:
        """Global live table merged with batch-screen counts."""
        return np.asarray(sparsity.merge_bucket_counts(
            self.global_counts(), batch_counts))

"""Online support sketch: incremental distinct-(patient, sequence) counts.

Batch screening (core/sparsity.local_bucket_counts) dedupes sequences per
patient row, multiply-shift hashes them into 2^H buckets and scatter-adds.
The streaming sketch maintains the *same* bucket table incrementally: per
patient it keeps the sorted set of sequence ids already contributed, and a
tick's delta slab increments a bucket only for ids the patient has never
produced (dedup within the delta by sort-run flags, against history by
binary search).  Consequences, both property-tested:

  * the table equals ``local_bucket_counts`` of the full batch-mined
    corpus after any replay order — not an approximation of it;
  * it stays mergeable with batch-screen counts
    (``sparsity.merge_bucket_counts``) and keeps the one-sided error of
    the hash screen: collisions only ever over-count, so a non-sparse
    sequence is never dropped.

Shard migration hands a patient's row between sketches with
``extract_row`` / ``admit_row``: the sorted distinct-id set moves, and the
bucket table transfers by subtract-at-source / add-at-dest — each side's
table stays exactly ``local_bucket_counts`` of *its* patient set, so the
merged (psum'd) table is unchanged by any migration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_lib
from repro.core import sparsity
from repro.core.encoding import SENTINEL


@functools.partial(jax.jit, static_argnames=("n_buckets_log2",))
def sketch_update(counts, stored, seq, mask, n_buckets_log2: int):
    """One tick: (counts', merged per-patient sets, per-row novel counts).

    ``stored`` [B, C] are the patients' sorted sentinel-padded sequence
    sets; ``seq``/``mask`` [B, T] the tick's delta slab rows.
    """
    B, C = stored.shape
    flat = jnp.where(mask, jnp.asarray(seq, jnp.int64), SENTINEL).reshape(B, -1)
    srt = jnp.sort(flat, axis=1)
    first = sparsity.row_first_flags(srt)   # same dedup as the batch screen
    idx = jax.vmap(jnp.searchsorted)(stored, srt)
    present = jnp.take_along_axis(stored, jnp.clip(idx, 0, C - 1), axis=1) == srt
    novel = first & ~present
    h = sparsity.hash_bucket(srt, n_buckets_log2)
    counts = counts.at[h.reshape(-1)].add(novel.reshape(-1).astype(jnp.int32))
    merged = jnp.sort(
        jnp.concatenate([stored, jnp.where(novel, srt, SENTINEL)], axis=1),
        axis=1)
    return counts, merged, jnp.sum(novel, axis=1).astype(jnp.int32)


class _PendingSketchUpdate:
    """Device phase of one tick's sketch fold, awaiting host bookkeeping.

    ``counts`` was already swapped in by ``update_begin`` (device arrays
    are futures; nothing blocked).  ``update_finish`` materializes
    ``n_novel`` and lands ``merged`` in the set planes."""

    __slots__ = ("pids", "merged", "n_novel")

    def __init__(self, pids, merged, n_novel):
        self.pids = pids
        self.merged = merged
        self.n_novel = n_novel


class OnlineSupportSketch:
    """Incrementally maintained hash-bucket support table + per-patient sets.

    ``device`` pins the table and set planes (same commitment contract as
    :class:`~repro.stream.store.PatientStore`): tick folds and handoff
    scatters stay on that device."""

    def __init__(self, n_buckets_log2: int = 20, pad_multiple: int = 64,
                 device=None, telemetry=None, labels: dict | None = None):
        self.n_buckets_log2 = n_buckets_log2
        self.pad_multiple = pad_multiple
        self.device = device
        self.counts = jnp.zeros(1 << n_buckets_log2, jnp.int32)
        self.seqset = jnp.full((0, pad_multiple), SENTINEL, jnp.int64)
        self.n_distinct = np.zeros(0, np.int32)
        if device is not None:
            self.counts = jax.device_put(self.counts, device)
            self.seqset = jax.device_put(self.seqset, device)
        self.obs = telemetry if telemetry is not None else obs_lib.NOOP
        lbl = labels or {}
        m = self.obs.metrics
        self._m_novel = m.counter("sketch.novel_ids", **lbl)
        self._m_growths = m.counter("sketch.plane_growths", **lbl)
        self._m_load = m.gauge("sketch.bucket_load_factor", **lbl)
        self._m_cols = m.gauge("sketch.set_columns", **lbl)

    @property
    def n_patients(self) -> int:
        return self.seqset.shape[0]

    def ensure_patients(self, n: int) -> None:
        if n <= self.n_patients:
            return
        grow = -(-n // 8) * 8 - self.n_patients
        self.seqset = jnp.pad(self.seqset, ((0, grow), (0, 0)),
                              constant_values=SENTINEL)
        self.n_distinct = np.pad(self.n_distinct, (0, grow))

    def _ensure_columns(self, n: int) -> None:
        """Widen the per-patient set planes to hold ``n`` ids (round up to
        the pad multiple, double geometrically — one growth policy for
        tick updates and migration admits)."""
        need = -(-max(n, 1) // self.pad_multiple) * self.pad_multiple
        if need <= self.seqset.shape[1]:
            return
        need = max(need, 2 * self.seqset.shape[1])
        self.seqset = jnp.pad(
            self.seqset, ((0, 0), (0, need - self.seqset.shape[1])),
            constant_values=SENTINEL)
        self._m_growths.inc()

    def update(self, pids, seq, mask) -> int:
        """Fold a tick's delta slab rows into the table; returns #novel ids.

        Pids must be distinct: rows gather/scatter the per-patient sets,
        so a repeated pid would double-count its buckets and lose part of
        its merged set."""
        return self.update_finish(self.update_begin(pids, seq, mask))

    def update_begin(self, pids, seq, mask) -> _PendingSketchUpdate:
        """Device phase only: dispatch the jitted fold and swap the new
        table in without forcing any host transfer, so a sharded tick can
        enqueue every shard's fold before blocking on the first
        (``update_finish`` completes the host bookkeeping)."""
        pids = np.asarray(pids, np.int32)
        if len(np.unique(pids)) != len(pids):
            raise ValueError("duplicate pids in one sketch update")
        self.ensure_patients(int(pids.max(initial=-1)) + 1)
        stored = self.seqset[pids]
        B = stored.shape[0]
        self.counts, merged, n_novel = sketch_update(
            self.counts, stored, jnp.asarray(seq).reshape(B, -1),
            jnp.asarray(mask).reshape(B, -1), self.n_buckets_log2)
        return _PendingSketchUpdate(pids, merged, n_novel)

    def update_finish(self, pending: _PendingSketchUpdate) -> int:
        """Host phase: materialize the novel counts, grow the set planes if
        a patient's distinct set outgrew them, and land the merged rows."""
        pids, merged = pending.pids, pending.merged
        self.n_distinct[pids] += np.asarray(pending.n_novel)
        self._ensure_columns(int(self.n_distinct.max(initial=1)))
        C = self.seqset.shape[1]
        if merged.shape[1] < C:
            merged = jnp.pad(merged, ((0, 0), (0, C - merged.shape[1])),
                             constant_values=SENTINEL)
        self.seqset = self.seqset.at[pids].set(merged[:, :C])
        n_novel = int(np.asarray(pending.n_novel).sum())
        self._m_novel.inc(n_novel)
        return n_novel

    def sample_metrics(self) -> None:
        """Snapshot-time gauges: bucket load factor (occupied / 2^H — one
        device->host table copy, so never sampled per tick) and the
        per-patient set plane width."""
        if not self.obs.enabled:
            return
        table = np.asarray(self.counts)
        self._m_load.set(float(np.count_nonzero(table)) / max(len(table), 1))
        self._m_cols.set(int(self.seqset.shape[1]))

    # --- migration handoff --------------------------------------------------
    def _bucket_transfer(self, ids: np.ndarray, sign: int) -> None:
        """Scatter ``sign`` into the ids' buckets, padded to the column
        multiple with zero weights — handoff sizes vary per patient, so an
        exact-length hash would compile one XLA program per distinct set
        size; quantizing keeps the variant count O(log)."""
        cap = -(-max(len(ids), 1) // self.pad_multiple) * self.pad_multiple
        padded = np.zeros(cap, np.int64)
        padded[: len(ids)] = ids
        w = np.zeros(cap, np.int32)
        w[: len(ids)] = sign
        h = sparsity.hash_bucket(jnp.asarray(padded), self.n_buckets_log2)
        self.counts = self.counts.at[h].add(jnp.asarray(w))

    def extract_row(self, pid: int) -> np.ndarray:
        """Withdraw a patient's set: returns its sorted distinct sequence
        ids and *subtracts* one from each id's bucket, so this table is
        again exactly ``local_bucket_counts`` of the remaining patients.
        The row stays allocated (pids are never reused) but zeroed."""
        if pid >= self.n_patients:
            return np.zeros(0, np.int64)
        n = int(self.n_distinct[pid])
        ids = np.asarray(self.seqset[pid])[:n]   # host slice: stable shapes
        if n:
            self._bucket_transfer(ids, -1)
            self.seqset = self.seqset.at[pid].set(SENTINEL)
            self.n_distinct[pid] = 0
        return ids

    def admit_row(self, pid: int, ids) -> None:
        """Install a migrated patient's sorted distinct-id set at ``pid``
        and *add* one to each id's bucket (the other half of the
        subtract/add transfer; extract then admit is a global no-op)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        self.ensure_patients(pid + 1)
        self._ensure_columns(len(ids))
        row = np.full(self.seqset.shape[1], SENTINEL, np.int64)
        row[: len(ids)] = ids
        self.seqset = self.seqset.at[pid].set(jnp.asarray(row))
        self.n_distinct[pid] = len(ids)
        if len(ids):
            self._bucket_transfer(ids, 1)

    # --- checkpoint ---------------------------------------------------------
    def state_dict(self) -> dict:
        """Bucket table + per-patient set planes (shapes included: the
        restored planes keep their exact width, so the first post-restore
        tick retraces nothing the uninterrupted run wouldn't)."""
        return {"counts": np.asarray(self.counts),
                "seqset": np.asarray(self.seqset),
                "n_distinct": self.n_distinct.copy()}

    def load_state_dict(self, state: dict) -> None:
        self.counts = jnp.asarray(np.asarray(state["counts"], np.int32))
        self.seqset = jnp.asarray(np.asarray(state["seqset"], np.int64))
        if self.device is not None:
            self.counts = jax.device_put(self.counts, self.device)
            self.seqset = jax.device_put(self.seqset, self.device)
        self.n_distinct = np.asarray(state["n_distinct"], np.int32).copy()

    # --- interop with the batch screen -------------------------------------
    def merged_with(self, batch_counts):
        """Sketch counts + batch-screen bucket counts (same table format)."""
        return sparsity.merge_bucket_counts(self.counts, batch_counts)

    def keep_mask(self, seq, mask, threshold: int):
        """Hash-screen keep mask over any corpus using the live table."""
        return sparsity.screen_hash_from_counts(
            seq, mask, self.counts, threshold, self.n_buckets_log2)

    def survivors(self, seq, dur, patient, threshold: int, mask=None):
        """Compact a corpus to its hash-screen survivors using the live
        table — the streaming half of ``screen='fused'``: because this
        table exactly equals the batch ``local_bucket_counts``, the
        compacted arrays are byte-identical to the corpus-free batch
        path's survivors on the same corpus."""
        return sparsity.screen_survivors(
            seq, dur, patient, np.asarray(self.counts), threshold,
            self.n_buckets_log2, mask=mask)

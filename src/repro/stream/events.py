"""Typed session events: one subscription API for every mutation.

PR 9 grew two ad-hoc hooks (``subscribe_tick(fn(service))`` and
``subscribe_delta(fn(keys, slot_idx, seq, dur))``); the journal needs
every *other* mutation too (evictions, migrations, rebalances), so the
hooks unify here into a single typed stream: services emit frozen
``SessionEvent`` dataclasses through an :class:`EventDispatcher`, and
consumers register one ``subscribe(fn, kinds=...)`` callback for the
event kinds they care about.  The old hooks survive as thin shims over
the dispatcher.

Two properties the tick hot path relies on:

  * **pay-per-subscriber** — ``dispatcher.wants(Kind)`` gates payload
    assembly, so a service with no subscriber for ``TickCompleted``
    never materializes the per-tick delta feed;
  * **isolation** — a subscriber raising inside ``tick_finish`` would
    otherwise corrupt the tick (corpus appended, stats lost).  By
    default ``emit`` catches per-subscriber exceptions, logs them, and
    counts them on the ``events.subscriber_errors`` metric; consumers
    whose failure *must* propagate (the journal — a silently-dropped
    audit record is worse than a failed tick) subscribe with
    ``isolate=False``.
"""
from __future__ import annotations

import dataclasses
import logging
from collections import deque

import numpy as np

from repro import obs as obs_lib

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class SessionEvent:
    """Base of the event union; ``shard`` is the emitting shard's tag
    (None on a single-shard service and on cohort-level events)."""


@dataclasses.dataclass(frozen=True)
class DeltaSubmitted(SessionEvent):
    """A patient delta entered the ingest queue (pre-mining)."""

    key: object
    dates: np.ndarray    # [d] int32
    phenx: np.ndarray    # [d] int32
    shard: int | None = None


@dataclasses.dataclass(frozen=True)
class TickCompleted(SessionEvent):
    """One completed tick: the publication boundary for read replicas,
    plus the tick's newly-mined corpus rows keyed by patient key
    (``slot_idx`` indexes ``keys``) for incremental consumers.  On a
    sharded service this is the *cohort-level* tick (all shard waves
    collected, pending admits flushed) with per-shard payloads
    concatenated in shard-index order; ``service`` is the emitting
    service (sharded or single-shard)."""

    tick: int
    service: object
    keys: list
    slot_idx: np.ndarray   # [n] int — wave slot of each mined row
    seq: np.ndarray        # [n] int64 mined sequence ids
    dur: np.ndarray        # [n] int32 durations
    shard: int | None = None


@dataclasses.dataclass(frozen=True)
class Evicted(SessionEvent):
    """Patients spilled device -> host (``keys``) and host -> disk
    (``demoted``) by the byte-budget walk inside one tick."""

    keys: tuple
    demoted: tuple
    shard: int | None = None


@dataclasses.dataclass(frozen=True)
class Migrated(SessionEvent):
    """A patient changed homes.  ``src`` is the source shard, or None
    for an external admit (cross-service handoff) — in both cases
    ``state`` carries the admitted :class:`PatientState`, so consumers
    that only see the tick delta feed (the serving feature store) can
    pick up the patient's already-mined rows."""

    key: object
    src: int | None
    dst: int
    state: object


@dataclasses.dataclass(frozen=True)
class Rebalanced(SessionEvent):
    """One rebalance pass finished; ``moves`` is its (key, src, dst)
    list (each move already emitted as a :class:`Migrated`)."""

    moves: tuple


@dataclasses.dataclass(frozen=True)
class CheckpointTaken(SessionEvent):
    """A session checkpoint was written (step = lifetime tick count)."""

    step: int
    path: str


#: the full union, in a stable order (docs + journal framing)
EVENT_KINDS = (DeltaSubmitted, TickCompleted, Evicted, Migrated,
               Rebalanced, CheckpointTaken)


def _normalize_kinds(kinds):
    if kinds is None:
        return None
    if isinstance(kinds, type):
        return (kinds,)
    kinds = tuple(kinds)
    for k in kinds:
        if not (isinstance(k, type) and issubclass(k, SessionEvent)):
            raise TypeError(f"not a SessionEvent kind: {k!r}")
    return kinds


class EventDispatcher:
    """Per-service fan-out of :class:`SessionEvent` to subscribers."""

    def __init__(self, telemetry=None):
        self.obs = telemetry if telemetry is not None else obs_lib.NOOP
        self._subs: list[tuple] = []   # (fn, kinds|None, isolate)
        self._m_errors = self.obs.metrics.counter("events.subscriber_errors")

    def subscribe(self, fn, kinds=None, isolate: bool = True):
        """Register ``fn(event)`` for ``kinds`` (a SessionEvent subclass
        or iterable of them; None = every event).  ``isolate=True``
        (default) contains exceptions raised by ``fn``: they are logged
        and counted on ``events.subscriber_errors`` instead of
        corrupting the emitting tick."""
        self._subs.append((fn, _normalize_kinds(kinds), bool(isolate)))
        return fn

    def wants(self, kind) -> bool:
        """True when some subscriber would receive ``kind`` — emitters
        gate payload assembly on this, so unobserved events are free."""
        return any(kinds is None or issubclass(kind, kinds)
                   for _, kinds, _ in self._subs)

    def emit(self, event: SessionEvent) -> None:
        for fn, kinds, isolate in self._subs:
            if kinds is not None and not isinstance(event, kinds):
                continue
            if not isolate:
                fn(event)
                continue
            try:
                fn(event)
            except Exception:
                logger.exception(
                    "event subscriber %r failed on %s (dropped)",
                    fn, type(event).__name__)
                self._m_errors.inc()


class EventTap:
    """A pull-side buffer over an event source (a dispatcher or any
    service exposing ``subscribe``): ``MiningSession.events()`` returns
    one, and iterating it drains everything emitted since the last
    drain (bounded by ``maxlen`` — oldest events drop first)."""

    def __init__(self, source, kinds=None, maxlen: int | None = 4096):
        self._buf: deque = deque(maxlen=maxlen)
        source.subscribe(self._buf.append, kinds=kinds)

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        while self._buf:
            yield self._buf.popleft()

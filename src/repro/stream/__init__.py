"""Streaming mining subsystem (incremental tSPM+).

Batch mining re-derives all ``n(n-1)/2`` pairs per patient on every run;
a clinical stream appends a handful of events per encounter, so only the
``O(delta * n)`` pairs ending in a new event are actually new.  This
package keeps the screened sequence corpus continuously up to date:

  * ``store``   — device-resident padded patient history planes with
                  per-patient cursors, regrowth, and byte-budget eviction
                  (the streaming analogue of core/chunking);
  * ``delta``   — delta mining ([P, E, D] slabs; jnp reference + the
                  kernels/tspm_delta Pallas kernel);
  * ``counts``  — online support sketch: exact distinct-(patient, seq)
                  hash-bucket counts, incrementally updated, mergeable
                  with batch-screen counts (core/sparsity);
  * ``service`` — micro-batching ingest loop + snapshot queries;
  * ``events``  — the typed session-event union (DeltaSubmitted /
                  TickCompleted / Evicted / Migrated / Rebalanced /
                  CheckpointTaken) + the subscribe/emit dispatcher both
                  services publish through;
  * ``shard``   — patient->shard router (sticky until migrated) +
                  per-shard services over the ('data',) mesh; global
                  screen by one psum table merge; live patient migration
                  and load-triggered LPT rebalancing.

Invariant (property-tested): replaying a dbmart event-by-event through
``service.StreamService`` yields the same corpus, support counts, and
query masks as ``core.mining.mine`` + ``core.sparsity`` on the full
dbmart.
"""
from repro.stream import counts, delta, events, service, shard, \
    store  # noqa: F401
